"""repro.analysis: the contract rules, their computed scopes, and the CLI.

Each rule gets a firing fixture AND a near-miss — the near-miss is the
test that the rule encodes the *contract*, not a string match (a rule
that flags `np.asarray(x, np.int32)` or a split-then-draw would make the
pass unusable).  Plus: suppression semantics, fingerprint stability under
unrelated edits, baseline round-trip, ``--changed`` scoping against a
real git repo, and the dogfood check that the analysis package itself is
clean under its own rules.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import all_rules, run_analysis
from repro.analysis.baseline import load_baseline, split_new, write_baseline
from repro.analysis.cli import main as cli_main
from repro.analysis.engine import module_name_for
from repro.analysis.findings import parse_suppressions

REPO_ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def project(tmp_path, files):
    """Materialise {relpath: source} and run the full rule set over it."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_analysis([tmp_path], tmp_path)


def rule_findings(result, rule_id):
    return [f for f in result.findings if f.rule == rule_id]


# ---------------------------------------------------------------------------
# Registry / self-documentation
# ---------------------------------------------------------------------------

def test_registry_has_the_contract_rules():
    rules = all_rules()
    ids = {r.id for r in rules}
    assert {"prng-key-discipline", "host-sync-hygiene", "unaccounted-noise",
            "locked-shared-state", "canonical-hash-discipline",
            "nondeterminism"} <= ids
    for r in rules:
        assert r.contract, f"{r.id} has no contract line"
        assert r.design.startswith("§"), f"{r.id} has no DESIGN anchor"


def test_module_name_for():
    assert module_name_for("src/repro/arms/fused.py") == "repro.arms.fused"
    assert module_name_for("src/repro/obs/__init__.py") == "repro.obs"
    assert module_name_for("tests/test_obs.py") == "tests.test_obs"


# ---------------------------------------------------------------------------
# prng-key-discipline
# ---------------------------------------------------------------------------

def test_prng_key_reuse_fires(tmp_path):
    result = project(tmp_path, {"src/pkg/a.py": """
        import jax

        def f(key, shape):
            a = jax.random.normal(key, shape)
            b = jax.random.normal(key, shape)
            return a + b
    """})
    hits = rule_findings(result, "prng-key-discipline")
    assert len(hits) == 1 and "reused PRNG stream" in hits[0].message


def test_prng_split_between_draws_is_clean(tmp_path):
    result = project(tmp_path, {"src/pkg/a.py": """
        import jax

        def f(key, shape):
            a = jax.random.normal(key, shape)
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, shape)
            return a + b
    """})
    assert rule_findings(result, "prng-key-discipline") == []


def test_prng_loop_reuse_fires_and_fold_in_loop_is_clean(tmp_path):
    result = project(tmp_path, {"src/pkg/bad.py": """
        import jax

        def f(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, (3,)))
            return out
    """, "src/pkg/good.py": """
        import jax

        def f(key, n):
            out = []
            for i in range(n):
                key = jax.random.fold_in(key, i)
                out.append(jax.random.normal(key, (3,)))
            return out
    """})
    hits = rule_findings(result, "prng-key-discipline")
    assert len(hits) == 1 and hits[0].path == "src/pkg/bad.py"
    assert "inside a loop" in hits[0].message


def test_prng_comprehension_key_is_fresh_per_iteration(tmp_path):
    result = project(tmp_path, {"src/pkg/a.py": """
        import jax

        def f(key, n):
            keys = jax.random.split(key, n)
            return [jax.random.normal(k, (3,)) for k in keys]
    """})
    assert rule_findings(result, "prng-key-discipline") == []


def test_prng_untagged_stdlib_seed_fires_tagged_is_clean(tmp_path):
    result = project(tmp_path, {"src/pkg/a.py": """
        import random

        def bad(seed):
            return random.Random(seed)

        def good(seed):
            return random.Random(f"{seed}:rewire")
    """})
    hits = rule_findings(result, "prng-key-discipline")
    assert len(hits) == 1 and "tagged" in hits[0].message


def test_prng_salt_collision_across_modules(tmp_path):
    result = project(tmp_path, {
        "src/pkg/a.py": "A_SALT = 17\n",
        "src/pkg/b.py": "B_SALT = 17\n",
        "src/pkg/c.py": "C_SALT = 53\n",
        "tests/legacy.py": "OLD_SALT = 17\n",  # tests/ exempt (vendored)
    })
    hits = rule_findings(result, "prng-key-discipline")
    assert {f.path for f in hits} == {"src/pkg/a.py", "src/pkg/b.py"}


# ---------------------------------------------------------------------------
# host-sync-hygiene (computed hot-path scope)
# ---------------------------------------------------------------------------

HOT_PATH_SRC = {"src/pkg/arm.py": """
    import jax

    def helper(x):
        return float(x)

    def reporting(x):          # NOT reachable from fused_round
        return float(x)

    def fused_round(state, x):
        y = helper(x)
        return state, y
"""}


def test_hostsync_flags_sync_in_reachable_helper(tmp_path):
    result = project(tmp_path, HOT_PATH_SRC)
    hits = rule_findings(result, "host-sync-hygiene")
    assert len(hits) == 1
    assert "pkg.arm:helper" in hits[0].message
    # the unreachable twin with the identical body is untouched: the scope
    # is the call graph, not a name list
    assert all("reporting" not in f.message for f in hits)


def test_hostsync_dtype_asarray_is_host_data_not_a_sync(tmp_path):
    result = project(tmp_path, {"src/pkg/arm.py": """
        import numpy as np

        def fused_round(state, active):
            idx = np.asarray(active, np.int32)   # host-data construction
            tail = np.asarray(state)             # device sync — flagged
            return idx, tail
    """})
    hits = rule_findings(result, "host-sync-hygiene")
    assert len(hits) == 1 and "numpy.asarray" in hits[0].message


def test_hostsync_item_in_fused_round_fires(tmp_path):
    result = project(tmp_path, {"src/pkg/arm.py": """
        def fused_round(state, x):
            return x.item()
    """})
    hits = rule_findings(result, "host-sync-hygiene")
    assert len(hits) == 1 and ".item()" in hits[0].message


def test_hostsync_real_whitelist_holds():
    """The repo's own sanctioned sync point stays out of scope."""
    from repro.analysis.rules.hostsync import WHITELIST
    assert "repro.arms.fused:build_contributions" in WHITELIST


# ---------------------------------------------------------------------------
# unaccounted-noise
# ---------------------------------------------------------------------------

def test_noise_sigma_scaled_draw_outside_dp_fires(tmp_path):
    result = project(tmp_path, {"src/pkg/mech.py": """
        import jax

        def add_noise(g, key, sigma):
            return g + sigma * jax.random.normal(key, g.shape)
    """})
    hits = rule_findings(result, "unaccounted-noise")
    assert len(hits) == 1 and "bypassing the accountant" in hits[0].message


def test_noise_core_dp_is_the_sanctioned_home(tmp_path):
    result = project(tmp_path, {"src/repro/core/dp.py": """
        import jax

        def noise_share(g, key, sigma):
            return g + sigma * jax.random.normal(key, g.shape)
    """})
    assert rule_findings(result, "unaccounted-noise") == []


def test_noise_model_initialisers_exempt_but_sigma_scaling_is_not(tmp_path):
    result = project(tmp_path, {"src/repro/models/init.py": """
        import jax

        def init(key, shape):
            return jax.random.normal(key, shape)        # initialiser: fine

        def sneak(key, shape, noise_std):
            return noise_std * jax.random.normal(key, shape)  # flagged
    """})
    hits = rule_findings(result, "unaccounted-noise")
    assert len(hits) == 1 and "noise_std" in hits[0].message


def test_noise_tests_and_benchmarks_exempt(tmp_path):
    result = project(tmp_path, {"tests/test_x.py": """
        import jax

        def fixture(key, sigma):
            return sigma * jax.random.normal(key, (3,))
    """})
    assert rule_findings(result, "unaccounted-noise") == []


# ---------------------------------------------------------------------------
# locked-shared-state (computed serve-thread scope)
# ---------------------------------------------------------------------------

THREADED = {
    "src/app/state.py": """
        import threading

        CACHE = {}
        _LOCK = threading.Lock()

        def put(k, v):
            CACHE[k] = v

        def put_locked(k, v):
            with _LOCK:
                CACHE[k] = v

        def register_thing(k, v):
            CACHE[k] = v     # import-time registration convention
    """,
    "src/app/worker.py": """
        import threading

        from app import state

        def work():
            state.put(1, 2)

        def start():
            t = threading.Thread(target=work)
            t.start()
            return t
    """,
}


def test_locking_flags_unlocked_mutation_in_thread_closure(tmp_path):
    result = project(tmp_path, THREADED)
    hits = rule_findings(result, "locked-shared-state")
    assert len(hits) == 1
    assert "'CACHE'" in hits[0].message and "put()" in hits[0].message


def test_locking_quiet_without_any_thread(tmp_path):
    files = {k: v for k, v in THREADED.items() if k != "src/app/worker.py"}
    result = project(tmp_path, files)
    assert rule_findings(result, "locked-shared-state") == []


def test_locking_threading_local_is_clean(tmp_path):
    files = dict(THREADED)
    files["src/app/state.py"] = """
        import threading

        _TL = threading.local()

        def put(k, v):
            _TL.value = (k, v)
    """
    files["src/app/worker.py"] = files["src/app/worker.py"].replace(
        "state.put(1, 2)", "state.put(1, 2)")
    result = project(tmp_path, files)
    assert rule_findings(result, "locked-shared-state") == []


# ---------------------------------------------------------------------------
# canonical-hash-discipline
# ---------------------------------------------------------------------------

def test_hashing_hand_rolled_dumps_plus_digest_fires(tmp_path):
    result = project(tmp_path, {"src/pkg/addr.py": """
        import hashlib
        import json

        def addr(obj):
            raw = json.dumps(obj, sort_keys=True).encode()
            return hashlib.sha256(raw).hexdigest()
    """})
    hits = rule_findings(result, "canonical-hash-discipline")
    assert len(hits) == 1 and "repro.canon" in hits[0].message


def test_hashing_split_across_functions_is_clean(tmp_path):
    result = project(tmp_path, {"src/pkg/split.py": """
        import hashlib
        import json

        def encode(obj):
            return json.dumps(obj).encode()

        def digest(raw):
            return hashlib.sha256(raw).hexdigest()
    """})
    assert rule_findings(result, "canonical-hash-discipline") == []


def test_hashing_tests_may_rederive(tmp_path):
    result = project(tmp_path, {"tests/test_tamper.py": """
        import hashlib
        import json

        def expected(obj):
            return hashlib.sha256(json.dumps(obj).encode()).hexdigest()
    """})
    assert rule_findings(result, "canonical-hash-discipline") == []


# ---------------------------------------------------------------------------
# nondeterminism
# ---------------------------------------------------------------------------

def test_nondeterminism_fires_in_population_modules(tmp_path):
    result = project(tmp_path, {"src/repro/population/thing.py": """
        import time
        import uuid

        def trace_id(spec):
            return f"{uuid.uuid4()}-{time.time()}-{hash(spec)}"
    """})
    msgs = [f.message for f in rule_findings(result, "nondeterminism")]
    assert len(msgs) == 3
    assert any("uuid.uuid4" in m for m in msgs)
    assert any("time.time" in m for m in msgs)
    assert any("hash()" in m for m in msgs)


def test_nondeterminism_cli_modules_are_reporting_layers(tmp_path):
    result = project(tmp_path, {"src/repro/population/cli.py": """
        import time

        def report():
            return time.time()
    """})
    assert rule_findings(result, "nondeterminism") == []


def test_nondeterminism_out_of_scope_module_untouched(tmp_path):
    result = project(tmp_path, {"src/repro/serve/metrics.py": """
        import time

        def stamp():
            return time.time()
    """})
    assert rule_findings(result, "nondeterminism") == []


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

def test_reasoned_suppression_suppresses(tmp_path):
    result = project(tmp_path, {"src/repro/population/t.py": """
        import time

        def f():
            return time.time()  # repro: allow[nondeterminism] wall metric only
    """})
    assert rule_findings(result, "nondeterminism") == []
    assert len(result.suppressed) == 1


def test_reasonless_suppression_does_not_suppress_and_is_itself_a_finding(tmp_path):
    result = project(tmp_path, {"src/repro/population/t.py": """
        import time

        def f():
            return time.time()  # repro: allow[nondeterminism]
    """})
    assert len(rule_findings(result, "nondeterminism")) == 1
    meta = rule_findings(result, "analysis-suppression")
    assert len(meta) == 1 and "without a reason" in meta[0].message


def test_own_line_suppression_covers_next_line():
    sups = parse_suppressions(
        "# repro: allow[nondeterminism] wall metric\n"
        "t0 = time.time()\n"
    )
    assert 2 in sups and sups[2][0].rule == "nondeterminism"


def test_wrong_rule_suppression_does_not_suppress(tmp_path):
    result = project(tmp_path, {"src/repro/population/t.py": """
        import time

        def f():
            return time.time()  # repro: allow[prng-key-discipline] wrong rule
    """})
    assert len(rule_findings(result, "nondeterminism")) == 1


# ---------------------------------------------------------------------------
# Fingerprints + baseline
# ---------------------------------------------------------------------------

BAD_SRC = """
    import time

    def f():
        return time.time()
"""


def test_fingerprint_survives_unrelated_edits(tmp_path):
    r1 = project(tmp_path / "v1", {"src/repro/population/t.py": BAD_SRC})
    shifted = "# a new comment line\n# another\n" + textwrap.dedent(BAD_SRC)
    r2 = project(tmp_path / "v2", {"src/repro/population/t.py": shifted})
    f1, = rule_findings(r1, "nondeterminism")
    f2, = rule_findings(r2, "nondeterminism")
    assert f1.line != f2.line
    assert f1.fingerprint() == f2.fingerprint()


def test_duplicate_sites_get_distinct_fingerprints(tmp_path):
    result = project(tmp_path, {"src/repro/population/t.py": """
        import time

        def f():
            return time.time()

        def g():
            return time.time()
    """})
    fps = {f.fingerprint() for f in rule_findings(result, "nondeterminism")}
    assert len(fps) == 2


def test_baseline_round_trip_and_ratchet(tmp_path):
    result = project(tmp_path, {"src/repro/population/t.py": BAD_SRC})
    findings = rule_findings(result, "nondeterminism")
    path = tmp_path / "baseline.json"
    write_baseline(path, findings)
    baseline = load_baseline(path)
    new, old = split_new(findings, baseline)
    assert new == [] and old == findings
    # a fresh violation is NOT covered by the old baseline
    r2 = project(tmp_path / "v2", {
        "src/repro/population/t.py": BAD_SRC,
        "src/repro/population/u.py": BAD_SRC,
    })
    new2, old2 = split_new(rule_findings(r2, "nondeterminism"), baseline)
    assert {f.path for f in old2} == {"src/repro/population/t.py"}
    assert {f.path for f in new2} == {"src/repro/population/u.py"}


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == set()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write_tree(root, files):
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))


def test_cli_json_report_and_exit_codes(tmp_path, capsys):
    _write_tree(tmp_path, {"src/repro/population/t.py": BAD_SRC})
    out = tmp_path / "report.json"
    rc = cli_main(["src", "--root", str(tmp_path), "--format", "json",
                   "--out", str(out)])
    capsys.readouterr()
    assert rc == 1
    payload = json.loads(out.read_text())
    assert payload["counts"]["findings"] == 1
    assert payload["findings"][0]["rule"] == "nondeterminism"
    assert payload["findings"][0]["new"] is True
    assert "hot_path_defs" in payload["scopes"]


def test_cli_fail_on_new_respects_baseline(tmp_path, capsys):
    _write_tree(tmp_path, {"src/repro/population/t.py": BAD_SRC})
    rc = cli_main(["src", "--root", str(tmp_path), "--write-baseline"])
    assert rc == 0
    rc = cli_main(["src", "--root", str(tmp_path), "--fail-on-new"])
    capsys.readouterr()
    assert rc == 0   # baselined debt is frozen, not failing
    _write_tree(tmp_path, {"src/repro/population/u.py": BAD_SRC})
    rc = cli_main(["src", "--root", str(tmp_path), "--fail-on-new"])
    err = capsys.readouterr().err
    assert rc == 1 and "u.py" in err  # ...but new debt fails


def test_cli_list_rules(capsys):
    rc = cli_main(["--list-rules"])
    out = capsys.readouterr().out
    assert rc == 0
    for rid in ("prng-key-discipline", "host-sync-hygiene",
                "canonical-hash-discipline"):
        assert rid in out
    assert "allow[<rule-id>]" in out


def test_cli_missing_path_is_usage_error(tmp_path, capsys):
    rc = cli_main(["no/such/dir", "--root", str(tmp_path)])
    capsys.readouterr()
    assert rc == 2


def _git(root, *argv):
    subprocess.run(["git", *argv], cwd=root, check=True,
                   capture_output=True, text=True)


def test_cli_changed_scopes_reporting_to_touched_files(tmp_path, capsys):
    _write_tree(tmp_path, {
        "src/repro/population/old.py": BAD_SRC,
        "src/repro/population/clean.py": "X = 1\n",
    })
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "-c", "user.email=t@t", "-c", "user.name=t",
         "add", "-A")
    _git(tmp_path, "-c", "user.email=t@t", "-c", "user.name=t",
         "commit", "-qm", "seed")
    # old.py's violation predates the diff; new.py's is in it
    _write_tree(tmp_path, {"src/repro/population/new.py": BAD_SRC})
    out = tmp_path / "report.json"
    rc = cli_main(["src", "--root", str(tmp_path), "--changed", "HEAD",
                   "--format", "json", "--out", str(out)])
    capsys.readouterr()
    assert rc == 1
    paths = {f["path"] for f in json.loads(out.read_text())["findings"]}
    assert paths == {"src/repro/population/new.py"}


# ---------------------------------------------------------------------------
# Dogfood + repo gate
# ---------------------------------------------------------------------------

def test_dogfood_analysis_package_is_clean_under_its_own_rules():
    result = run_analysis([REPO_ROOT / "src" / "repro" / "analysis"],
                          REPO_ROOT)
    assert result.findings == []
    assert result.skipped == []


@pytest.mark.slow
def test_repo_gate_src_tests_benchmarks_clean_with_empty_baseline():
    """The PR acceptance gate, as a test: empty baseline, zero findings."""
    baseline = load_baseline(REPO_ROOT / "analysis_baseline.json")
    assert baseline == set()
    result = run_analysis(
        [REPO_ROOT / p for p in ("src", "tests", "benchmarks")], REPO_ROOT)
    assert [f.render() for f in result.findings] == []
