import os
import sys

# Tests see the normal single CPU device (the dry-run sets its own XLA_FLAGS
# in a subprocess; never globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# Make the vendored hypothesis shim (tests/_hyp.py) importable regardless of
# pytest's rootdir/import mode.
sys.path.insert(0, os.path.dirname(__file__))
