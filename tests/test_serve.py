"""repro.serve: dispatch-count invariant, hot swap, slot lifecycle, traffic.

The engine's structural promise — one jitted program launch + one host sync
per steady-state decode step, two launches per admission, zero per eviction
— is asserted against the process-global ``instrumented_jit`` meter (the
same one DESIGN.md §7 pins on fused training rounds).  Hot-swap tests pin
the handoff semantics: a published federation checkpoint is picked up
between steps and in-flight generations complete their full budget under
the new params.

Equivalence tests (prefill vs sequential decode, per-slot positional decode
vs aligned batch decode) use non-MoE archs: MoE expert capacity is computed
per row under the serving vmap (no cross-request routing interference),
which deviates from aligned-batch routing at the dropped-token level — a
documented serving semantic, not drift (see ``repro.serve.engine``).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.instrument import jit_dispatches, reset_jit_dispatches
from repro.models import transformer as tf
from repro.serve.engine import ServeConfig, ServeEngine, batch_generate
from repro.serve.handoff import (
    CheckpointPublisher,
    CheckpointWatcher,
    checkpoint_path,
    list_rounds,
)
from repro.serve.traffic import TrafficConfig, generate_requests, Request


def _engine(slots=2, max_len=32, temperature=1.0, eos_id=None, seed=0):
    return ServeEngine(ServeConfig(
        arch="smollm-360m", slots=slots, max_len=max_len,
        temperature=temperature, eos_id=eos_id, seed=seed,
    ))


def _request(rid=0, prompt_len=6, gen=8, fill=None):
    prompt = (np.full((prompt_len,), fill, np.int32) if fill is not None
              else np.arange(1, prompt_len + 1, dtype=np.int32))
    return Request(rid=rid, arrival=0.0, prompt=prompt, max_new_tokens=gen)


# -- the O(1)-dispatch invariant ---------------------------------------------


def test_steady_state_is_one_dispatch_per_step():
    engine = _engine(slots=3, max_len=32)
    for i in range(3):
        assert not engine.admit(_request(rid=i, prompt_len=4, gen=20))
    reset_jit_dispatches()
    n = 10
    for _ in range(n):
        assert engine.step() == []   # nobody finishes inside the segment
    assert jit_dispatches() == n
    assert engine.decode_steps >= n
    assert engine.decode_dispatches == engine.decode_steps


def test_admission_costs_exactly_two_dispatches():
    engine = _engine(slots=2, max_len=32)
    reset_jit_dispatches()
    engine.admit(_request(rid=0, prompt_len=6, gen=8))
    assert jit_dispatches() == 2          # prefill + slot splice
    assert engine.admit_dispatches == 2


def test_eviction_is_dispatch_free():
    engine = _engine(slots=1, max_len=32, temperature=0.0)
    engine.admit(_request(rid=0, prompt_len=4, gen=2))
    reset_jit_dispatches()
    done = engine.step()                  # budget of 2 reached -> evict
    assert [r.rid for r in done] == [0]
    assert engine.free_slots() == 1
    assert jit_dispatches() == 1          # the decode step itself, nothing more


def test_churn_does_not_add_dispatches():
    # admissions and completions interleave, decode stays 1 launch/step
    engine = _engine(slots=2, max_len=32, temperature=0.0)
    engine.admit(_request(rid=0, prompt_len=4, gen=3))
    engine.admit(_request(rid=1, prompt_len=4, gen=30))
    total_steps = 0
    while engine.busy():
        before = engine.decode_dispatches
        done = engine.step()
        total_steps += 1
        assert engine.decode_dispatches == before + 1
        if done and engine.free_slots() and total_steps < 6:
            engine.admit(_request(rid=90 + total_steps, prompt_len=6, gen=2))
    assert engine.decode_dispatches == engine.decode_steps


# -- hot swap ----------------------------------------------------------------


def test_hot_swap_mid_stream_keeps_inflight_generations(tmp_path):
    engine = _engine(slots=2, max_len=32)
    reqs = [_request(rid=i, prompt_len=4, gen=10) for i in range(2)]
    for r in reqs:
        assert not engine.admit(r)
    for _ in range(3):
        engine.step()
    pub = CheckpointPublisher(str(tmp_path))
    watcher = CheckpointWatcher(str(tmp_path))
    pub.publish(5, jax.tree_util.tree_map(lambda x: x * 1.01, engine.params))
    assert engine.poll_watcher(watcher)
    assert engine.serving_round == 5 and engine.swaps == 1
    while engine.busy():
        engine.step()
    for r in reqs:
        assert len(r.tokens) == 10        # full budget, across the swap
        assert r.round_at_first == -1     # first token was pre-swap


def test_swap_changes_the_sampled_continuation(tmp_path):
    # same engine state, greedy sampling: stepping under swapped (scaled)
    # params is a REAL weight change, not a no-op
    def run(swap):
        engine = _engine(slots=1, max_len=32, temperature=0.0)
        r = _request(rid=0, prompt_len=6, gen=12)
        engine.admit(r)
        if swap:
            # rescaling final-norm/head changes logit sharpness -> greedy
            # path diverges eventually; cheaper than retraining
            engine.set_params(jax.tree_util.tree_map(
                lambda x: x * 0.5, engine.params), round_idx=1)
        while engine.busy():
            engine.step()
        return r.tokens

    base, swapped = run(False), run(True)
    assert len(base) == len(swapped) == 12
    assert base != swapped


def test_watcher_skips_corrupt_then_recovers(tmp_path):
    root = str(tmp_path)
    watcher = CheckpointWatcher(root)
    with open(checkpoint_path(root, 1), "wb") as f:
        f.write(b"torn to shreds")
    assert watcher.poll() is None         # skip, do not raise
    assert watcher.seen_round == -1       # not marked seen: retry allowed
    pub = CheckpointPublisher(root)
    pub.publish(2, {"w": jnp.ones((2,), jnp.float32)})
    got = watcher.poll()
    assert got is not None
    _, round_idx, _ = got
    assert round_idx == 2
    assert watcher.poll() is None         # nothing newer


def test_publisher_prunes_but_keeps_newest(tmp_path):
    pub = CheckpointPublisher(str(tmp_path), keep_last=2)
    for t in range(5):
        pub.publish(t, {"w": jnp.full((2,), float(t))})
    assert list_rounds(str(tmp_path)) == [3, 4]


# -- slot lifecycle ----------------------------------------------------------


def test_eos_evicts_early():
    # probe run: sampling is deterministic in (seed, admit/step counters),
    # so a fresh engine with the same seed reproduces the token stream and
    # we can pick a mid-stream token as the EOS id
    probe = _engine(slots=1, max_len=32, temperature=1.0, seed=11)
    r = _request(rid=0, prompt_len=4, gen=8)
    probe.admit(r)
    while probe.busy():
        probe.step()
    assert len(r.tokens) == 8
    k = next(i for i in range(1, 8) if r.tokens[i] != r.tokens[0])
    eos = r.tokens[k]

    engine = _engine(slots=1, max_len=32, temperature=1.0, seed=11,
                     eos_id=eos)
    r2 = _request(rid=0, prompt_len=4, gen=8)
    assert not engine.admit(r2)
    while engine.busy():
        engine.step()
    assert r2.tokens == r.tokens[:k + 1]  # stopped AT the eos token
    assert engine.free_slots() == 1


def test_budget_of_one_finishes_at_admission():
    engine = _engine(slots=1, max_len=32)
    r = _request(rid=0, prompt_len=4, gen=1)
    assert engine.admit(r)                # finished: never takes the slot
    assert engine.free_slots() == 1
    assert len(r.tokens) == 1 and r.t_done is not None


def test_prompt_exceeding_capacity_is_rejected():
    engine = _engine(slots=1, max_len=8)
    with pytest.raises(ValueError, match="no room to generate"):
        engine.admit(_request(rid=0, prompt_len=8, gen=4))


def test_generation_clamped_to_kv_capacity():
    engine = _engine(slots=1, max_len=12, temperature=0.0)
    r = _request(rid=0, prompt_len=8, gen=100)
    engine.admit(r)
    while engine.busy():
        engine.step()
    assert len(r.tokens) == 4             # max_len - prompt_len


def test_first_token_respects_temperature():
    # satellite-a regression: the FIRST generated token must be sampled at
    # --temperature like the rest, not argmax'd.  At temperature 1 two
    # different engine seeds must disagree on the first token for at least
    # one of several prompts (argmax would make them all identical).
    prompts = [np.full((4,), v, np.int32) for v in (3, 50, 200, 400, 17)]

    def first_tokens(seed):
        engine = _engine(slots=1, max_len=16, temperature=1.0, seed=seed)
        out = []
        for i, p in enumerate(prompts):
            r = Request(rid=i, arrival=0.0, prompt=p, max_new_tokens=1)
            engine.admit(r)
            out.append(r.tokens[0])
        return out

    a, b = first_tokens(0), first_tokens(9)
    assert a != b


# -- numerics: the engine's programs match the reference decode path ----------


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-3b"])
def test_prefill_matches_sequential_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = tf.init(cfg, key)
    b, s, max_len = 2, 7, 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                              cfg.vocab_size)
    logits_p, cache_p = tf.prefill(cfg, params, tf.init_cache(cfg, b, max_len),
                                   toks)
    cache_s = tf.init_cache(cfg, b, max_len)
    for t in range(s):
        logits_s, cache_s = tf.decode_step(cfg, params, cache_s,
                                           toks[:, t:t + 1],
                                           jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_s, np.float32),
                               atol=2e-5, rtol=2e-5)
    for a, b_ in zip(jax.tree_util.tree_leaves(cache_p),
                     jax.tree_util.tree_leaves(cache_s)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-3b"])
def test_positional_decode_matches_aligned_decode(arch):
    # every slot at the SAME position must agree with the aligned batched
    # decode_step (per-slot positions generalize it)
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = tf.init(cfg, key)
    b, s, max_len = 3, 5, 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s + 1), 0,
                              cfg.vocab_size)
    _, cache = tf.prefill(cfg, params, tf.init_cache(cfg, b, max_len),
                          toks[:, :s])
    logits_a, cache_a = tf.decode_step(cfg, params, cache, toks[:, s:s + 1],
                                       jnp.asarray(s, jnp.int32))
    _, cache2 = tf.prefill(cfg, params, tf.init_cache(cfg, b, max_len),
                           toks[:, :s])
    logits_v, cache_v = tf.decode_step_positions(
        cfg, params, cache2, toks[:, s:s + 1],
        jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_a, np.float32),
                               np.asarray(logits_v, np.float32),
                               atol=2e-5, rtol=2e-5)
    for x, y in zip(jax.tree_util.tree_leaves(cache_a),
                    jax.tree_util.tree_leaves(cache_v)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_batch_generate_shapes_and_determinism():
    prompts = np.arange(8, dtype=np.int32).reshape(2, 4) + 1
    a = batch_generate(_engine(slots=2, max_len=16, seed=3), prompts, 6)
    b = batch_generate(_engine(slots=2, max_len=16, seed=3), prompts, 6)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(a, b)


# -- traffic determinism (satellite f) ----------------------------------------


def test_traffic_schedule_is_pinned():
    cfg = TrafficConfig(rate=4.0, n_requests=5, vocab_size=512, seed=0)
    reqs = generate_requests(cfg)
    # literal schedule for seed 0 — a change here means BENCH_serve rows
    # stopped being comparable across commits
    np.testing.assert_allclose(
        [r.arrival for r in reqs],
        [0.169983, 0.424882, 0.429834, 0.430401, 0.567987], atol=1e-6)
    assert [len(r.prompt) for r in reqs] == [16, 32, 16, 16, 32]
    assert [r.max_new_tokens for r in reqs] == [32, 16, 16, 16, 32]
    assert reqs[0].prompt[:6].tolist() == [142, 417, 343, 1, 201, 438]


def test_traffic_same_seed_identical_different_seed_not():
    cfg = TrafficConfig(rate=8.0, n_requests=12, vocab_size=128, seed=7)
    a, b = generate_requests(cfg), generate_requests(cfg)
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival
        assert ra.max_new_tokens == rb.max_new_tokens
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    c = generate_requests(TrafficConfig(rate=8.0, n_requests=12,
                                        vocab_size=128, seed=8))
    assert [r.arrival for r in a] != [r.arrival for r in c]


# -- federation integration ---------------------------------------------------


def test_federation_round_publishes_feed_the_watcher(tmp_path):
    from repro.serve.federation import token_silos, train_and_publish

    # shrink widths only: the smoke stack fixes the layer count
    cfg = get_smoke_config("smollm-360m").replace(
        d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=64,
    )
    silos = token_silos(cfg, hospitals=2, n_per=12, seq_len=8, seed=0)
    report, pub = train_and_publish(
        "fl", cfg, str(tmp_path), rounds=3, batch_size=8, seed=0,
        silos=silos,
    )
    assert report.rounds_completed == 3
    assert pub.published == [0, 1, 2]
    assert list_rounds(str(tmp_path)) == [0, 1, 2]

    engine = ServeEngine(ServeConfig(arch="smollm-360m", slots=1,
                                     max_len=16), model_cfg=cfg)
    watcher = CheckpointWatcher(str(tmp_path))
    assert engine.poll_watcher(watcher)
    assert engine.serving_round == 2      # newest round wins
    # trained params serve: a generation completes under them
    r = Request(rid=0, arrival=0.0,
                prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    engine.admit(r)
    while engine.busy():
        engine.step()
    assert len(r.tokens) == 4
