"""Substrate: optimizers, checkpointing, data generators, MIA machinery."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.mia import auroc, lira_attack, tpr_at_fpr
from repro.data import (
    dirichlet_partition,
    make_gemini_like,
    make_lm_stream,
    make_pancreas_like,
    make_xray_like,
)
from repro.data.partition import train_test_split_silos
from repro.optim import get_optimizer


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw", "adafactor"])
def test_optimizers_descend(name):
    opt = get_optimizer(name, 0.05)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < l0 * 0.05


def test_adafactor_state_is_factored():
    opt = get_optimizer("adafactor", 0.01)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    state = opt.init(params)
    assert state.vr["w"].shape == (64,)
    assert state.vc["w"].shape == (32,)
    assert state.vr["b"].shape == (32,)   # vectors keep full second moment


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.asarray(1.5), "d": [jnp.ones((4,), jnp.bfloat16)]},
    }
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_checkpoint(path, tree, step=7, metadata={"arch": "test"})
    loaded, step, meta = load_checkpoint(path)
    assert step == 7 and meta["arch"] == "test"
    np.testing.assert_array_equal(np.asarray(loaded["a"]), np.asarray(tree["a"]))
    assert loaded["b"]["d"][0].dtype == jnp.bfloat16


def test_gemini_like_matches_published_stats():
    silos = make_gemini_like(n_total=2000)
    assert len(silos) == 8
    assert silos[0].x.shape[1] == 436
    sizes = np.array([len(p) for p in silos])
    assert sizes.max() > 2.5 * sizes.min()          # heavy skew (Fig 2a)
    rate = np.concatenate([p.y for p in silos]).mean()
    assert 0.08 < rate < 0.30                        # mortality imbalance


def test_pancreas_like_matches_published_stats():
    silos = make_pancreas_like(n_total=600, n_genes=2000)
    assert len(silos) == 5
    assert silos[0].x.shape[1] == 2000
    sizes = [len(p) for p in silos]
    assert min(sizes) == sizes[3]                    # Wang (P4) is tiny
    labels = np.concatenate([p.y for p in silos])
    assert set(np.unique(labels)) <= {0, 1, 2, 3}


def test_xray_like_labels():
    silos = make_xray_like(n_total=300, image_size=16)
    assert len(silos) == 3
    y = np.concatenate([p.y for p in silos])
    assert y.shape[1] == 4
    # "No Finding" is mutually exclusive with the pathologies
    assert ((y[:, 3] == 1) & (y[:, :3].sum(1) > 0)).sum() == 0


def test_dirichlet_partition_is_label_skewed():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (1200, 4)).astype(np.float32)
    y = rng.integers(0, 3, 1200)
    silos = dirichlet_partition(x, y, 4, alpha=0.2, seed=0)
    assert sum(len(p) for p in silos) == 1200
    # at least one silo should be clearly skewed at alpha=0.2
    props = [np.bincount(p.y.astype(int), minlength=3) / len(p) for p in silos]
    assert max(p.max() for p in props) > 0.55


def test_train_test_split():
    silos = make_gemini_like(n_total=800)
    train, tx, ty = train_test_split_silos(silos, 0.25, seed=0)
    assert len(train) == len(silos)
    total = sum(len(p) for p in silos)
    assert abs(len(tx) - total * 0.25) < len(silos) * 2


def test_lm_stream_learnable_structure():
    stream = make_lm_stream(64, 32, seed=0)
    b = stream.batch(0, 8)
    assert b["tokens"].shape == (8, 32)
    # ~85% of transitions follow the drift rule
    drift_ok = (np.diff(np.concatenate(
        [b["tokens"], b["labels"][:, -1:]], axis=1), axis=1) % 64)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).mean() > 0.99


def test_auroc_sanity():
    scores = np.array([0.9, 0.8, 0.7, 0.3, 0.2, 0.1])
    labels = np.array([1, 1, 1, 0, 0, 0])
    assert auroc(scores, labels) == 1.0
    assert abs(auroc(np.random.default_rng(0).normal(0, 1, 2000),
                     np.random.default_rng(1).integers(0, 2, 2000)) - 0.5) < 0.05


def test_lira_detects_overfit_model():
    """A nearest-neighbour-ish overfit model must be attackable; LiRA AUROC
    should be well above 0.5 for it."""
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (300, 6)).astype(np.float32)
    y = (rng.random(300) > 0.5).astype(np.float32)  # pure noise labels

    def train_fn(xt, yt, seed):
        return (xt, yt)  # memorising "model"

    def conf_fn(model, xq, yq):
        xt, yt = model
        d = ((xq[:, None] - xt[None]) ** 2).sum(-1)
        nearest = d.argmin(1)
        pred = yt[nearest]
        close = d.min(1) < 1e-9
        p = np.where(pred == yq, np.where(close, 0.99, 0.6),
                     np.where(close, 0.01, 0.4))
        return p

    res = lira_attack(train_fn, conf_fn, x, y, n_shadows=8, seed=0)
    assert res.auroc > 0.8
    assert 0 <= res.tpr_at_1pct_fpr <= 1
