"""Ghost-clipping transformer path: exactness vs the faithful per-example
path, plus the blocked-attention and quadratic-RWKV perf variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import dp as dp_lib
from repro.core.ghost import forward_ghost, ghost_clipped_grad_sum
from repro.models import transformer as tf
from repro.models.attention import _causal_mask, _sdpa, _sdpa_blocked

DENSE_ARCHS = ["nemotron-4-340b", "olmo-1b", "smollm-360m", "gemma-7b"]


def _batch(cfg, b=4, s=12, key=1):
    k = jax.random.key(key)
    return {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(k, 1), (b, s), 0,
                                     cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", DENSE_ARCHS)
def test_ghost_loss_matches_forward(arch):
    cfg = get_smoke_config(arch).replace(tie_embeddings=False)
    params = tf.init(cfg, jax.random.key(0))
    batch = _batch(cfg)
    per_ex, _ = forward_ghost(cfg, params, batch, jnp.zeros((4,)),
                              with_norms=False)
    ref = tf.loss_fn(cfg, params, batch)
    np.testing.assert_allclose(float(jnp.mean(per_ex)), float(ref), rtol=1e-5)


@pytest.mark.parametrize("arch", DENSE_ARCHS)
@pytest.mark.parametrize("chunk", [None, 2])
def test_ghost_norms_and_grads_exact(arch, chunk):
    cfg = get_smoke_config(arch).replace(tie_embeddings=False)
    params = tf.init(cfg, jax.random.key(0))
    batch = _batch(cfg)

    def one_norm(ex):
        g = jax.grad(lambda p, e: tf.per_example_loss_fn(cfg, p, e))(params, ex)
        return dp_lib.global_l2_norm(g)

    true_norms = jax.vmap(one_norm)(batch)
    grads, _, norms = ghost_clipped_grad_sum(cfg, params, batch,
                                             clip_norm=0.5, chunk_size=chunk)
    np.testing.assert_allclose(np.asarray(true_norms), np.asarray(norms),
                               rtol=5e-5)
    g_ref, _ = dp_lib.per_example_clipped_grad_sum(
        lambda p, ex: tf.per_example_loss_fn(cfg, p, ex), params, batch,
        clip_norm=0.5, microbatch_size=2,
    )
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-6)


def test_ghost_with_remat_and_flash():
    cfg = get_smoke_config("nemotron-4-340b").replace(
        tie_embeddings=False, remat=True, use_flash=True,
    )
    params = tf.init(cfg, jax.random.key(0))
    batch = _batch(cfg)

    def one_norm(ex):
        g = jax.grad(lambda p, e: tf.per_example_loss_fn(
            cfg.replace(use_flash=False), p, e))(params, ex)
        return dp_lib.global_l2_norm(g)

    true_norms = jax.vmap(one_norm)(batch)
    _, _, norms = ghost_clipped_grad_sum(cfg, params, batch, clip_norm=1.0)
    np.testing.assert_allclose(np.asarray(true_norms), np.asarray(norms),
                               rtol=1e-4)


def test_ghost_rejects_unsupported_archs():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = tf.init(cfg, jax.random.key(0))
    with pytest.raises(AssertionError):
        forward_ghost(cfg, params, _batch(cfg), jnp.zeros((4,)))


@pytest.mark.parametrize(
    "s,causal,window,bk",
    [(100, True, None, 32), (256, True, 64, 128), (64, False, None, 48)],
)
def test_blocked_attention_matches_reference(s, causal, window, bk):
    k = jax.random.key(0)
    q = 0.5 * jax.random.normal(jax.random.fold_in(k, 1), (2, s, 4, 32))
    kk = 0.5 * jax.random.normal(jax.random.fold_in(k, 2), (2, s, 2, 32))
    v = jax.random.normal(jax.random.fold_in(k, 3), (2, s, 2, 32))
    mask = _causal_mask(s, s, 0, window) if causal else None
    ref = _sdpa(q, kk, v, mask)
    blk = _sdpa_blocked(q, kk, v, causal=causal, window=window, block_k=bk)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(blk), atol=5e-6)


def test_blocked_attention_grads_flow():
    k = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(k, 1), (1, 64, 4, 16))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, 64, 2, 16))
    g = jax.grad(lambda q_: jnp.sum(_sdpa_blocked(q_, kk, v) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_rwkv_quadratic_matches_states_impl():
    from repro.models import transformer as tf_

    cfg_s = get_smoke_config("rwkv6-3b")
    cfg_q = cfg_s.replace(rwkv_chunk_impl="quadratic", rwkv_chunk=8)
    params = tf_.init(cfg_s, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 29), 0, cfg_s.vocab_size)
    l_s, _ = tf_.forward(cfg_s, params, {"tokens": toks})
    l_q, _ = tf_.forward(cfg_q, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_q), atol=5e-5)
