"""repro.scenarios: spec round-trips, sweep cache semantics, grids, churn,
the fedprox satellite arm, and the scaling-law report layer."""

import json
import logging

import numpy as np
import pytest

import repro.arms as arms
from repro.scenarios import (
    ResultCache,
    ScenarioSpec,
    SweepGrid,
    all_presets,
    fit_power_law,
    get_preset,
    get_sweep,
    markdown_report,
    run_spec,
    run_sweep,
    scaling_laws,
)
from repro.sim import LinkSchedule, Topology, nodes_from_trace

# -- ScenarioSpec -------------------------------------------------------------


def test_spec_json_roundtrip_is_identity():
    spec = get_preset("gemini-5hospital-churn")
    back = ScenarioSpec.from_json(spec.to_json())
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()
    # a second decode of the re-encoded form is stable too
    assert ScenarioSpec.from_json(back.to_json()) == spec


def test_spec_hash_excludes_labels_but_covers_semantics():
    spec = ScenarioSpec(name="a", tags=("x",))
    relabeled = spec.replace(name="b", tags=("y", "z"))
    assert relabeled.spec_hash() == spec.spec_hash()
    for field, value in (("seed", 7), ("hospitals", 3), ("arm", "fl"),
                         ("noise_multiplier", 1.3), ("backend", "ideal"),
                         ("topology", {"kind": "ring"})):
        assert spec.replace(**{field: value}).spec_hash() != spec.spec_hash()


def test_spec_validation_rejects_bad_values():
    with pytest.raises(ValueError, match="task"):
        ScenarioSpec(task="mri")
    with pytest.raises(ValueError, match="backend"):
        ScenarioSpec(backend="cloud")
    with pytest.raises(ValueError, match="hospitals"):
        ScenarioSpec(hospitals=0)
    with pytest.raises(ValueError, match="straggler_ratio"):
        ScenarioSpec(straggler_ratio=1.5)
    with pytest.raises(ValueError, match="nodes trace"):
        ScenarioSpec(hospitals=3, nodes=[{"throughput": 10.0}] * 2)
    with pytest.raises(ValueError, match="unknown ScenarioSpec fields"):
        ScenarioSpec.from_dict({"task": "gemini", "bogus": 1})


def test_preset_library_covers_paper_case_studies():
    catalogue = all_presets()
    for task in ("gemini", "pancreas", "xray"):
        for size in ("small", "medium", "full"):
            assert f"{task}-{size}" in catalogue
    assert catalogue["gemini-full"].features is None  # task default: 436
    with pytest.raises(KeyError, match="unknown preset"):
        get_preset("nope")


# -- SweepGrid ----------------------------------------------------------------


def test_sweep_grid_expands_axis_product():
    grid = SweepGrid(
        "t", ScenarioSpec(name="base", tags=("base",)),
        {"arm": ["fl", "decaph"], "hospitals": [3, 5, 7]},
    )
    specs = grid.specs()
    assert len(specs) == grid.size() == 6
    assert {(s.arm, s.hospitals) for s in specs} == {
        (a, h) for a in ("fl", "decaph") for h in (3, 5, 7)
    }
    # names are self-describing and unique; sweep tag is appended
    assert len({s.name for s in specs}) == 6
    assert all("sweep:t" in s.tags and "base" in s.tags for s in specs)


def test_sweep_grid_rejects_unknown_axis():
    with pytest.raises(ValueError, match="unknown spec fields"):
        SweepGrid("t", ScenarioSpec(), {"bogus_axis": [1]})


def test_named_sweeps_enumerate_live_arm_registry():
    mini = get_sweep("capacity-mini")
    assert set(mini.axes["arm"]) == set(arms.names())  # fedprox included
    assert mini.size() >= 12


# -- result cache -------------------------------------------------------------


def _fake_result(spec, **overrides):
    out = {
        "name": spec.name, "key": spec.spec_hash(), "task": spec.task,
        "arm": spec.arm, "backend": spec.backend,
        "hospitals": spec.hospitals, "model_size": spec.model_size,
        "model_params": 9, "rounds_completed": spec.rounds,
        "epsilon": 1.0, "mean_loss": 0.5, "accuracy": 0.9,
        "wall_clock": 1.0, "bytes_on_wire": 100.0, "dropout_events": 0,
        "recoveries": 0, "lost_rounds": 0, "events": 10,
        "host_seconds": 0.01,
    }
    out.update(overrides)
    return out


def test_cache_hit_skips_executor_and_changed_spec_misses(tmp_path):
    cache = ResultCache(tmp_path)
    spec = ScenarioSpec(name="cell", arm="fl", rounds=2)
    calls = []

    def counting_runner(s):
        calls.append(s.spec_hash())
        return _fake_result(s)

    first = run_sweep([spec], cache, runner=counting_runner)
    assert (first.hits, first.misses) == (0, 1) and len(calls) == 1

    again = run_sweep([spec], cache, runner=counting_runner)
    assert (again.hits, again.misses) == (1, 0)
    assert len(calls) == 1  # executor NOT invoked twice for the same spec
    assert again.results[0] == first.results[0]

    # a changed seed is a different cell: miss, executor runs
    reseeded = spec.replace(seed=99)
    third = run_sweep([spec, reseeded], cache, runner=counting_runner)
    assert (third.hits, third.misses) == (1, 1)
    assert len(calls) == 2 and calls[-1] == reseeded.spec_hash()


def test_cache_corrupted_entry_recomputed_with_warning(tmp_path, caplog):
    cache = ResultCache(tmp_path)
    spec = ScenarioSpec(name="cell", arm="fl", rounds=2)
    cache.put(spec, _fake_result(spec))
    cache.path(spec).write_text("{ not json")

    calls = []

    def counting_runner(s):
        calls.append(s.name)
        return _fake_result(s)

    with caplog.at_level(logging.WARNING, logger="repro.scenarios.cache"):
        outcome = run_sweep([spec], cache, runner=counting_runner)
    assert outcome.misses == 1 and calls == ["cell"]
    assert any("corrupted cache entry" in r.message for r in caplog.records)
    # the recompute repaired the entry
    assert cache.get(spec) is not None


def test_cache_rejects_key_mismatch_and_missing_fields(tmp_path, caplog):
    cache = ResultCache(tmp_path)
    spec = ScenarioSpec(name="cell", arm="fl")
    # entry whose key does not match the spec hash (stale/foreign file)
    cache.path(spec).write_text(json.dumps(
        {"schema": 1, "key": "deadbeef", "spec": {},
         "result": _fake_result(spec)}
    ))
    with caplog.at_level(logging.WARNING, logger="repro.scenarios.cache"):
        assert cache.get(spec) is None
    assert not cache.path(spec).exists()  # evicted
    # entry with a valid key but gutted result payload
    entry = {"schema": 1, "key": spec.spec_hash(), "spec": spec.to_dict(),
             "result": {"arm": "fl"}}
    cache.path(spec).write_text(json.dumps(entry))
    assert cache.get(spec) is None


# -- end-to-end: a real (tiny) sweep through the cache ------------------------


def test_mini_sweep_end_to_end_cached(tmp_path):
    specs = SweepGrid(
        "e2e",
        ScenarioSpec(task="gemini", model_size="small", features=6,
                     examples=160, rounds=2, batch_size=24, backend="sim",
                     use_secagg=False),
        {"arm": ["fl"], "hospitals": [3, 4]},
    ).specs()
    cache = ResultCache(tmp_path)
    first = run_sweep(specs, cache, jobs=1)
    assert (first.hits, first.misses) == (0, 2)
    for cell in first.results:
        assert cell["rounds_completed"] == 2
        assert cell["wall_clock"] > 0 and cell["bytes_on_wire"] > 0
        assert 0.0 <= cell["accuracy"] <= 1.0

    second = run_sweep(specs, cache, jobs=1)
    assert (second.hits, second.misses) == (2, 0)
    assert second.results == first.results

    laws = scaling_laws(first.results)
    assert "fl" in laws["bytes_vs_hospitals"]
    md = markdown_report("e2e", first.results, laws)
    assert "| fl |" in md and "Bytes on wire vs cohort size" in md


@pytest.mark.slow
def test_pool_sweep_caches_survivors_when_one_cell_fails(tmp_path):
    """Process-pool path: a failing cell raises AFTER sibling results are
    cached, so the re-run resumes from every cell that succeeded."""
    good = ScenarioSpec(name="good", task="gemini", model_size="small",
                        features=6, examples=160, rounds=2, batch_size=24,
                        backend="sim", use_secagg=False, arm="fl")
    bad = good.replace(name="bad", arm="no-such-arm")  # fails in the worker
    cache = ResultCache(tmp_path)
    with pytest.raises(KeyError, match="no-such-arm"):
        run_sweep([bad, good], cache, jobs=2)
    assert cache.get(good) is not None      # survivor was persisted
    assert cache.get(bad) is None
    resumed = run_sweep([good], cache, jobs=2)
    assert (resumed.hits, resumed.misses) == (1, 0)


def test_run_spec_executes_preset_on_ideal_backend():
    spec = get_preset("gemini-small").replace(
        backend="ideal", features=6, examples=160, rounds=2, batch_size=24,
        hospitals=3, use_secagg=False, arm="fl",
    )
    cell = run_spec(spec)
    assert cell["rounds_completed"] == 2
    assert cell["wall_clock"] == 0.0  # idealized: no systems story
    assert cell["model_params"] == 7  # w[6] + b


# -- LinkSchedule churn (satellite) ------------------------------------------


def test_link_schedule_from_trace_and_advance():
    topo = Topology.from_trace({
        "n": 3, "kind": "full",
        "default": {"bandwidth": 1e6, "latency": 0.01},
        "schedule": [
            {"t": 1.0, "link": "0-2", "bandwidth": 1e3, "latency": 0.5},
            {"t": 2.0, "link": "0-2", "down": True},
            {"t": 5.0, "link": "0-2", "bandwidth": 1e6, "latency": 0.01},
        ],
    })
    assert topo.transfer_time(0, 2, 1e3) == pytest.approx(0.011)
    assert topo.advance_to(1.0) == 1          # degrade fires
    assert topo.transfer_time(2, 0, 1e3) == pytest.approx(1.5)  # symmetric
    assert topo.advance_to(1.5) == 0          # idempotent between changes
    topo.advance_to(2.0)                      # edge removed
    assert not topo.has_edge(0, 2)
    assert topo.neighbors(0) == [1]
    topo.advance_to(10.0)                     # restored
    assert topo.has_edge(0, 2)
    assert topo.transfer_time(0, 2, 1e3) == pytest.approx(0.011)


def test_link_schedule_roundtrips_and_validates():
    sched = LinkSchedule.from_trace([
        {"t": 2.0, "link": "1-0", "down": True},
        {"t": 1.0, "link": "0-1", "bandwidth": 5.0, "latency": 0.1},
    ])
    assert [c.time for c in sched.changes] == [1.0, 2.0]  # time-sorted
    assert LinkSchedule.from_trace(sched.to_trace()).changes == sched.changes
    with pytest.raises(ValueError, match="schedule change on edge"):
        Topology.from_trace({
            "n": 2, "kind": "full",
            "schedule": [{"t": 1.0, "link": "0-5", "down": True}],
        })


def test_churn_severs_uploads_and_triggers_recovery():
    """Killing every link to one hospital mid-run behaves like a dropout:
    decaph keeps stepping via Shamir recovery, and restoring the links
    brings the hospital back into the rounds."""
    from repro.models.tabular import linear_model

    rng = np.random.default_rng(0)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    silos = []
    for i in range(4):
        x = rng.normal(0.1 * i, 1.0, (120, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, 120) > 0).astype(np.float32)
        silos.append(arms.Participant(x, y))
    model = linear_model(5)
    cfg = arms.ArmConfig(rounds=6, batch_size=32, lr=0.3, seed=0)
    nodes = nodes_from_trace([{"throughput": 200.0, "overhead": 0.02}] * 4)
    topo = Topology.from_trace({
        "n": 4, "kind": "full",
        "default": {"bandwidth": 1e5, "latency": 0.01},
        "schedule": [{"t": 0.5, "link": f"{i}-3", "down": True}
                     for i in range(3)],
    })
    rep = arms.run("decaph", model, silos, cfg, backend="sim",
                   nodes=nodes, topo=topo)
    assert rep.rounds_completed >= 4     # training survived the partition
    assert rep.recoveries >= 1           # severed upload recovered via Shamir


# -- fedprox (satellite) ------------------------------------------------------


def test_fedprox_registered_and_learns_on_both_backends():
    assert "fedprox" in arms.names()
    cls = arms.get("fedprox")
    assert cls.mode == "round" and cls.topology_kind == "star"

    rng = np.random.default_rng(1)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    silos = []
    for i in range(4):  # heterogeneous silos: fedprox's home turf
        x = rng.normal(0.3 * i, 1.0, (110, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, 110) > 0).astype(np.float32)
        silos.append(arms.Participant(x, y))
    from repro.models.tabular import linear_model, pooled_accuracy

    model = linear_model(5)
    cfg = arms.ArmConfig(rounds=6, batch_size=32, lr=0.3, seed=0,
                         use_secagg=False, fedprox_mu=0.1)
    rep = arms.run("fedprox", model, silos, cfg)
    assert rep.rounds_completed == 6
    assert pooled_accuracy(model, rep.params, silos) > 0.75
    # mu=0 with one pass matches plain FedAvg's trajectory shape (sanity:
    # the proximal term actually changes the update when mu > 0)
    rep0 = arms.run("fedprox", model, silos,
                    arms.ArmConfig(rounds=6, batch_size=32, lr=0.3, seed=0,
                                   use_secagg=False, fedprox_mu=0.0))
    la = np.asarray(rep.params["w"])
    lb = np.asarray(rep0.params["w"])
    assert not np.array_equal(la, lb)


# -- report layer -------------------------------------------------------------


def test_fit_power_law_recovers_known_exponent():
    xs = [3, 5, 10, 20]
    ys = [2.0 * x**1.5 for x in xs]
    fit = fit_power_law(xs, ys)
    assert fit["exponent"] == pytest.approx(1.5, abs=1e-9)
    assert fit["coefficient"] == pytest.approx(2.0, rel=1e-9)
    assert fit["r2"] == pytest.approx(1.0)
    assert fit_power_law([3, 3], [1.0, 2.0]) is None   # one distinct x
    assert fit_power_law([1, 2], [0.0, 1.0]) is None   # non-positive y
