"""Vectorized SecAgg vs the frozen per-leaf loop reference.

The vectorized path (one batched PRG call per round, flat field vectors,
sign-convention scatter) changes every pad *value* but not a single
aggregate *bit*: mask cancellation is exact in Z_2^32 either way, so the
sum of uploads equals the sum of encoded plaintexts exactly in both
implementations.  These tests pin that contract against the vendored
pre-refactor loops in ``tests/_legacy_secagg.py``, plus field round-trip
properties for ``_encode``/``_decode`` and the exact integer sum path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.secagg import (
    DropoutRobustSession,
    SecAggConfig,
    SecAggSession,
    secure_sum,
    secure_sum_ints,
    secure_sum_with_dropouts,
    _decode,
    _encode,
)

from _legacy_secagg import (
    LegacySecAggSession,
    legacy_secure_sum,
    legacy_secure_sum_with_dropouts,
)


def _trees(rng, n, dims=(7, 3)):
    return [
        {"w": jnp.asarray(rng.normal(0, 3, dims[0]).astype(np.float32)),
         "b": {"c": jnp.asarray(rng.normal(0, 1, dims[1]).astype(np.float32))}}
        for _ in range(n)
    ]


# -- masks cancel + aggregates bit-identical to the legacy loops -------------


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 6), dim=st.integers(1, 17), seed=st.integers(0, 500))
def test_vectorized_masks_cancel_exactly(n, dim, seed):
    cfg = SecAggConfig(n, frac_bits=16, seed=seed)
    session = SecAggSession(cfg, {"w": jnp.zeros((dim,))})
    with np.errstate(over="ignore"):
        total = sum(
            np.asarray(session.mask_for(i)[0], dtype=np.uint64)
            for i in range(n)
        ) % (1 << 32)
    assert (total == 0).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 500))
def test_secure_sum_bit_identical_to_legacy_loop(n, seed):
    rng = np.random.default_rng(seed)
    vals = _trees(rng, n)
    cfg = SecAggConfig(n, frac_bits=16, seed=seed)
    new = secure_sum(vals, cfg)
    old = legacy_secure_sum(vals, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(new),
                    jax.tree_util.tree_leaves(old)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_empty_and_scalar_leaves_round_trip():
    """Zero-size leaves contribute 0 field elements and scalars 1 — the
    flat vector and the mask rows must agree on both."""
    tree = {"w": jnp.zeros((0,)), "b": jnp.asarray(1.25),
            "v": jnp.asarray([0.5, -0.5])}
    out = secure_sum([tree, tree, tree], SecAggConfig(3, seed=5))
    assert np.shape(out["w"]) == (0,)
    np.testing.assert_allclose(float(out["b"]), 3.75, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["v"]), [1.5, -1.5], atol=1e-4)
    out2 = secure_sum_with_dropouts(
        [tree, tree, None], SecAggConfig(3, seed=5), threshold=2
    )
    np.testing.assert_allclose(float(out2["b"]), 2.5, atol=1e-4)


def test_ciphertexts_differ_but_sums_agree():
    """The pads changed (one generation per pair, flat derivation) — a
    sanity check that this test file isn't comparing identical bytes."""
    cfg = SecAggConfig(3, frac_bits=16, seed=11)
    tmpl = {"w": jnp.zeros((16,))}
    x = {"w": jnp.ones((16,))}
    new_up = SecAggSession(cfg, tmpl).upload(0, x)[0]
    old_up = LegacySecAggSession(cfg, tmpl).upload(0, x)[0]
    assert not np.array_equal(new_up, old_up)


@pytest.mark.parametrize("dropped", [set(), {2}, {0, 4}, {1, 2}])
def test_dropout_aggregate_bit_identical_to_legacy_loop(dropped):
    rng = np.random.default_rng(3)
    n = 5
    vals = _trees(rng, n)
    cfg = SecAggConfig(n, frac_bits=16, seed=7)
    slots = [None if i in dropped else vals[i] for i in range(n)]
    new = secure_sum_with_dropouts(slots, cfg, threshold=3)
    old = legacy_secure_sum_with_dropouts(slots, cfg, threshold=3)
    for a, b in zip(jax.tree_util.tree_leaves(new),
                    jax.tree_util.tree_leaves(old)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dropout_recovery_pads_regenerate_from_secret():
    """Upload-side pads and recovery-side pads must come from the same
    seed-keyed derivation: with a dropout, the recovered aggregate equals
    the survivors' plain sum to fixed-point exactness."""
    rng = np.random.default_rng(4)
    n = 4
    vals = _trees(rng, n)
    cfg = SecAggConfig(n, frac_bits=16, seed=9)
    session = DropoutRobustSession(cfg, vals[0], threshold=2)
    uploads = {i: session.upload(i, vals[i]) for i in range(n) if i != 1}
    out = session.aggregate(uploads)
    expected = sum(
        np.concatenate([np.asarray(v["w"]), np.asarray(v["b"]["c"])])
        for i, v in enumerate(vals) if i != 1
    )
    got = np.concatenate([np.asarray(out["w"]), np.asarray(out["b"]["c"])])
    np.testing.assert_allclose(got, expected, atol=n * 2**-15)


# -- field encode/decode round-trips -----------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    x=st.floats(min_value=-30000.0, max_value=30000.0,
                allow_nan=False, allow_infinity=False),
    frac_bits=st.integers(0, 20),
)
def test_encode_decode_round_trip(x, frac_bits):
    """decode(encode(x)) is x rounded to the fixed-point grid (exactly),
    for every value whose quantisation fits the field's signed half."""
    cfg = SecAggConfig(2, frac_bits=frac_bits)
    q = np.round(np.float64(np.float32(x)) * cfg.scale)
    if abs(q) >= 2**31:
        return  # out of field range: wraps by design
    got = _decode(_encode(np.float32(x), cfg), cfg)
    want = np.float32(q / cfg.scale)
    assert got == want


@settings(max_examples=30, deadline=None)
@given(v=st.integers(0, 2**24), frac_bits=st.integers(0, 6))
def test_integers_on_the_grid_survive_exactly(v, frac_bits):
    """Integers round-trip the float fixed-point path exactly only up to
    float32's 2^24 mantissa limit — the reason ``secure_sum_ints`` exists:
    the field itself is exact to 2^31, the float decode is not."""
    cfg = SecAggConfig(2, frac_bits=frac_bits)
    if v * cfg.scale >= 2**31:  # representable range shrinks with frac bits
        return
    assert float(_decode(_encode(float(v), cfg), cfg)) == float(v)


def test_float_path_loses_big_integers_but_int_path_does_not():
    """Above 2^24 the old float round-trip quantises; the integer field
    sum stays exact — the sum_sizes bugfix, demonstrated."""
    v = 366_390_673  # < 2^31, not representable in float32
    cfg = SecAggConfig(2, frac_bits=0)
    assert float(_decode(_encode(float(v), cfg), cfg)) != float(v)
    assert secure_sum_ints([v, 17], n_participants=2, seed=0) == v + 17


# -- exact integer sums (the sum_sizes bugfix) -------------------------------


@settings(max_examples=30, deadline=None)
@given(
    sizes=st.lists(st.integers(0, 1 << 27), min_size=1, max_size=12),
    seed=st.integers(0, 100),
)
def test_secure_sum_ints_is_exact(sizes, seed):
    got = secure_sum_ints(sizes, n_participants=len(sizes), seed=seed)
    assert got == sum(sizes)


def test_secure_sum_ints_validates():
    with pytest.raises(ValueError, match="participants"):
        secure_sum_ints([1, 2], n_participants=3)
    with pytest.raises(ValueError, match="negative"):
        secure_sum_ints([-1], n_participants=1)
    with pytest.raises(ValueError, match="overflow"):
        secure_sum_ints([1 << 31], n_participants=1)
    assert secure_sum_ints([5], n_participants=1, seed=3) == 5  # no pairs
