"""Numeric SPMD equivalence (subprocess: needs 8 placeholder devices).

Proves the sharded programs compute the SAME VALUES as single-device
execution — the dry-run proves lowering; this proves semantics:

  * DeCaPH train step (per-example clip + noise) on a (4,2) mesh == the
    same step on 1 device (same rng),
  * ghost train step mesh == single-device,
  * decode with the KV-cache *sequence* sharded over data (the long_500k
    layout) == unsharded decode.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.core import dp as dp_lib
from repro.core.ghost import ghost_clipped_grad_sum
from repro.launch import sharding as sh
from repro.models import transformer as tf
from repro.models.layers import activation_sharding

mesh = jax.make_mesh((4, 2), ("data", "model"))
policy = sh.ShardingPolicy()
results = {}

# ---- DeCaPH train-step gradient: mesh vs single device -------------------
cfg = get_smoke_config("smollm-360m").replace(d_ff=256)
params = tf.init(cfg, jax.random.key(0))
B, S = 8, 16
batch = {
    "tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size),
}

def clipped(p, b):
    g, loss = dp_lib.per_example_clipped_grad_sum(
        lambda pp, ex: tf.per_example_loss_fn(cfg, pp, ex), p, b,
        clip_norm=0.5, microbatch_size=4,
    )
    return g, loss

g_single, loss_single = jax.jit(clipped)(params, batch)

pspecs = sh.param_specs(params, mesh, policy)
bspecs = sh.batch_specs(batch, mesh, policy)
params_sh = jax.device_put(params, pspecs)
batch_sh = jax.device_put(batch, bspecs)
rules = sh.activation_rules(mesh, policy, global_batch=B)

def clipped_mesh(p, b):
    with activation_sharding(rules):
        return clipped(p, b)

g_mesh, loss_mesh = jax.jit(clipped_mesh)(params_sh, batch_sh)
err = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(g_mesh)),
                    jax.tree_util.tree_leaves(jax.device_get(g_single)))
)
results["train_grad_err"] = err
results["train_loss_err"] = abs(float(loss_mesh) - float(loss_single))

# ---- ghost step: mesh vs single ------------------------------------------
cfg_g = get_smoke_config("olmo-1b").replace(tie_embeddings=False)
params_g = tf.init(cfg_g, jax.random.key(3))
gg_single, _, norms_single = jax.jit(
    lambda p, b: ghost_clipped_grad_sum(cfg_g, p, b, clip_norm=0.5)
)(params_g, batch)
pspecs_g = sh.param_specs(params_g, mesh, policy)
params_g_sh = jax.device_put(params_g, pspecs_g)

def ghost_mesh(p, b):
    with activation_sharding(rules):
        return ghost_clipped_grad_sum(cfg_g, p, b, clip_norm=0.5)

gg_mesh, _, norms_mesh = jax.jit(ghost_mesh)(params_g_sh, batch_sh)
results["ghost_norm_err"] = float(jnp.max(jnp.abs(norms_mesh - norms_single)))
results["ghost_grad_err"] = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(gg_mesh)),
                    jax.tree_util.tree_leaves(jax.device_get(gg_single)))
)

# ---- decode with sequence-sharded KV cache (long_500k layout) -------------
cfg_d = get_smoke_config("gemma-7b")
params_d = tf.init(cfg_d, jax.random.key(4))
b1 = 1
toks = jax.random.randint(jax.random.key(5), (b1, 12), 0, cfg_d.vocab_size)
cache = tf.init_cache(cfg_d, b1, 16)
logits_ref = None
for t in range(6):
    logits_ref, cache = tf.decode_step(cfg_d, params_d, cache,
                                       toks[:, t:t+1], jnp.asarray(t, jnp.int32))

cache_sh = tf.init_cache(cfg_d, b1, 16)
cspec = sh.cache_specs(jax.eval_shape(lambda: cache_sh), mesh, policy,
                       global_batch=b1)
cache_sh = jax.device_put(cache_sh, cspec)
params_d_sh = jax.device_put(params_d, sh.param_specs(params_d, mesh, policy))
rules_d = sh.activation_rules(mesh, policy, global_batch=b1, shard_kv_seq=True)

@jax.jit
def dstep(p, c, tok, i):
    with activation_sharding(rules_d):
        return tf.decode_step(cfg_d, p, c, tok, i)

logits_sh = None
for t in range(6):
    logits_sh, cache_sh = dstep(params_d_sh, cache_sh, toks[:, t:t+1],
                                jnp.asarray(t, jnp.int32))
results["decode_err"] = float(jnp.max(jnp.abs(
    jax.device_get(logits_sh) - jax.device_get(logits_ref)
)))
print("RESULT::" + json.dumps(results))
"""


@pytest.mark.slow
def test_spmd_numeric_equivalence():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][0]
    res = json.loads(line[len("RESULT::"):])
    assert res["train_grad_err"] < 2e-4, res
    assert res["train_loss_err"] < 1e-4, res
    assert res["ghost_norm_err"] < 2e-4, res
    assert res["ghost_grad_err"] < 2e-4, res
    assert res["decode_err"] < 2e-3, res
