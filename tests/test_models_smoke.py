"""Per-architecture smoke tests (REQUIRED): reduced variant of each assigned
family runs one forward and one DP train step on CPU, asserting output shapes
and finiteness; decode consistency for every mixer family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.core import dp as dp_lib
from repro.models import transformer as tf
from repro.optim import get_optimizer

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.key(key)
    batch = {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(k, 1), (b, s), 0,
                                     cfg.vocab_size),
    }
    if cfg.arch_type == "vlm":
        sv = 4
        batch["tokens"] = batch["tokens"][:, : s - sv]
        batch["labels"] = batch["labels"][:, : s - sv]
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, 2), (b, sv, cfg.d_model)
        )
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, :, None], (b, s, 3)
        ).astype(jnp.int32)
    if cfg.arch_type == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, 3), (b, cfg.n_audio_ctx, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.stack_layers() <= 2
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = tf.init(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = tf.forward(cfg, params, batch)
    b = batch["tokens"].shape[0]
    s_total = batch["tokens"].shape[1] + (
        batch["vision_embeds"].shape[1] if "vision_embeds" in batch else 0
    )
    assert logits.shape == (b, s_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_dp_train_step(arch):
    """One full DeCaPH train step: per-example clip + noise + optimizer."""
    cfg = get_smoke_config(arch)
    params = tf.init(cfg, jax.random.key(1))
    batch = _batch(cfg, b=4, s=8)
    opt = get_optimizer(cfg.optimizer, 1e-3)
    opt_state = opt.init(params)
    g_sum, loss = dp_lib.per_example_clipped_grad_sum(
        lambda p, ex: tf.per_example_loss_fn(cfg, p, ex),
        params, batch, clip_norm=1.0, microbatch_size=2,
    )
    g_sum = dp_lib.tree_add_noise(
        g_sum, jax.random.key(2), clip_norm=1.0, noise_multiplier=0.5
    )
    grads = jax.tree_util.tree_map(lambda x: x / 4.0, g_sum)
    new_params, _ = opt.update(grads, opt_state, params)
    assert bool(jnp.isfinite(loss))
    # params changed and stayed finite
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params
    )
    assert any(jax.tree_util.tree_leaves(changed))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize(
    "arch", ["smollm-360m", "deepseek-v3-671b", "rwkv6-3b",
             "jamba-v0.1-52b", "whisper-small", "qwen3-moe-30b-a3b",
             "gemma-7b", "olmo-1b"]
)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))  # no drops
    params = tf.init(cfg, jax.random.key(3))
    b, s = 2, 10
    toks = jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.arch_type == "audio":
        batch["frames"] = 0.05 * jax.random.normal(
            jax.random.key(5), (b, cfg.n_audio_ctx, cfg.d_model)
        )
    logits_full, _ = tf.forward(cfg, params, batch)
    cache = tf.init_cache(cfg, b, s)
    if cfg.arch_type == "audio":
        from repro.models import attention as attn_lib

        enc = tf._encode(cfg, params, batch["frames"])
        cache["group0"]["e0"]["cross"] = jax.vmap(
            lambda lp: attn_lib.cross_kv_cache(lp["e0"]["cross"], enc, cfg)
        )(params["group0"])
    errs = []
    for t in range(s):
        lg, cache = tf.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t]))))
    assert max(errs) < 5e-4, f"{arch}: decode diverges {max(errs)}"


def test_sliding_window_changes_logits():
    cfg = get_smoke_config("smollm-360m")
    params = tf.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab_size)
    full, _ = tf.forward(cfg, params, {"tokens": toks})
    windowed, _ = tf.forward(cfg.replace(sliding_window=4), params,
                             {"tokens": toks})
    # early positions identical (window not binding), late ones differ
    np.testing.assert_allclose(np.asarray(full[:, 1]),
                               np.asarray(windowed[:, 1]), atol=1e-5)
    assert float(jnp.max(jnp.abs(full[:, -1] - windowed[:, -1]))) > 1e-4


def test_moe_capacity_drops_tokens():
    cfg = get_smoke_config("qwen3-moe-30b-a3b").replace(capacity_factor=0.25)
    params = tf.init(cfg, jax.random.key(0))
    batch = _batch(cfg, b=2, s=16)
    logits, aux = tf.forward(cfg, params, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))  # dropped tokens still finite
    assert float(aux) > 0  # load-balance loss reports imbalance
