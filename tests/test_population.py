"""Trace-then-solve cross-device engine (``repro.population``, DESIGN.md §10).

Pinned contracts:

1. **Sampler honesty** — the empirical sampled fraction over many rounds
   matches the exact ``q`` the arm hands its RDP accountant (the ε story
   depends on simulation and accounting using the same number).
2. **Trace determinism** — the trace phase is byte-identical for a fixed
   seed, including under link churn and flaky nodes, and round-trips
   through the content-addressed JSON encoding.
3. **q=1 bit-identity** — under full participation and ideal conditions
   the population backend reproduces the ``ideal`` backend bit for bit
   (the backend sits outside every ``bit_exact_group`` because it is
   fused-only, so the promise is pinned here instead of by the
   registry-driven equivalence suite).
4. **Capability gate** — ``participation_rate < 1`` is refused by any
   backend without ``supports_subsampling`` (running every hospital while
   composing ε at the subsampled rate would understate privacy loss).
5. **Noise top-up** — losing ``m`` of ``n`` distributed-noise shares
   mid-round triggers a conservative re-scaling back to full calibration.
"""

import json

import jax
import numpy as np
import pytest

import repro.arms as arms
from repro.arms import backends as backends_lib
from repro.core import dp as dp_lib
from repro.population import CohortSampler, ComputeGraph, PopulationSpec
from repro.population.backend import PopulationRunner
from repro.population.trace import run_trace
from repro.sim import Topology, nodes_from_trace

from test_arms_equivalence import _cfg, _make_model, _silos

H = 4


# -- spec + topology ---------------------------------------------------------


def test_population_spec_roundtrip_and_validation():
    spec = PopulationSpec(hospitals=64, seed=3, topology="small_world",
                          degree=6, flaky_fraction=0.1)
    again = PopulationSpec.from_dict(spec.to_dict())
    assert again == spec
    with pytest.raises((TypeError, ValueError)):
        PopulationSpec.from_dict({"hospitals": 8, "bogus_knob": 1})
    with pytest.raises(ValueError):
        PopulationSpec(hospitals=8, topology="torus").validate()


def test_build_nodes_deterministic_and_heterogeneous():
    spec = PopulationSpec(hospitals=200, seed=7, flaky_fraction=0.1)
    a, b = spec.build_nodes(), spec.build_nodes()
    assert a == b
    thr = [n["throughput"] for n in a]
    assert min(thr) < spec.throughput_median < max(thr)  # lognormal spread
    flaky = [n for n in a if n.get("dropouts")]
    assert 0 < len(flaky) <= int(round(0.1 * 200)) + 1


def test_small_world_topology_deterministic():
    def adjacency(t):
        return [t.neighbors(i) for i in range(50)]

    a = Topology.small_world(50, 6, 0.2, seed=1)
    assert adjacency(a) == adjacency(Topology.small_world(50, 6, 0.2, seed=1))
    assert adjacency(a) != adjacency(Topology.small_world(50, 6, 0.2, seed=2))
    # every node keeps degree >= 1 after rewiring (connectivity floor)
    assert all(a.neighbors(i) for i in range(50))


# -- cohort sampler ----------------------------------------------------------


def test_sampler_empirical_rate_matches_accountant_q():
    """The fraction actually sampled over many rounds converges on the q
    handed to the RDP accountant — same number, by construction."""
    q = 0.1
    sampler = CohortSampler(h=100, q=q, seed=0)
    for t in range(500):
        sampler.cohort(t)
    assert sampler.empirical_rate() == pytest.approx(q, rel=0.05)

    cfg = _cfg(participation_rate=q, rounds=3)
    arm = arms.get("decaph")(_make_model(5), _silos(), cfg)
    # the arm composes at rate * participation_rate (two-level caveat:
    # conservative upper bound, documented in population.sampler)
    assert arm.acct.sampling_rate == pytest.approx(arm.rate * q)


def test_sampler_is_pure_function_of_seed_and_round():
    a = CohortSampler(h=64, q=0.25, seed=9)
    b = CohortSampler(h=64, q=0.25, seed=9)
    assert [a.cohort(t) for t in (5, 2, 2)] == [b.cohort(t) for t in (5, 2, 2)]
    full = CohortSampler(h=8, q=1.0, seed=0)
    assert full.cohort(0) == list(range(8))  # q=1: no randomness consumed


# -- trace phase -------------------------------------------------------------


def _churny_trace(h=50, seed=11):
    spec = PopulationSpec(hospitals=h, seed=seed, topology="small_world",
                          degree=6, flaky_fraction=0.2, mean_uptime=30.0,
                          mean_downtime=5.0, churn_rate=0.01)
    nodes = nodes_from_trace(spec.build_nodes())
    topo = Topology.from_trace(spec.build_topology())
    return run_trace(nodes, topo, rounds=6, q=0.3, seed=seed,
                     sizes=[32] * h, model_bytes=4096, secure=True,
                     quorum=3, require=None,
                     facilitator=lambda t, cohort: cohort[t % len(cohort)])


def test_trace_byte_identical_for_fixed_seed():
    a, b = _churny_trace(), _churny_trace()
    blob = a.graph.to_json_bytes()
    assert blob == b.graph.to_json_bytes()
    assert a.graph.graph_hash() == b.graph.graph_hash()
    assert _churny_trace(seed=12).graph.graph_hash() != a.graph.graph_hash()


def test_trace_graph_roundtrip_and_waves_topological():
    trace = _churny_trace()
    again = ComputeGraph.from_json_bytes(trace.graph.to_json_bytes())
    assert again.to_json_bytes() == trace.graph.to_json_bytes()
    seen = set()
    for wave in trace.graph.waves():
        for node in wave:
            assert set(node.deps) <= seen  # deps live in earlier waves
        seen.update(node.id for node in wave)
    assert len(seen) == len(trace.graph.nodes)


def test_trace_content_hash_detects_tampering():
    trace = _churny_trace()
    payload = json.loads(trace.graph.to_json_bytes())
    payload["nodes"][0]["t_end"] += 1.0
    with pytest.raises(ValueError, match="content hash"):
        ComputeGraph.from_json_bytes(json.dumps(payload).encode())


def test_trace_samples_at_q_and_charges_wire_bytes():
    trace = _churny_trace()
    assert trace.empirical_q == pytest.approx(0.3, abs=0.12)
    assert trace.bytes_on_wire > 0 and trace.wall_clock > 0
    done = [p for p in trace.rounds if not p.lost]
    assert done and all(p.delivered for p in done)


# -- q=1 bit-identity with the ideal backend ---------------------------------


@pytest.mark.parametrize("arm_name", ["decaph", "fl"])
def test_population_matches_ideal_bit_for_bit_at_q1(arm_name):
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg(use_secagg=False)
    kind = arms.get(arm_name).topology_kind
    topo = Topology.star(H, 0) if kind == "star" else Topology.full(H)

    ref = arms.run(arm_name, model, silos, cfg, backend="ideal")
    pop = arms.run(arm_name, model, silos, cfg, backend="population",
                   topo=topo)

    assert pop.rounds_completed == ref.rounds_completed
    assert pop.epsilon == ref.epsilon
    for x, y in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(pop.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_population_subsampled_run_reports_cohorts():
    silos = _silos(sizes=(120,) * 6)
    model = _make_model(5)
    cfg = _cfg(participation_rate=0.5, rounds=6, use_secagg=False)
    arm = arms.get("decaph")(model, silos, cfg)
    runner = PopulationRunner(topo=Topology.full(6))
    rep = runner.run(arm)
    sr = runner.last_solve
    assert rep.rounds_completed >= 1 and rep.epsilon > 0
    assert 0.0 < sr.empirical_q <= 1.0
    assert sr.mean_cohort < 6  # subsampling actually shrank cohorts
    assert sr.wall_seconds > 0 and sr.simulated_seconds > 0


# -- capability gate ---------------------------------------------------------


@pytest.mark.parametrize("backend", ["ideal", "sim"])
def test_subsampling_refused_without_capability(backend):
    cfg = _cfg(participation_rate=0.5)
    err = backends_lib.compatibility_error(
        arms.get("decaph"), backends_lib.backend_registry()[backend],
        use_secagg=False, participation_rate=cfg.participation_rate)
    assert err is not None and "participation_rate" in err
    with pytest.raises(ValueError, match="participation_rate"):
        arms.run("decaph", _make_model(5), _silos(), cfg, backend=backend)


def test_population_backend_registered_with_capabilities():
    info = backends_lib.backend_registry()["population"]
    assert info.supports_subsampling and info.fused_only
    assert info.supports_sim_time and not info.supports_secagg
    assert info.bit_exact_group == ""  # pinned by the q=1 test instead


# -- noise top-up on lost SecAgg shares --------------------------------------


def test_tree_topup_noise_variance_and_validation():
    template = {"w": np.zeros(20000, np.float32), "b": np.zeros((), np.float32)}
    key = jax.random.key(0)
    top = dp_lib.tree_topup_noise(template, key, clip_norm=1.0,
                                  noise_multiplier=2.0, missing=3, n_shares=4)
    # std must be C*sigma*sqrt(m/n): the survivors' shares already carry
    # (n-m)/n of the calibrated variance
    want = 1.0 * 2.0 * np.sqrt(3 / 4)
    assert np.std(np.asarray(top["w"])) == pytest.approx(want, rel=0.05)
    with pytest.raises(ValueError):
        dp_lib.tree_topup_noise(template, key, clip_norm=1.0,
                                noise_multiplier=2.0, missing=5, n_shares=4)
    with pytest.raises(ValueError):
        dp_lib.tree_topup_noise(template, key, clip_norm=1.0,
                                noise_multiplier=2.0, missing=0, n_shares=4)


def test_sim_mid_round_dropout_triggers_noise_topup():
    """A DeCaPH share lost mid-round is compensated: SimTiming counts the
    top-up and the run still completes with full-calibration noise."""
    from repro.sim import heterogeneous_trace

    silos = _silos(sizes=(120,) * 5)
    model = _make_model(5)
    trace = heterogeneous_trace(5)
    trace[2]["dropouts"] = [[0.2, None]]  # drops mid-run, never returns
    rep = arms.run("decaph", model, silos, _cfg(rounds=8), backend="sim",
                   nodes=nodes_from_trace(trace), topo=Topology.full(5))
    assert rep.dropout_events == 1
    assert rep.noise_topups >= 1
    assert rep.rounds_completed >= 6
