"""Arm registry, the gossip-dp satellite arm, the poisson-pad fix, the CLI."""

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.arms as arms
from repro.arms.base import poisson_batch
from repro.core.dp import DPConfig
from repro.sim import Topology, nodes_from_trace


def _make_model(d):
    def init_fn(key):
        return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss(params, ex):
        logit = ex["x"] @ params["w"] + params["b"]
        y = ex["y"]
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def predict(params, x):
        return jax.nn.sigmoid(x @ params["w"] + params["b"])

    return arms.Model(init_fn, loss, predict)


def _silos(seed=0, sizes=(150, 110, 90, 70)):
    rng = np.random.default_rng(seed)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    out = []
    for i, n in enumerate(sizes):
        x = rng.normal(0.1 * i, 1.0, (n, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, n) > 0).astype(np.float32)
        out.append(arms.Participant(x, y))
    return out


def _acc(model, params, silos):
    x = np.concatenate([p.x for p in silos])
    y = np.concatenate([p.y for p in silos])
    return ((np.asarray(model.predict_fn(params, jnp.asarray(x))) > 0.5)
            == y).mean()


# -- registry ----------------------------------------------------------------


def test_registry_contains_every_arm_once():
    expected = {"decaph", "fl", "primia", "local", "gossip", "gossip-dp"}
    assert expected <= set(arms.names())
    cls = arms.get("decaph")
    assert cls.name == "decaph" and cls.mode == "round"
    assert arms.get("gossip-dp").mode == "node"


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(KeyError, match="registered arms"):
        arms.get("does-not-exist")
    with pytest.raises(ValueError, match="already registered"):

        @arms.register("decaph")
        class Impostor(arms.RoundArm):  # pragma: no cover - never runs
            pass


def test_runner_rejects_mismatched_nodes():
    silos = _silos()
    model = _make_model(5)
    with pytest.raises(ValueError, match="one HospitalNode per participant"):
        arms.run("fl", model, silos, arms.ArmConfig(rounds=2),
                 backend="sim",
                 nodes=nodes_from_trace([{"throughput": 100.0}] * 2),
                 topo=Topology.star(2))


# -- gossip-dp: the ROADMAP arm, <100 lines, both backends for free ----------


def _dp_cfg(**kw):
    base = dict(
        rounds=8, batch_size=40, lr=0.4, seed=0,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.7, microbatch_size=8),
    )
    base.update(kw)
    return arms.ArmConfig(**base)


def test_gossip_dp_learns_and_accounts_on_both_backends():
    silos = _silos()
    model = _make_model(5)
    cfg = _dp_cfg()
    ideal = arms.run("gossip-dp", model, silos, cfg)
    assert ideal.epsilon > 0
    assert ideal.rounds_completed == 8
    assert _acc(model, ideal.params, silos) > 0.75
    simmed = arms.run(
        "gossip-dp", model, silos, cfg, backend="sim",
        nodes=nodes_from_trace(
            [{"throughput": 200.0, "overhead": 0.02}] * 4),
        topo=Topology.ring(4),
    )
    assert simmed.epsilon > 0
    assert simmed.timing is not None and simmed.timing.bytes_on_wire > 0
    assert _acc(model, simmed.params, silos) > 0.75


def test_gossip_dp_budget_retires_nodes():
    """A tiny per-node budget stops local steps early (local-DP semantics)."""
    silos = _silos()
    model = _make_model(5)
    res = arms.run("gossip-dp", model, silos,
                   _dp_cfg(rounds=30, epsilon_budget=1.0))
    assert res.rounds_completed < 30  # budget exhausted before the horizon
    assert res.epsilon <= 1.0 + 1e-6  # never overshoots the local budget


# -- poisson_batch: no silent truncation -------------------------------------


def test_poisson_batch_grows_pad_instead_of_truncating(caplog):
    """A draw larger than the pad must keep every selected example (silent
    truncation would bias sampling and void the subsampled-RDP analysis)."""
    part = arms.Participant(
        np.arange(64, dtype=np.float32).reshape(64, 1),
        np.ones((64,), np.float32),
    )
    rng = np.random.default_rng(0)
    with caplog.at_level(logging.WARNING, logger="repro.arms.base"):
        batch, mask, k = poisson_batch(rng, part, rate=1.0, pad_to=16)
    assert k == 64                      # every selected example survived
    assert batch["x"].shape[0] == 64    # pad grew to the next power of two
    assert int(mask.sum()) == 64
    assert any("exceeded the padded batch" in r.message
               for r in caplog.records)


def test_poisson_batch_unchanged_when_pad_suffices():
    part = arms.Participant(
        np.arange(64, dtype=np.float32).reshape(64, 1),
        np.ones((64,), np.float32),
    )
    b1, m1, k1 = poisson_batch(np.random.default_rng(7), part, 0.25, 32)
    assert b1["x"].shape[0] == 32 and k1 == int(m1.sum()) and k1 < 32


# -- CLI entry point ----------------------------------------------------------


def test_cli_list_and_single_run(capsys):
    from repro.run import main

    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in arms.names():
        assert name in out
    assert main(["--arm", "fl", "--backend", "sim", "--rounds", "2",
                 "--hospitals", "3", "--features", "6", "--examples", "120",
                 "--batch", "24"]) == 0
    out = capsys.readouterr().out
    assert "sim_wall" in out
