"""Arm/Backend acceptance: one set of numerics, two backends, one history.

1. Cross-backend equivalence — for every registered arm, the sim backend
   under an ideal trace (uniform nodes, effectively infinite bandwidth, zero
   latency, no dropouts) reproduces the idealized backend's losses/params.
2. Seed-for-seed shims — the deprecation shims in ``repro.core.federation``
   reproduce the pre-refactor results exactly, verified against a frozen
   snapshot of the historical loops (``tests/_legacy_federation.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.arms as arms
from repro.core.dp import DPConfig
from repro.sim import Link, Topology, nodes_from_trace

from _legacy_federation import (
    legacy_run_decaph,
    legacy_run_fl,
    legacy_run_primia,
)

H = 4
_IDEAL_LINK = Link(bandwidth=1e15, latency=0.0)


def _make_model(d):
    def init_fn(key):
        return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss(params, ex):
        logit = ex["x"] @ params["w"] + params["b"]
        y = ex["y"]
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def predict(params, x):
        return jax.nn.sigmoid(x @ params["w"] + params["b"])

    return arms.Model(init_fn, loss, predict)


def _silos(seed=0, sizes=(120,) * H):
    # equal silo sizes -> uniform per-step compute cost, so the ideal trace
    # really is lockstep for the node arms
    rng = np.random.default_rng(seed)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    out = []
    for i, n in enumerate(sizes):
        x = rng.normal(0.1 * i, 1.0, (n, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, n) > 0).astype(np.float32)
        out.append(arms.Participant(x, y))
    return out


def _cfg(**kw):
    base = dict(
        rounds=5, batch_size=32, lr=0.3, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.7, microbatch_size=8),
    )
    base.update(kw)
    return arms.ArmConfig(**base)


def _ideal_nodes(h=H):
    return nodes_from_trace(
        [{"throughput": 1000.0, "overhead": 0.01}] * h
    )


def _ideal_topology(kind: str, h=H) -> Topology:
    if kind == "star":
        return Topology.star(h, 0, _IDEAL_LINK)
    if kind == "ring":
        return Topology.ring(h, _IDEAL_LINK)
    return Topology.full(h, _IDEAL_LINK)


def _assert_trees_close(a, b, atol=0.0):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=0.0, atol=atol
        )


# -- 1. cross-backend equivalence -------------------------------------------


@pytest.mark.parametrize("arm_name", arms.names())
def test_sim_matches_ideal_under_ideal_trace(arm_name):
    """SimRunner on an ideal trace == LocalRunner, for every registered arm."""
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg()
    topo = _ideal_topology(arms.get(arm_name).topology_kind)

    ideal = arms.run(arm_name, model, silos, cfg, topo=topo)
    simmed = arms.run(arm_name, model, silos, cfg, backend="sim",
                      nodes=_ideal_nodes(), topo=topo)

    assert ideal.rounds_completed == simmed.rounds_completed
    _assert_trees_close(ideal.params, simmed.params)
    if ideal.per_node_params is not None:
        assert simmed.per_node_params is not None
        for a, b in zip(ideal.per_node_params, simmed.per_node_params):
            _assert_trees_close(a, b)
    # losses agree wherever both backends log them (round arms)
    if ideal.logs and simmed.logs:
        np.testing.assert_allclose(
            [l.loss for l in ideal.logs], [l.loss for l in simmed.logs],
            rtol=0.0, atol=0.0,
        )
    assert ideal.epsilon == pytest.approx(simmed.epsilon, abs=1e-9)
    # the sim side additionally carries the systems story
    assert simmed.timing is not None and ideal.timing is None
    assert simmed.timing.wall_clock > 0


def test_sim_backend_honors_epsilon_budget():
    """Both backends pre-cap rounds via planned_rounds(): the sim side must
    not overshoot the operator's budget by a round before noticing."""
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg(rounds=40, epsilon_budget=3.0)
    ideal = arms.run("decaph", model, silos, cfg)
    simmed = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=_ideal_nodes(), topo=_ideal_topology("full"))
    assert ideal.rounds_completed == simmed.rounds_completed
    assert simmed.epsilon <= 3.0 + 1e-9
    _assert_trees_close(ideal.params, simmed.params)


def test_decaph_secagg_cross_backend_within_fixed_point():
    """With SecAgg on, the backends use different sessions (idealized
    honest-but-curious vs dropout-robust), so they agree only up to the
    fixed-point quantisation of each round's sum."""
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg(use_secagg=True)
    ideal = arms.run("decaph", model, silos, cfg)
    simmed = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=_ideal_nodes(), topo=_ideal_topology("full"))
    assert ideal.rounds_completed == simmed.rounds_completed
    _assert_trees_close(ideal.params, simmed.params, atol=5e-3)


# -- 2. shims reproduce pre-refactor results seed-for-seed -------------------


@pytest.mark.parametrize("use_secagg", [False, True])
def test_run_decaph_shim_seed_for_seed(use_secagg):
    from repro.core.federation import run_decaph

    silos = _silos(sizes=(180, 120, 90))
    model = _make_model(5)
    cfg = _cfg(rounds=6, use_secagg=use_secagg, epsilon_budget=8.0)
    new = run_decaph(model, silos, cfg)
    params, n_logged, losses, eps = legacy_run_decaph(model, silos, cfg)
    _assert_trees_close(new.params, params)
    assert new.rounds_completed == n_logged
    np.testing.assert_allclose(
        [l.loss for l in new.logs if np.isfinite(l.loss)], losses,
        rtol=0.0, atol=0.0,
    )
    assert new.epsilon == pytest.approx(eps, abs=1e-12)


@pytest.mark.parametrize("local_steps", [1, 3])
def test_run_fl_shim_seed_for_seed(local_steps):
    from repro.core.federation import run_fl

    silos = _silos(sizes=(180, 120, 90))
    model = _make_model(5)
    cfg = _cfg(rounds=6, fl_local_steps=local_steps)
    new = run_fl(model, silos, cfg)
    params, n_logged = legacy_run_fl(model, silos, cfg)
    _assert_trees_close(new.params, params)
    assert new.rounds_completed == n_logged
    assert new.epsilon == 0.0


def test_run_primia_shim_seed_for_seed():
    from repro.core.federation import run_primia

    # unequal silos: the small clients exhaust their local budgets first
    silos = _silos(sizes=(300, 60, 60))
    model = _make_model(5)
    cfg = _cfg(rounds=20, epsilon_budget=2.0,
               dp=DPConfig(clip_norm=1.0, noise_multiplier=1.0,
                           microbatch_size=8))
    new = run_primia(model, silos, cfg)
    params, n_logged, eps = legacy_run_primia(model, silos, cfg)
    _assert_trees_close(new.params, params)
    assert new.rounds_completed == n_logged
    assert new.epsilon == pytest.approx(eps, abs=1e-12)
