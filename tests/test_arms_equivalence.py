"""Arm/Backend acceptance: one set of numerics, N backends, one history.

1. Cross-backend equivalence — driven by the registry's ``bit_exact_group``
   capability (DESIGN.md §8), not a hardcoded backend pair: for every
   registered arm and every pair of backends sharing a group, running under
   ideal conditions (uniform nodes, effectively infinite bandwidth, zero
   latency, no dropouts) must reproduce losses/params bit for bit.  A
   backend in its own group (e.g. ``shard``, whose partitioned reductions
   re-associate float math) is exercised to a documented tolerance in
   ``tests/test_backends.py`` instead.
2. Seed-for-seed shims — the deprecation shims in ``repro.core.federation``
   reproduce the pre-refactor results exactly, verified against a frozen
   snapshot of the historical loops (``tests/_legacy_federation.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.arms as arms
from repro.arms import backends as backends_lib
from repro.core.dp import DPConfig
from repro.sim import Link, Topology, nodes_from_trace

from _legacy_federation import (
    legacy_run_decaph,
    legacy_run_fl,
    legacy_run_primia,
)

H = 4
_IDEAL_LINK = Link(bandwidth=1e15, latency=0.0)


def _runnable_group_pairs() -> list[tuple[str, str]]:
    """(reference, other) backend pairs promised bit-identical by their
    shared ``bit_exact_group``, restricted to backends this process can
    run (``shard`` needs forced host devices and its own subprocess)."""
    pairs = []
    for _group, names in backends_lib.bit_exact_groups().items():
        ready = [n for n in names if backends_lib.availability(n) is None]
        pairs += [(ready[0], other) for other in ready[1:]]
    return pairs


def _make_model(d):
    def init_fn(key):
        return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss(params, ex):
        logit = ex["x"] @ params["w"] + params["b"]
        y = ex["y"]
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def predict(params, x):
        return jax.nn.sigmoid(x @ params["w"] + params["b"])

    return arms.Model(init_fn, loss, predict)


def _silos(seed=0, sizes=(120,) * H):
    # equal silo sizes -> uniform per-step compute cost, so the ideal trace
    # really is lockstep for the node arms
    rng = np.random.default_rng(seed)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    out = []
    for i, n in enumerate(sizes):
        x = rng.normal(0.1 * i, 1.0, (n, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, n) > 0).astype(np.float32)
        out.append(arms.Participant(x, y))
    return out


def _cfg(**kw):
    base = dict(
        rounds=5, batch_size=32, lr=0.3, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.7, microbatch_size=8),
    )
    base.update(kw)
    return arms.ArmConfig(**base)


def _ideal_nodes(h=H):
    return nodes_from_trace(
        [{"throughput": 1000.0, "overhead": 0.01}] * h
    )


def _ideal_topology(kind: str, h=H) -> Topology:
    if kind == "star":
        return Topology.star(h, 0, _IDEAL_LINK)
    if kind == "ring":
        return Topology.ring(h, _IDEAL_LINK)
    return Topology.full(h, _IDEAL_LINK)


def _assert_trees_close(a, b, atol=0.0):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=0.0, atol=atol
        )


# -- 1. cross-backend equivalence -------------------------------------------


def _run_backend(backend_name, arm_name, model, silos, cfg, topo):
    """Run on a registry backend under ideal conditions (capability-aware:
    sim-time backends get uniform nodes + the ideal-link topology)."""
    info = backends_lib.get_backend(backend_name).info
    nodes = _ideal_nodes() if info.supports_sim_time else None
    return arms.run(arm_name, model, silos, cfg, backend=backend_name,
                    nodes=nodes, topo=topo)


@pytest.mark.parametrize("pair", _runnable_group_pairs(),
                         ids=lambda p: f"{p[0]}=={p[1]}")
@pytest.mark.parametrize("arm_name", arms.names())
def test_bit_exact_groups_agree_under_ideal_trace(arm_name, pair):
    """Backends sharing a ``bit_exact_group`` reproduce each other bit for
    bit, for every registered arm, under an ideal trace."""
    ref_name, other_name = pair
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg()
    topo = _ideal_topology(arms.get(arm_name).topology_kind)

    ref = _run_backend(ref_name, arm_name, model, silos, cfg, topo)
    other = _run_backend(other_name, arm_name, model, silos, cfg,
                         _ideal_topology(arms.get(arm_name).topology_kind))

    assert ref.rounds_completed == other.rounds_completed
    _assert_trees_close(ref.params, other.params)
    if ref.per_node_params is not None:
        assert other.per_node_params is not None
        for a, b in zip(ref.per_node_params, other.per_node_params):
            _assert_trees_close(a, b)
    # losses agree wherever both backends log them (round arms)
    if ref.logs and other.logs:
        np.testing.assert_allclose(
            [l.loss for l in ref.logs], [l.loss for l in other.logs],
            rtol=0.0, atol=0.0,
        )
    assert ref.epsilon == pytest.approx(other.epsilon, abs=1e-9)


def test_registry_pairs_cover_the_ideal_sim_promise():
    """The host group must keep pairing the idealized and discrete-event
    backends — losing it would silently drop the PR-2 acceptance test."""
    assert ("ideal", "sim") in _runnable_group_pairs()


def test_sim_carries_the_systems_story():
    """Only sim-time backends produce a SimTiming section."""
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg()
    ideal = arms.run("decaph", model, silos, cfg)
    simmed = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=_ideal_nodes(), topo=_ideal_topology("full"))
    assert simmed.timing is not None and ideal.timing is None
    assert simmed.timing.wall_clock > 0


def test_sim_backend_honors_epsilon_budget():
    """Both backends pre-cap rounds via planned_rounds(): the sim side must
    not overshoot the operator's budget by a round before noticing."""
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg(rounds=40, epsilon_budget=3.0)
    ideal = arms.run("decaph", model, silos, cfg)
    simmed = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=_ideal_nodes(), topo=_ideal_topology("full"))
    assert ideal.rounds_completed == simmed.rounds_completed
    assert simmed.epsilon <= 3.0 + 1e-9
    _assert_trees_close(ideal.params, simmed.params)


def test_decaph_secagg_cross_backend_within_fixed_point():
    """With SecAgg on, the backends use different sessions (idealized
    honest-but-curious vs dropout-robust), so they agree only up to the
    fixed-point quantisation of each round's sum."""
    silos = _silos()
    model = _make_model(5)
    cfg = _cfg(use_secagg=True)
    ideal = arms.run("decaph", model, silos, cfg)
    simmed = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=_ideal_nodes(), topo=_ideal_topology("full"))
    assert ideal.rounds_completed == simmed.rounds_completed
    _assert_trees_close(ideal.params, simmed.params, atol=5e-3)


# -- 2. shims reproduce pre-refactor results seed-for-seed -------------------


@pytest.mark.parametrize("use_secagg", [False, True])
def test_run_decaph_shim_seed_for_seed(use_secagg):
    from repro.core.federation import run_decaph

    silos = _silos(sizes=(180, 120, 90))
    model = _make_model(5)
    cfg = _cfg(rounds=6, use_secagg=use_secagg, epsilon_budget=8.0)
    new = run_decaph(model, silos, cfg)
    params, n_logged, losses, eps = legacy_run_decaph(model, silos, cfg)
    _assert_trees_close(new.params, params)
    assert new.rounds_completed == n_logged
    np.testing.assert_allclose(
        [l.loss for l in new.logs if np.isfinite(l.loss)], losses,
        rtol=0.0, atol=0.0,
    )
    assert new.epsilon == pytest.approx(eps, abs=1e-12)


@pytest.mark.parametrize("local_steps", [1, 3])
def test_run_fl_shim_seed_for_seed(local_steps):
    from repro.core.federation import run_fl

    silos = _silos(sizes=(180, 120, 90))
    model = _make_model(5)
    cfg = _cfg(rounds=6, fl_local_steps=local_steps)
    new = run_fl(model, silos, cfg)
    params, n_logged = legacy_run_fl(model, silos, cfg)
    _assert_trees_close(new.params, params)
    assert new.rounds_completed == n_logged
    assert new.epsilon == 0.0


def test_run_primia_shim_seed_for_seed():
    from repro.core.federation import run_primia

    # unequal silos: the small clients exhaust their local budgets first
    silos = _silos(sizes=(300, 60, 60))
    model = _make_model(5)
    cfg = _cfg(rounds=20, epsilon_budget=2.0,
               dp=DPConfig(clip_norm=1.0, noise_multiplier=1.0,
                           microbatch_size=8))
    new = run_primia(model, silos, cfg)
    params, n_logged, eps = legacy_run_primia(model, silos, cfg)
    _assert_trees_close(new.params, params)
    assert new.rounds_completed == n_logged
    assert new.epsilon == pytest.approx(eps, abs=1e-12)
