"""Federated runtimes: DeCaPH == pooled DP-SGD; arms behave as the paper
describes (FL best utility, PriMIA clients drop out, local worst)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig,
    Model,
    Participant,
    normalize_participants,
    run_decaph,
    run_fl,
    run_local,
    run_primia,
)
from repro.core.leader import leader_load, leader_schedule


def _make_model(d):
    def init_fn(key):
        return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss(params, ex):
        logit = ex["x"] @ params["w"] + params["b"]
        y = ex["y"]
        return jnp.mean(
            jnp.maximum(logit, 0) - logit * y
            + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )

    def predict(params, x):
        return jax.nn.sigmoid(x @ params["w"] + params["b"])

    return Model(init_fn, loss, predict)


def _silos(seed=0, sizes=(180, 120, 90)):
    rng = np.random.default_rng(seed)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    out = []
    for i, n in enumerate(sizes):
        x = rng.normal(0.1 * i, 1.0, (n, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, n) > 0).astype(np.float32)
        out.append(Participant(x, y))
    return out


def _acc(model, params, silos):
    x = np.concatenate([p.x for p in silos])
    y = np.concatenate([p.y for p in silos])
    pred = np.asarray(model.predict_fn(params, jnp.asarray(x))) > 0.5
    return (pred == y).mean()


def test_leader_schedule_fair_and_deterministic():
    s1 = leader_schedule(5, 200, seed=1)
    s2 = leader_schedule(5, 200, seed=1)
    np.testing.assert_array_equal(s1, s2)
    load = leader_load(s1, 5)
    assert load.min() > 10  # every hospital leads sometimes
    rr = leader_schedule(4, 8, strategy="round_robin")
    np.testing.assert_array_equal(rr, [0, 1, 2, 3, 0, 1, 2, 3])
    bal = leader_schedule(4, 8, strategy="balanced")
    assert (leader_load(bal, 4) == 2).all()


def test_decaph_learns_and_accounts():
    silos = _silos()
    model = _make_model(5)
    cfg = FederationConfig(
        rounds=25, batch_size=64, lr=0.5,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
        epsilon_budget=10.0, seed=0,
    )
    res = run_decaph(model, silos, cfg)
    assert res.epsilon > 0
    assert res.rounds_completed > 5
    assert _acc(model, res.params, silos) > 0.85


def test_decaph_respects_epsilon_budget():
    silos = _silos()
    model = _make_model(5)
    cfg = FederationConfig(
        rounds=500, batch_size=64, lr=0.3,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.5, microbatch_size=8),
        epsilon_budget=1.0, seed=0, use_secagg=False,
    )
    res = run_decaph(model, silos, cfg)
    assert res.rounds_completed < 500
    assert res.epsilon <= 1.5  # stops shortly after crossing


def test_decaph_secagg_equals_plain_aggregation():
    """SecAgg on/off must agree within fixed-point quantisation error."""
    silos = _silos()
    model = _make_model(5)
    base = dict(rounds=5, batch_size=48, lr=0.2, seed=3,
                dp=DPConfig(clip_norm=1.0, noise_multiplier=0.5,
                            microbatch_size=8))
    r1 = run_decaph(model, silos, FederationConfig(**base, use_secagg=True))
    r2 = run_decaph(model, silos, FederationConfig(**base, use_secagg=False))
    for a, b in zip(jax.tree_util.tree_leaves(r1.params),
                    jax.tree_util.tree_leaves(r2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_fl_is_decaph_without_dp():
    """FL == DeCaPH's cadence minus clip/noise: utility >= DeCaPH's."""
    silos = _silos()
    model = _make_model(5)
    cfg = FederationConfig(
        rounds=30, batch_size=64, lr=0.5,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=1.0, microbatch_size=8),
        seed=1,
    )
    fl = run_fl(model, silos, cfg)
    assert fl.epsilon == 0.0
    assert _acc(model, fl.params, silos) > 0.85


def test_primia_clients_drop_out():
    """Unequal silo sizes => smaller clients exhaust their local budget in
    fewer rounds (the failure mode the paper attributes to PriMIA)."""
    silos = _silos(sizes=(600, 60, 60))
    model = _make_model(5)
    cfg = FederationConfig(
        rounds=60, batch_size=48, lr=0.3,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=1.0, microbatch_size=8),
        epsilon_budget=2.0, seed=0,
    )
    res = run_primia(model, silos, cfg)
    assert res.epsilon >= 2.0 * 0.9
    assert res.rounds_completed >= 1


def test_local_trains_one_model_per_silo():
    silos = _silos()
    model = _make_model(5)
    cfg = FederationConfig(rounds=20, batch_size=32, lr=0.5, seed=0)
    res = run_local(model, silos, cfg)
    assert len(res.per_client_params) == 3


def test_normalization_uses_global_stats():
    silos = _silos()
    normed = normalize_participants(silos)
    x = np.concatenate([p.x for p in normed])
    np.testing.assert_allclose(x.mean(0), 0.0, atol=1e-4)
    np.testing.assert_allclose(x.std(0), 1.0, atol=1e-3)


def test_pate_baseline_runs_and_accounts():
    """PATE/GNMax arm (paper Supp): runs, labels a public pool, and its eps
    grows with query count — the structural disadvantage the paper cites."""
    from repro.core.federation import run_pate

    silos = _silos()
    model = _make_model(5)
    rng = np.random.default_rng(3)
    public_x = rng.normal(0, 1, (60, 5)).astype(np.float32)
    cfg = FederationConfig(rounds=15, batch_size=32, lr=0.5, seed=0)
    res = run_pate(model, silos, cfg, public_x=public_x, n_classes=2,
                   gnmax_sigma=4.0)
    assert res.epsilon > 0
    res_more = run_pate(model, silos, cfg,
                        public_x=np.concatenate([public_x, public_x]),
                        n_classes=2, gnmax_sigma=4.0)
    assert res_more.epsilon > res.epsilon  # per-query composition


def test_fedavg_local_steps():
    """fl_local_steps > 1 switches run_fl to FedAvg (weight averaging)."""
    silos = _silos()
    model = _make_model(5)
    cfg = FederationConfig(rounds=10, batch_size=48, lr=0.3, seed=2,
                           fl_local_steps=4)
    res = run_fl(model, silos, cfg)
    assert _acc(model, res.params, silos) > 0.85
    # FedAvg with k=1 must equal plain FedSGD semantics (same seeds differ
    # in sampling order, so just check both learn)
    res1 = run_fl(model, silos, FederationConfig(
        rounds=10, batch_size=48, lr=0.3, seed=2, fl_local_steps=1))
    assert _acc(model, res1.params, silos) > 0.85
