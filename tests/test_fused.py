"""The fused cohort round-step: loop-equivalence, dispatch counts, secagg.

The fused path vmaps the per-participant numerics, which re-associates
float math at the ulp level — so fused-vs-loop agreement is tested to a
tight-but-nonzero tolerance, while the *cross-backend* bit-exactness of the
fused path itself is covered by ``tests/test_arms_equivalence.py`` (both
backends run the same fused program).
"""

import dataclasses

import numpy as np
import pytest

import repro.arms as arms
from repro.arms import fused
from repro.core.dp import DPConfig

from test_arms_equivalence import _cfg, _make_model, _silos

ROUND_ARMS = ["decaph", "fl", "fedprox", "scaffold", "primia"]
FUSED_ARMS = ["decaph", "fl", "fedprox", "scaffold", "primia"]


def _run(arm, cfg):
    return arms.run(arm, _make_model(5), _silos(), cfg)


def _leaves_close(a, b, atol):
    import jax
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=0.0, atol=atol)


@pytest.mark.parametrize("arm_name", ROUND_ARMS)
def test_fused_matches_loop_path(arm_name):
    """Same draws, same schedule, same trajectory (to vmap re-association)."""
    cfg = _cfg(rounds=5)
    fused_rep = _run(arm_name, cfg)
    loop_rep = _run(arm_name, dataclasses.replace(cfg, fused_rounds=False))
    assert fused_rep.rounds_completed == loop_rep.rounds_completed
    _leaves_close(fused_rep.params, loop_rep.params, atol=1e-5)
    for a, b in zip(fused_rep.logs, loop_rep.logs):
        assert a.round == b.round and a.leader == b.leader
        assert a.aggregate_batch == b.aggregate_batch
        if np.isfinite(a.loss) or np.isfinite(b.loss):
            assert abs(a.loss - b.loss) < 1e-5
    assert fused_rep.epsilon == pytest.approx(loop_rep.epsilon, abs=1e-12)


@pytest.mark.parametrize("local_steps", [1, 3])
def test_fused_fl_fedavg_matches_loop(local_steps):
    cfg = _cfg(rounds=4, fl_local_steps=local_steps)
    fused_rep = _run("fl", cfg)
    loop_rep = _run("fl", dataclasses.replace(cfg, fused_rounds=False))
    _leaves_close(fused_rep.params, loop_rep.params, atol=1e-5)


def test_fused_primia_ragged_retirement_matches_loop():
    """primia's fused round pads the ragged per-client Poisson draws (each
    client has its own rate AND pad) to the cohort max, and keeps matching
    the loop path bit-for-bit on the accountants even as small clients
    exhaust their local budgets and the active cohort shrinks."""
    model = _make_model(5)
    # unequal silos: the small clients' higher sampling rates exhaust their
    # local budgets first (the legacy-shim retirement setup)
    silos = _silos(seed=3, sizes=(300, 60, 60))
    cfg = _cfg(rounds=20, epsilon_budget=2.0,
               dp=DPConfig(clip_norm=1.0, noise_multiplier=1.0,
                           microbatch_size=8))
    fused_rep = arms.run("primia", model, silos, cfg)
    loop_rep = arms.run("primia", model, silos,
                        dataclasses.replace(cfg, fused_rounds=False))
    assert fused_rep.rounds_completed == loop_rep.rounds_completed
    assert fused_rep.rounds_completed < 20  # retirement actually happened
    _leaves_close(fused_rep.params, loop_rep.params, atol=1e-5)
    assert fused_rep.epsilon == pytest.approx(loop_rep.epsilon, abs=1e-12)


def test_fused_decaph_secagg_matches_loop():
    """Under SecAgg the payloads differ at the ulp before encoding, so the
    field sums agree to one quantisation step per participant."""
    cfg = _cfg(rounds=4, use_secagg=True)
    fused_rep = _run("decaph", cfg)
    loop_rep = _run("decaph", dataclasses.replace(cfg, fused_rounds=False))
    _leaves_close(fused_rep.params, loop_rep.params, atol=1e-3)


@pytest.mark.parametrize("arm_name", FUSED_ARMS)
def test_fused_round_is_one_dispatch(arm_name):
    """The O(1)-dispatch contract: one cohort program launch per round."""
    cfg = _cfg(rounds=3)
    _run(arm_name, cfg)  # compile warmup for this config shape
    fused.reset_jit_dispatches()
    rep = _run(arm_name, cfg)
    assert rep.rounds_completed == 3
    assert fused.jit_dispatches() == 3  # exactly one per round
    fused.reset_jit_dispatches()
    loop = _run(arm_name, dataclasses.replace(cfg, fused_rounds=False))
    assert fused.jit_dispatches() >= loop.rounds_completed * 4  # O(H)


def test_fused_round_withheld_payloads_never_hit_the_wire():
    """With SecAgg off on the idealized backend, payloads stay on device:
    the per-participant Contribution carries None and the aggregate is
    served from the in-jit reduced sum."""
    captured = {}

    class Probe(arms.get("decaph")):
        def aggregate(self, params, contributions, services):
            captured["payloads"] = [c.payload for c in contributions.values()]
            return super().aggregate(params, contributions, services)

    cfg = _cfg(rounds=2)
    model, silos = _make_model(5), _silos()
    rep = arms.LocalRunner().run(Probe(model, silos, cfg))
    assert rep.rounds_completed == 2
    assert all(p is None for p in captured["payloads"])


def test_sim_backend_gets_real_payloads():
    """The sim backend ships each contribution over the wire, so the fused
    path must hand it real per-participant payload trees."""
    from repro.sim import Link, Topology, nodes_from_trace

    cfg = _cfg(rounds=2)
    model, silos = _make_model(5), _silos()
    rep = arms.run(
        "decaph", model, silos, cfg, backend="sim",
        nodes=nodes_from_trace([{"throughput": 1000.0, "overhead": 0.01}] * 4),
        topo=Topology.full(4, Link(bandwidth=1e15, latency=0.0)),
    )
    assert rep.rounds_completed == 2


def test_stack_poisson_consumes_rng_like_the_loop():
    """Identical draws in identical order — the fused-path contract."""
    from repro.arms.base import poisson_batch

    silos = _silos()
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    cb = fused.stack_poisson(rng_a, silos, [0, 1, 2, 3], 0.1, 32, steps=2)
    for s, i in enumerate([0, 1, 2, 3]):
        for k in range(2):
            b, m, n = poisson_batch(rng_b, silos[i], 0.1, 32)
            np.testing.assert_array_equal(cb.x[s, k], b["x"])
            np.testing.assert_array_equal(cb.masks[s, k], m)
            assert cb.counts[s, k] == n
    assert cb.sizes == [int(r.sum()) for r in cb.counts]


def test_stack_poisson_grows_pad_for_the_whole_cohort():
    """One oversized draw re-pads the round; masks keep the pad inert."""
    silos = _silos()
    rng = np.random.default_rng(0)
    cb = fused.stack_poisson(rng, silos, [0, 1], 0.9, 8)  # rate 0.9 >> pad 8
    assert cb.x.shape[1] >= 64  # grown to a power of two that fits
    assert (cb.masks.sum(axis=1) == np.asarray(cb.sizes)).all()


def test_scaffold_beats_fedavg_under_heterogeneity():
    """The control variates must actually correct client drift: on skewed
    silos SCAFFOLD's final loss should not be worse than plain FedAvg's."""
    model = _make_model(5)
    silos = _silos(seed=3, sizes=(200, 60, 40, 30))
    cfg = arms.ArmConfig(
        rounds=12, batch_size=32, lr=0.3, seed=0, use_secagg=False,
        fl_local_steps=4,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.7, microbatch_size=8),
    )
    from repro.models.tabular import pooled_accuracy

    fedavg = arms.run("fl", model, silos, cfg)
    scaffold = arms.run("scaffold", model, silos, cfg)
    acc_fedavg = pooled_accuracy(model, fedavg.params, silos)
    acc_scaffold = pooled_accuracy(model, scaffold.params, silos)
    assert acc_scaffold >= acc_fedavg - 0.05
