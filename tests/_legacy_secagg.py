"""Frozen snapshot of the pre-vectorization SecAgg mask loops.

This is the per-leaf, per-pair Python-loop implementation that
``repro.core.secagg`` shipped before the fused/vectorized hot path
(each unordered pair's pad generated twice — once with ``+`` by the lower
index, once with ``-`` by the higher — as O(H^2 * leaves) individual PRG
calls).  Kept verbatim as the reference the vectorized path is tested
against: mask cancellation is exact in the field, so the *aggregates* must
be bit-identical even though the pad values themselves differ.

Only the session internals are vendored; the field encoding and the Shamir
algebra are unchanged in the live module and imported from it.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.secagg import (
    SecAggConfig,
    _FIELD_DTYPE,
    _decode,
    _encode,
    _SHAMIR_PRIME,
    _DH_GENERATOR,
    shamir_share,
    shamir_reconstruct,
)

PyTree = Any


def _pair_key(base: jax.Array, i: int, j: int) -> jax.Array:
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(base, lo), hi)


def _prg_mask(key: jax.Array, shape: tuple[int, ...]) -> np.ndarray:
    return np.asarray(jax.random.bits(key, shape, dtype=jnp.uint32))


class LegacySecAggSession:
    """The historical honest-but-curious session, per-leaf loops."""

    def __init__(self, cfg: SecAggConfig, template: PyTree):
        self.cfg = cfg
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._base_key = jax.random.key(cfg.seed)

    def mask_for(self, i: int) -> list[np.ndarray]:
        masks = []
        for li, leaf in enumerate(self._leaves):
            key_leaf = jax.random.fold_in(self._base_key, 1000 + li)
            shape = tuple(np.shape(leaf))
            m = np.zeros(shape, _FIELD_DTYPE)
            with np.errstate(over="ignore"):
                for j in range(self.cfg.n_participants):
                    if j == i:
                        continue
                    pad = _prg_mask(_pair_key(key_leaf, i, j), shape)
                    m = (m + pad) if i < j else (m - pad)
            masks.append(m)
        return masks

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        leaves = jax.tree_util.tree_leaves(values)
        masks = self.mask_for(i)
        with np.errstate(over="ignore"):
            return [_encode(x, self.cfg) + m for x, m in zip(leaves, masks)]

    def aggregate(self, uploads: Sequence[list[np.ndarray]]) -> PyTree:
        total = [np.zeros(np.shape(x), _FIELD_DTYPE) for x in self._leaves]
        with np.errstate(over="ignore"):
            for up in uploads:
                total = [t + u for t, u in zip(total, up)]
        decoded = [jnp.asarray(_decode(t, self.cfg)) for t in total]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def legacy_secure_sum(values: Sequence[PyTree], cfg: SecAggConfig) -> PyTree:
    session = LegacySecAggSession(cfg, values[0])
    uploads = [session.upload(i, v) for i, v in enumerate(values)]
    return session.aggregate(uploads)


class LegacyDropoutRobustSession:
    """The historical dropout-robust session, per-leaf recovery loops."""

    def __init__(self, cfg: SecAggConfig, template: PyTree, *,
                 threshold: int | None = None):
        n = cfg.n_participants
        self.cfg = cfg
        self.threshold = threshold if threshold is not None else n // 2 + 1
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        rng = np.random.default_rng(np.uint64(cfg.seed) ^ np.uint64(0x5ECA66))
        self._secret_keys = [
            int(rng.integers(2, _SHAMIR_PRIME - 1)) for _ in range(n)
        ]
        self.public_keys = [
            pow(_DH_GENERATOR, u, _SHAMIR_PRIME) for u in self._secret_keys
        ]
        self._shares = [
            shamir_share(u, n, self.threshold, rng) for u in self._secret_keys
        ]

    def _pair_seed(self, holder: int, other: int) -> int:
        return pow(
            self.public_keys[other], self._secret_keys[holder], _SHAMIR_PRIME
        )

    @staticmethod
    def _pad_from_seed(seed: int, leaf_index: int,
                       shape: tuple[int, ...]) -> np.ndarray:
        key = jax.random.fold_in(
            jax.random.key(seed % ((1 << 63) - 1)), leaf_index
        )
        return _prg_mask(key, shape)

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        leaves = jax.tree_util.tree_leaves(values)
        out = []
        with np.errstate(over="ignore"):
            for li, leaf in enumerate(leaves):
                shape = tuple(np.shape(self._leaves[li]))
                v = _encode(leaf, self.cfg)
                for j in range(self.cfg.n_participants):
                    if j == i:
                        continue
                    pad = self._pad_from_seed(self._pair_seed(i, j), li, shape)
                    v = (v + pad) if i < j else (v - pad)
                out.append(v)
        return out

    def aggregate(self, uploads: dict[int, list[np.ndarray]]) -> PyTree:
        n = self.cfg.n_participants
        survivors = sorted(uploads)
        dropped = [d for d in range(n) if d not in uploads]
        total = [np.zeros(np.shape(x), _FIELD_DTYPE) for x in self._leaves]
        with np.errstate(over="ignore"):
            for s in survivors:
                total = [t + u for t, u in zip(total, uploads[s])]
            for d in dropped:
                shares = [self._shares[d][j]
                          for j in survivors[: self.threshold]]
                u_d = shamir_reconstruct(shares)
                for j in survivors:
                    seed = pow(self.public_keys[j], u_d, _SHAMIR_PRIME)
                    for li in range(len(total)):
                        pad = self._pad_from_seed(
                            seed, li, tuple(np.shape(self._leaves[li]))
                        )
                        total[li] = (
                            total[li] - pad if j < d else total[li] + pad
                        )
        decoded = [jnp.asarray(_decode(t, self.cfg)) for t in total]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def legacy_secure_sum_with_dropouts(
    values: Sequence[PyTree | None],
    cfg: SecAggConfig,
    *,
    threshold: int | None = None,
) -> PyTree:
    template = next(v for v in values if v is not None)
    session = LegacyDropoutRobustSession(cfg, template, threshold=threshold)
    uploads = {
        i: session.upload(i, v) for i, v in enumerate(values) if v is not None
    }
    return session.aggregate(uploads)
