"""Verbatim snapshot of the pre-refactor idealized runtimes (PR 1 state).

Test fixture only: the Arm/Backend redesign promises that the deprecation
shims in ``repro.core.federation`` reproduce the historical results
seed-for-seed, and the only honest way to regression-test that is against a
frozen copy of the historical loops.  Do NOT import this from library code —
the single source of truth for arm numerics is ``repro.arms``.

Copied from repro/core/federation.py @ 15d8ab4 (run_decaph / run_fl /
run_primia bodies, including the then-current truncating ``_poisson_batch``);
results are returned as plain tuples to avoid depending on the result type.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_lib
from repro.core.accountant import RDPAccountant, steps_for_epsilon
from repro.core.leader import leader_schedule
from repro.core.secagg import SecAggConfig, secure_sum


def _poisson_batch(rng, part, rate, pad_to):
    sel = rng.random(len(part)) < rate
    idx = np.nonzero(sel)[0]
    k = len(idx)
    if k > pad_to:
        idx = idx[:pad_to]
        k = pad_to
    xb = np.zeros((pad_to,) + part.x.shape[1:], part.x.dtype)
    yb = np.zeros((pad_to,) + part.y.shape[1:], part.y.dtype)
    xb[:k] = part.x[idx]
    yb[:k] = part.y[idx]
    mask = np.zeros((pad_to,), np.float32)
    mask[:k] = 1.0
    return {"x": xb, "y": yb}, mask, k


def _sgd_update(params, grads, lr, wd):
    return jax.tree_util.tree_map(
        lambda p, g: p - lr * (g + wd * p), params, grads
    )


def legacy_run_decaph(model, participants, cfg):
    """Pre-refactor run_decaph; returns (params, n_logged, losses, epsilon)."""
    h = len(participants)
    n_total = sum(len(p) for p in participants)
    rate = cfg.batch_size / n_total
    pad = cfg.max_pad_batch or max(8, int(rate * max(len(p) for p in participants) * 4))
    leaders = leader_schedule(
        h, cfg.rounds, seed=cfg.seed, strategy=cfg.leader_strategy
    )
    acct = RDPAccountant(
        sampling_rate=rate,
        noise_multiplier=cfg.dp.noise_multiplier,
        delta=cfg.dp.delta,
    )
    n_rounds = cfg.rounds
    if cfg.epsilon_budget is not None:
        n_rounds = min(
            cfg.rounds,
            steps_for_epsilon(rate, cfg.dp.noise_multiplier,
                              cfg.epsilon_budget, cfg.dp.delta,
                              max_steps=cfg.rounds + 1),
        )

    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)

    clipped_sum = jax.jit(
        lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
            model.loss_fn, p, b,
            clip_norm=cfg.dp.clip_norm,
            microbatch_size=min(cfg.dp.microbatch_size, pad),
            mask=m,
        )
    )

    round_losses = []
    n_logged = 0
    for t in range(n_rounds):
        leader = int(leaders[t])
        batches, masks, sizes = [], [], []
        for part in participants:
            b, m, k = _poisson_batch(rng, part, rate, pad)
            batches.append(b)
            masks.append(m)
            sizes.append(k)
        if cfg.use_secagg:
            agg_size = secure_sum(
                [jnp.asarray([float(s)]) for s in sizes],
                SecAggConfig(h, frac_bits=0, seed=cfg.seed * 7919 + t),
            )[0]
            agg_batch = int(round(float(agg_size)))
        else:
            agg_batch = int(sum(sizes))
        if agg_batch == 0:
            n_logged += 1
            continue
        shares, losses = [], []
        for i, (b, m) in enumerate(zip(batches, masks)):
            g_sum, loss = clipped_sum(params, b, jnp.asarray(m))
            nkey = jax.random.fold_in(jax.random.fold_in(key, 17 + t), i)
            g_noised = dp_lib.tree_add_noise(
                g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=h,
            )
            shares.append(g_noised)
            losses.append(float(loss))
        if cfg.use_secagg:
            total = secure_sum(
                shares, SecAggConfig(h, cfg.secagg_frac_bits, seed=cfg.seed + t)
            )
        else:
            total = jax.tree_util.tree_map(
                lambda *xs: sum(xs[1:], xs[0]), *shares
            )
        grad = jax.tree_util.tree_map(lambda x: x / agg_batch, total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        acct.step()
        n_logged += 1
        round_losses.append(float(np.mean(losses)))
        if cfg.epsilon_budget is not None and acct.exceeds(cfg.epsilon_budget):
            break
    return params, n_logged, round_losses, acct.epsilon()


def legacy_run_fl(model, participants, cfg):
    """Pre-refactor run_fl; returns (params, n_logged)."""
    h = len(participants)
    n_total = sum(len(p) for p in participants)
    rate = cfg.batch_size / n_total
    pad = cfg.max_pad_batch or max(8, int(rate * max(len(p) for p in participants) * 4))
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)

    def batch_grad(p, b, m):
        def masked_loss(pp):
            losses = jax.vmap(lambda ex: model.loss_fn(pp, ex))(b)
            return jnp.sum(losses * m)
        return jax.grad(masked_loss)(p)

    batch_grad = jax.jit(batch_grad)
    n_logged = 0
    for t in range(cfg.rounds):
        if cfg.fl_local_steps <= 1:  # FedSGD
            grads, sizes = [], []
            for part in participants:
                b, m, k = _poisson_batch(rng, part, rate, pad)
                grads.append(batch_grad(params, b, jnp.asarray(m)))
                sizes.append(k)
            agg = int(sum(sizes))
            if agg == 0:
                continue
            total = jax.tree_util.tree_map(
                lambda *xs: sum(xs[1:], xs[0]), *grads
            )
            grad = jax.tree_util.tree_map(lambda x: x / agg, total)
            params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        else:  # FedAvg: local epochs then size-weighted weight averaging
            client_params, weights = [], []
            for part in participants:
                local = params
                for _ in range(cfg.fl_local_steps):
                    b, m, k = _poisson_batch(rng, part, rate, pad)
                    if k == 0:
                        continue
                    g = batch_grad(local, b, jnp.asarray(m))
                    g = jax.tree_util.tree_map(lambda x: x / max(k, 1), g)
                    local = _sgd_update(local, g, cfg.lr, cfg.weight_decay)
                client_params.append(local)
                weights.append(len(part))
            wsum = float(sum(weights))
            params = jax.tree_util.tree_map(
                lambda *xs: sum(w / wsum * x for w, x in zip(weights, xs)),
                *client_params,
            )
        n_logged += 1
    return params, n_logged


def legacy_run_primia(model, participants, cfg):
    """Pre-refactor run_primia; returns (params, n_logged, epsilon)."""
    h = len(participants)
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)

    per_client_batch = max(1, cfg.batch_size // h)
    rates = [min(1.0, per_client_batch / max(len(p), 1)) for p in participants]
    pads = [cfg.max_pad_batch or max(8, int(r * len(p) * 4) or 8)
            for r, p in zip(rates, participants)]
    accts = [
        RDPAccountant(
            sampling_rate=r, noise_multiplier=cfg.dp.noise_multiplier,
            delta=cfg.dp.delta,
        )
        for r in rates
    ]
    budget = cfg.epsilon_budget or float("inf")
    if cfg.epsilon_budget is not None:
        max_rounds = [
            steps_for_epsilon(r, cfg.dp.noise_multiplier, budget, cfg.dp.delta,
                              max_steps=cfg.rounds + 1)
            for r in rates
        ]
    else:
        max_rounds = [cfg.rounds] * h

    clipped_sum = jax.jit(
        lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
            model.loss_fn, p, b,
            clip_norm=cfg.dp.clip_norm,
            microbatch_size=cfg.dp.microbatch_size,
            mask=m,
        ),
    )

    n_logged = 0
    for t in range(cfg.rounds):
        updates, sizes = [], []
        for i, part in enumerate(participants):
            if accts[i].steps >= max_rounds[i]:
                continue  # client's local budget exhausted -> drops out
            b, m, k = _poisson_batch(rng, part, rates[i], pads[i])
            g_sum, _ = clipped_sum(params, b, jnp.asarray(m))
            nkey = jax.random.fold_in(jax.random.fold_in(key, 31 + t), i)
            g = dp_lib.tree_add_noise(
                g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=1,
            )
            g = jax.tree_util.tree_map(lambda x: x / max(k, 1), g)
            updates.append(g)
            sizes.append(k)
            accts[i].step()
        if not updates:
            break
        total = jax.tree_util.tree_map(lambda *xs: sum(xs[1:], xs[0]), *updates)
        grad = jax.tree_util.tree_map(lambda x: x / len(updates), total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        n_logged += 1
    eps = max(a.epsilon() for a in accts)
    return params, n_logged, eps
