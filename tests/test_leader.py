"""leader_schedule: validity, determinism, long-run fairness per strategy."""

import numpy as np
import pytest

from repro.core.leader import leader_load, leader_schedule

STRATEGIES = ("uniform", "round_robin", "balanced")


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("n,rounds", [(1, 7), (3, 50), (8, 200)])
def test_all_strategies_produce_valid_indices(strategy, n, rounds):
    sched = leader_schedule(n, rounds, seed=5, strategy=strategy)
    assert sched.shape == (rounds,)
    assert sched.min() >= 0 and sched.max() < n
    assert np.issubdtype(sched.dtype, np.integer)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_determinism_under_fixed_seed(strategy):
    a = leader_schedule(6, 120, seed=42, strategy=strategy)
    b = leader_schedule(6, 120, seed=42, strategy=strategy)
    np.testing.assert_array_equal(a, b)


def test_uniform_seeds_differ():
    a = leader_schedule(6, 120, seed=0)
    b = leader_schedule(6, 120, seed=1)
    assert (a != b).any()


def test_round_robin_exact_rotation():
    sched = leader_schedule(4, 10, strategy="round_robin")
    np.testing.assert_array_equal(sched, [0, 1, 2, 3, 0, 1, 2, 3, 0, 1])
    # perfectly fair up to remainder
    load = leader_load(sched, 4)
    assert load.max() - load.min() <= 1


def test_balanced_is_exactly_fair_on_whole_permutations():
    sched = leader_schedule(5, 5 * 40, seed=3, strategy="balanced")
    assert (leader_load(sched, 5) == 40).all()
    # and within one of fair on partial permutations
    sched = leader_schedule(5, 5 * 40 + 3, seed=3, strategy="balanced")
    load = leader_load(sched, 5)
    assert load.max() - load.min() <= 1


def test_uniform_fairness_over_many_rounds():
    """i.i.d. uniform: every hospital leads close to rounds/n times."""
    n, rounds = 5, 5000
    load = leader_load(leader_schedule(n, rounds, seed=11), n)
    expected = rounds / n
    # 5-sigma binomial bound
    sigma = np.sqrt(rounds * (1 / n) * (1 - 1 / n))
    assert np.all(np.abs(load - expected) < 5 * sigma)


def test_rejects_bad_arguments():
    with pytest.raises(ValueError):
        leader_schedule(0, 10)
    with pytest.raises(ValueError):
        leader_schedule(3, -1)
    with pytest.raises(ValueError):
        leader_schedule(3, 10, strategy="no_such_strategy")


def test_zero_rounds_edge_case():
    for strategy in STRATEGIES:
        sched = leader_schedule(4, 0, strategy=strategy)
        assert sched.shape == (0,)
