"""Discrete-event simulator: engine semantics, topologies, protocol arms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp import DPConfig
from repro.core.federation import Model, Participant
from repro.sim import (
    ComputeDone,
    EventEngine,
    NodeDropout,
    NodeRejoin,
    SimConfig,
    Topology,
    TransferDone,
    heterogeneous_trace,
    nodes_from_trace,
    scenario_from_trace,
    simulate_decaph,
    simulate_fl,
    simulate_gossip,
    simulate_local,
    simulate_primia,
)

# -- engine -----------------------------------------------------------------


def test_engine_pops_in_time_order_fifo_ties():
    eng = EventEngine()
    eng.schedule(2.0, ComputeDone(0, tag="late"))
    eng.schedule(1.0, ComputeDone(1, tag="early"))
    eng.schedule(1.0, ComputeDone(2, tag="early2"))  # same time: FIFO
    order = [ev.node for ev in eng.drain()]
    assert order == [1, 2, 0]
    assert eng.now == 2.0


def test_engine_cancel_and_negative_delay():
    eng = EventEngine()
    h = eng.schedule(1.0, ComputeDone(0))
    eng.schedule(2.0, ComputeDone(1))
    eng.cancel(h)
    assert [ev.node for ev in eng.drain()] == [1]
    with pytest.raises(ValueError):
        eng.schedule(-0.1, ComputeDone(0))
    with pytest.raises(ValueError):
        eng.schedule_at(eng.now - 1.0, ComputeDone(0))


def test_engine_run_until_and_pending_kinds():
    eng = EventEngine()
    eng.schedule(1.0, NodeDropout(0))
    eng.schedule(5.0, NodeRejoin(0))
    seen = []
    n = eng.run(seen.append, until=2.0)
    assert n == 1 and isinstance(seen[0], NodeDropout)
    assert eng.now == 2.0  # clock advanced to the horizon
    assert eng.pending_kinds() == {NodeRejoin}


# -- topology ---------------------------------------------------------------


def test_topology_builders_shapes():
    star = Topology.star(5, center=0)
    assert star.degree(0) == 4 and all(star.degree(j) == 1 for j in range(1, 5))
    ring = Topology.ring(6)
    assert all(ring.degree(i) == 2 for i in range(6))
    reg = Topology.k_regular(6, 4)
    assert all(reg.degree(i) == 4 for i in range(6))
    full = Topology.full(4)
    assert all(full.degree(i) == 3 for i in range(4))
    with pytest.raises(ValueError):
        Topology.k_regular(5, 3)  # odd degree on odd n is impossible


def test_transfer_time_and_missing_link():
    topo = Topology.from_trace({
        "n": 3, "kind": "star", "center": 0,
        "default": {"bandwidth": 1e6, "latency": 0.5},
        "links": {"0-2": {"bandwidth": 2e6, "latency": 0.25}},
    })
    assert topo.transfer_time(0, 1, 1e6) == pytest.approx(1.5)
    assert topo.transfer_time(2, 0, 1e6) == pytest.approx(0.75)  # override
    with pytest.raises(ValueError):
        topo.transfer_time(1, 2, 100.0)  # leaves don't talk directly


def test_nodes_from_trace_validates():
    nodes = nodes_from_trace(heterogeneous_trace(4))
    assert len(nodes) == 4
    assert nodes[0].throughput > nodes[3].throughput  # straggler is last
    assert nodes[1].compute_time(100) > nodes[0].compute_time(100)
    with pytest.raises(ValueError):
        nodes_from_trace([{"throughput": 0.0}])
    with pytest.raises(ValueError):
        nodes_from_trace([{"throughput": 10.0, "dropouts": [[5.0, 1.0]]}])


# -- protocol arms ----------------------------------------------------------


def _make_model(d):
    def init_fn(key):
        return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss(params, ex):
        logit = ex["x"] @ params["w"] + params["b"]
        y = ex["y"]
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def predict(params, x):
        return jax.nn.sigmoid(x @ params["w"] + params["b"])

    return Model(init_fn, loss, predict)


def _silos(seed=0, sizes=(150, 110, 90, 70, 60)):
    rng = np.random.default_rng(seed)
    w_true = np.array([1.5, -2.0, 1.0, 0.0, 0.5])
    out = []
    for i, n in enumerate(sizes):
        x = rng.normal(0.1 * i, 1.0, (n, 5)).astype(np.float32)
        y = (x @ w_true + rng.normal(0, 0.2, n) > 0).astype(np.float32)
        out.append(Participant(x, y))
    return out


def _acc(model, params, silos):
    x = np.concatenate([p.x for p in silos])
    y = np.concatenate([p.y for p in silos])
    return ((np.asarray(model.predict_fn(params, jnp.asarray(x))) > 0.5)
            == y).mean()


def _cfg(**kw):
    base = dict(
        rounds=8, batch_size=48, lr=0.5, seed=0,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.6, microbatch_size=8),
    )
    base.update(kw)
    return SimConfig(**base)


def test_decaph_sim_learns_and_reports_systems_metrics():
    silos = _silos()
    model = _make_model(5)
    rep = simulate_decaph(model, silos, nodes_from_trace(heterogeneous_trace(5)),
                          Topology.full(5), _cfg())
    assert rep.rounds_completed == 8
    assert rep.wall_clock > 0 and rep.bytes_on_wire > 0
    assert rep.epsilon > 0
    assert _acc(model, rep.params, silos) > 0.8


def test_decaph_sim_dropout_triggers_shamir_recovery():
    silos = _silos()
    model = _make_model(5)
    trace = heterogeneous_trace(5)
    trace[2]["dropouts"] = [[0.2, None]]  # drops mid-run, never returns
    rep = simulate_decaph(model, silos, nodes_from_trace(trace),
                          Topology.full(5), _cfg())
    assert rep.dropout_events == 1
    assert rep.recoveries >= 1          # the mid-round drop was recovered
    assert rep.rounds_completed >= 6    # training continued with survivors
    assert _acc(model, rep.params, silos) > 0.75


def test_straggler_dominates_sync_wall_clock():
    """Same workload, one 20x-slower hospital => wall-clock inflates."""
    silos = _silos()
    model = _make_model(5)
    fast = [{"throughput": 500.0} for _ in range(5)]
    slow = [dict(t) for t in fast]
    slow[4] = {"throughput": 25.0}
    r_fast = simulate_fl(model, silos, nodes_from_trace(fast),
                         Topology.star(5), _cfg())
    r_slow = simulate_fl(model, silos, nodes_from_trace(slow),
                         Topology.star(5), _cfg())
    assert r_slow.wall_clock > 2.0 * r_fast.wall_clock


def test_fl_and_primia_sim_run_star():
    silos = _silos()
    model = _make_model(5)
    rep = simulate_fl(model, silos, nodes_from_trace(heterogeneous_trace(5)),
                      Topology.star(5), _cfg())
    assert rep.epsilon == 0.0 and rep.rounds_completed == 8
    assert _acc(model, rep.params, silos) > 0.8
    rep = simulate_primia(model, silos,
                          nodes_from_trace(heterogeneous_trace(5)),
                          Topology.star(5), _cfg())
    assert rep.epsilon > 0 and rep.rounds_completed >= 1


def test_fl_stalls_when_hub_dies():
    """Server-based FL has a single point of failure; the sim must show it."""
    silos = _silos()
    model = _make_model(5)
    trace = heterogeneous_trace(5)
    trace[0]["dropouts"] = [[0.1, None]]  # the hub (fl_server=0) dies early
    rep = simulate_fl(model, silos, nodes_from_trace(trace),
                      Topology.star(5), _cfg())
    assert rep.rounds_completed <= 1  # nothing aggregates at a dead hub
    # decaph's rotating facilitator survives the same failure
    rep2 = simulate_decaph(model, silos, nodes_from_trace(trace),
                           Topology.full(5), _cfg())
    assert rep2.rounds_completed >= 6


def test_local_sim_no_bytes_and_dropout_stalls():
    silos = _silos()
    model = _make_model(5)
    rep = simulate_local(model, silos,
                         nodes_from_trace(heterogeneous_trace(5)),
                         Topology.full(5), _cfg())
    assert rep.bytes_on_wire == 0.0
    assert len(rep.per_node_params) == 5
    # an offline window on the straggler stretches its wall-clock
    trace = heterogeneous_trace(5)
    trace[4]["dropouts"] = [[0.1, 30.0]]
    rep2 = simulate_local(model, silos, nodes_from_trace(trace),
                          Topology.full(5), _cfg())
    assert rep2.wall_clock > rep.wall_clock + 25.0


def test_gossip_sim_learns_and_reaches_rough_consensus():
    silos = _silos()
    model = _make_model(5)
    rep = simulate_gossip(model, silos,
                          nodes_from_trace(heterogeneous_trace(5)),
                          Topology.k_regular(5, 2), _cfg(rounds=12))
    assert rep.rounds_completed == 12   # every node finished its steps
    assert rep.bytes_on_wire > 0
    assert _acc(model, rep.params, silos) > 0.8
    # pairwise averaging keeps nodes near the consensus model
    w_avg = np.asarray(rep.params["w"])
    for p in rep.per_node_params:
        assert np.linalg.norm(np.asarray(p["w"]) - w_avg) < 2.0


def test_gossip_survives_permanent_dropout():
    silos = _silos()
    model = _make_model(5)
    trace = heterogeneous_trace(5)
    trace[1]["dropouts"] = [[0.05, None]]
    rep = simulate_gossip(model, silos, nodes_from_trace(trace),
                          Topology.ring(5), _cfg(rounds=6))
    assert rep.dropout_events == 1
    # the dead node froze early; the others finished their steps
    assert rep.rounds_completed < 6
    assert _acc(model, rep.params, silos) > 0.6


def test_scenario_from_trace_roundtrip():
    nodes, topo = scenario_from_trace({
        "nodes": heterogeneous_trace(4),
        "topology": {"kind": "ring"},
    })
    assert len(nodes) == 4 and topo.n == 4
    assert all(topo.degree(i) == 2 for i in range(4))
