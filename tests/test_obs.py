"""repro.obs: tracing core, privacy ledger, and the zero-overhead contract.

The load-bearing promises (DESIGN.md §11):

  * spans nest and order correctly, and the recorder survives concurrent
    writers (the serve CLI's trainer thread + decode loop);
  * the ledger's content-hash chain detects any tamper, and its per-round
    cumulative ε is exactly the arm accountant's ε — the ledger is an
    audit of the accountant, not a second accountant;
  * enabling recording adds ZERO jit dispatches to the fused round loop
    (the O(1)-dispatch contract of DESIGN.md §7 is recording-invariant).
"""

import json
import threading

import numpy as np
import pytest

import repro.arms as arms
import repro.obs as obs
from repro.instrument import (
    instrumented_jit,
    jit_dispatches,
    reset_jit_dispatches,
)
from repro.obs.convert import chrome_trace, validate_chrome_trace
from repro.obs.ledger import GENESIS, LedgerError, PrivacyLedger, entry_id
from repro.obs.recorder import EventStreamError, Recorder, validate_events
from repro.sim import nodes_from_trace
from repro.sim.nodes import heterogeneous_trace

from test_arms_equivalence import _cfg, _make_model, _silos


@pytest.fixture(autouse=True)
def _no_global_recorder():
    """Every test starts and ends with recording off."""
    obs.disable()
    yield
    obs.disable()


# -- tracing core -------------------------------------------------------------


def test_span_nesting_depth_and_ordering():
    rec = Recorder()
    with rec.span("outer", cat="t"):
        with rec.span("inner", cat="t", k=1):
            pass
        with rec.span("inner2", cat="t"):
            pass
    evs = [e for e in rec.events() if e["type"] == "span"]
    by_name = {e["name"]: e for e in evs}
    # children close before the parent: completion order is inner..outer
    assert [e["name"] for e in evs] == ["inner", "inner2", "outer"]
    assert by_name["outer"]["depth"] == 0
    assert by_name["inner"]["depth"] == 1
    assert by_name["inner2"]["depth"] == 1
    # child intervals lie inside the parent interval
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-9
    assert i["args"] == {"k": 1}
    validate_events(rec.events())


def test_counters_accumulate_and_gauges_record():
    rec = Recorder()
    rec.counter("c", 2)
    rec.counter("c", 3, tag="x")
    rec.gauge("g", 7.5)
    totals = rec.counter_totals()
    assert totals["c"] == 5
    evs = rec.events()
    counters = [e for e in evs if e["type"] == "counter"]
    assert [e["total"] for e in counters] == [2, 5]
    gauge = next(e for e in evs if e["type"] == "gauge")
    assert gauge["value"] == 7.5
    validate_events(evs)


def test_disabled_api_is_a_noop():
    assert obs.recorder() is None
    assert obs.now() is None
    ctx = obs.span("x")
    assert ctx is obs.span("y")  # the one shared nullcontext
    with ctx:
        pass
    obs.counter("c")
    obs.gauge("g", 1.0)
    obs.complete("x", None)


def test_recording_context_restores_previous_state():
    with obs.recording() as rec:
        assert obs.recorder() is rec
        with obs.recording() as rec2:
            assert obs.recorder() is rec2
        assert obs.recorder() is rec
    assert obs.recorder() is None


def test_recorder_thread_safety_stress():
    """Trainer-thread + decode-loop shape: concurrent spans and counters
    from many threads land without loss or interleaving corruption."""
    rec = Recorder()
    n_threads, n_iter = 8, 200

    def work(tid):
        for i in range(n_iter):
            with rec.span("w", cat="stress", tid_arg=tid):
                rec.counter("ticks", 1)

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert rec.counter_totals()["ticks"] == n_threads * n_iter
    spans = [e for e in rec.events() if e["type"] == "span"]
    assert len(spans) == n_threads * n_iter
    # per-thread depth tracking: no cross-thread depth bleed
    assert all(e["depth"] == 0 for e in spans)
    validate_events(rec.events())


def test_instrumented_jit_dispatch_count_is_thread_safe():
    """The satellite fix: an unguarded += would lose ticks here."""
    import jax.numpy as jnp

    f = instrumented_jit(lambda x: x + 1)
    f(jnp.zeros(()))  # compile outside the timed region
    reset_jit_dispatches()
    n_threads, n_iter = 8, 50

    def work():
        for _ in range(n_iter):
            f(jnp.zeros(()))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert jit_dispatches() == n_threads * n_iter


def test_instrumented_jit_feeds_obs_counter():
    import jax.numpy as jnp

    f = instrumented_jit(lambda x: x * 2)
    with obs.recording() as rec:
        f(jnp.ones(()))
        f(jnp.ones(()))
    assert rec.counter_totals()["jit_dispatches"] == 2
    assert sum(e["name"] == "jit_dispatch" for e in rec.events()
               if e["type"] == "span") == 2


def test_event_stream_validation_catches_corruption():
    rec = Recorder()
    rec.counter("c", 1)
    rec.counter("c", 1)
    evs = [dict(e) for e in rec.events()]
    evs[-1]["total"] = 99.0  # break the running sum
    with pytest.raises(EventStreamError):
        validate_events(evs)


# -- chrome trace conversion --------------------------------------------------


def test_chrome_trace_conversion(tmp_path):
    rec = Recorder()
    with rec.span("a", cat="t"):
        rec.counter("c", 1)
    doc = chrome_trace(rec.events())
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in phs and "C" in phs
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert x["name"] == "a" and x["dur"] >= 0
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(doc))
    assert validate_chrome_trace(p)["trace_events"] == len(doc["traceEvents"])


# -- privacy ledger -----------------------------------------------------------


def _toy_rounds(ledger, rounds=3, h=4, eps_step=0.5):
    for t in range(rounds):
        ledger.record_round(
            round=t, arm="decaph", backend="ideal", hospitals=h,
            cohort=range(h), delivered=range(h),
            epsilon=(t + 1) * eps_step, delta=1e-5,
            sampling_rate=0.1, participation_rate=1.0,
            noise_multiplier=0.8, bytes_up=100.0,
        )


def test_ledger_chain_validates_and_summarizes():
    led = PrivacyLedger()
    _toy_rounds(led)
    entries = led.entries()
    assert entries[0]["prev"] == GENESIS
    summary = obs.validate_entries(entries)
    assert summary["hospitals"] == 4 and summary["rounds"] == 3
    assert summary["final_eps"] == {i: pytest.approx(1.5) for i in range(4)}
    assert obs.per_hospital_epsilon(entries) == {
        i: pytest.approx(1.5) for i in range(4)
    }


@pytest.mark.parametrize("tamper", ["eps", "reorder", "drop", "prev"])
def test_ledger_tamper_detection(tamper):
    led = PrivacyLedger()
    _toy_rounds(led)
    entries = [dict(e) for e in led.entries()]
    if tamper == "eps":
        entries[5]["eps"] = 0.0            # rewrite history, keep the id
    elif tamper == "reorder":
        entries[2], entries[3] = entries[3], entries[2]
    elif tamper == "drop":
        del entries[4]
    elif tamper == "prev":
        entries[6]["prev"] = "f" * 16
    with pytest.raises(LedgerError):
        obs.validate_entries(entries)


def test_ledger_recompute_id_detects_field_rewrite():
    led = PrivacyLedger()
    _toy_rounds(led, rounds=1)
    e = dict(led.entries()[0])
    e["bytes_up"] = 1e9
    assert entry_id(e) != e["id"]


def test_ledger_jsonl_roundtrip(tmp_path):
    led = PrivacyLedger()
    _toy_rounds(led)
    p = tmp_path / "ledger.jsonl"
    led.write_jsonl(p)
    back = obs.read_entries(p)
    assert back == led.entries()
    obs.validate_entries(back)


# -- ledger vs accountant (the acceptance criterion) --------------------------


def _sim_nodes(h):
    return nodes_from_trace(heterogeneous_trace(h))


def test_ledger_epsilon_matches_accountant_per_round():
    """decaph/sim/H=5: every ledger entry's ε equals the accountant's ε
    at that round (RoundLog pins it), and the cumulative per-hospital ε
    equals the run's final ε — the shared-accountant semantics of the
    paper (one ε over the aggregate dataset, every hospital covered)."""
    h = 5
    cfg = _cfg(rounds=4, use_secagg=True)
    with obs.recording() as rec:
        rep = arms.run("decaph", _make_model(5), _silos(sizes=(120,) * h),
                       cfg, backend="sim", nodes=_sim_nodes(h))
    entries = rec.ledger.entries()
    obs.validate_entries(entries)
    assert rep.rounds_completed == 4
    assert len(entries) == h * rep.rounds_completed
    eps_by_round = {log.round: log.epsilon for log in rep.logs}
    for e in entries:
        assert e["hospital"] in range(h)
        assert e["eps"] == pytest.approx(eps_by_round[e["round"]], rel=1e-9)
        assert e["arm"] == "decaph" and e["backend"] == "sim"
        assert e["member"] and e["delivered"]
        assert e["bytes_up"] > 0
    per_h = obs.per_hospital_epsilon(entries)
    assert set(per_h) == set(range(h))
    for hosp in range(h):
        assert per_h[hosp] == pytest.approx(rep.epsilon, rel=1e-9)


def test_ledger_ideal_backend_matches_sim_epsilon():
    cfg = _cfg(rounds=3)
    with obs.recording() as rec:
        rep = arms.run("decaph", _make_model(5), _silos(), cfg)
    entries = rec.ledger.entries()
    assert len(entries) == 4 * 3  # H=4 silos x 3 rounds
    assert obs.per_hospital_epsilon(entries)[0] == pytest.approx(rep.epsilon)


# -- the zero-overhead contract ----------------------------------------------


def test_recording_adds_zero_jit_dispatches():
    """The pinned overhead bound: the fused round loop launches exactly as
    many compiled programs with recording on as off."""
    cfg = _cfg(rounds=3)
    model, silos = _make_model(5), _silos()

    arms.run("decaph", model, silos, cfg)  # warm the compile caches
    reset_jit_dispatches()
    arms.run("decaph", model, silos, cfg)
    baseline = jit_dispatches()

    reset_jit_dispatches()
    with obs.recording() as rec:
        arms.run("decaph", model, silos, cfg)
    recorded = jit_dispatches()

    assert baseline > 0
    assert recorded == baseline
    # and the recorder's own counter agrees with the process counter
    assert rec.counter_totals()["jit_dispatches"] == recorded


# -- serve + metrics ----------------------------------------------------------


def test_serve_engine_emits_obs_counters():
    from repro.serve.engine import ServeConfig, ServeEngine, batch_generate

    with obs.recording() as rec:
        engine = ServeEngine(ServeConfig(slots=2, max_len=32, seed=0))
        prompts = np.ones((2, 4), np.int32)
        batch_generate(engine, prompts, gen=3)
    totals = rec.counter_totals()
    assert totals["serve.admits"] == 2
    assert totals["serve.decode_steps"] == engine.decode_steps
    assert totals["serve.evictions"] == 2
    names = {e["name"] for e in rec.events() if e["type"] == "span"}
    assert {"serve.admit", "serve.decode_step"} <= names
    validate_events(rec.events())


def test_metrics_survive_degenerate_traces():
    from repro.serve.metrics import render_markdown, summarize
    from repro.serve.traffic import TraceResult

    empty = TraceResult(completed=[], steps=[], wall=0.0, swaps=0,
                        decode_steps=0, decode_dispatches=0,
                        admit_dispatches=0)
    row = summarize(empty, slots=0, rate=1.0)
    assert row["throughput_tok_s"] == 0.0
    assert row["occupancy"] == 0.0
    assert row["dispatches_per_step"] == 0.0
    assert row["ttft_p95_ms"] == 0.0 and row["tpot_p95_ms"] == 0.0
    md = render_markdown([row], title="t")
    assert "TTFT p95" in md and "TPOT p95" in md
    # pre-p95 rows (the committed BENCH_serve.json) still render
    old = {k: v for k, v in row.items() if "p95" not in k}
    assert render_markdown([old], title="t").count("|")


# -- export + CLI -------------------------------------------------------------


def test_export_and_cli_validate_roundtrip(tmp_path):
    from repro.obs.cli import main as obs_main

    out = tmp_path / "obs"
    cfg = _cfg(rounds=2)
    with obs.recording() as rec:
        arms.run("decaph", _make_model(5), _silos(), cfg)
        paths = obs.export(out, rec)
    assert all(p.exists() for p in paths.values())
    assert obs_main(["--validate", str(out)]) == 0
    assert obs_main([str(out)]) == 0  # summary mode

    # corrupt one ledger line -> the chain breaks -> exit 1
    lines = paths["ledger"].read_text().splitlines()
    tampered = json.loads(lines[2])
    tampered["eps"] = 0.0
    lines[2] = json.dumps(tampered)
    paths["ledger"].write_text("\n".join(lines) + "\n")
    assert obs_main(["--validate", str(out)]) == 1


def test_export_without_recorder_raises(tmp_path):
    with pytest.raises(RuntimeError):
        obs.export(tmp_path / "nope")


def test_cli_to_chrome(tmp_path):
    from repro.obs.cli import main as obs_main

    rec = Recorder()
    with rec.span("a"):
        pass
    events = tmp_path / "events.jsonl"
    rec.write_jsonl(events)
    out = tmp_path / "converted.json"
    assert obs_main(["--to-chrome", str(events), "--out", str(out)]) == 0
    assert validate_chrome_trace(out)["trace_events"] >= 1


# -- sweep cells --------------------------------------------------------------


def test_sweep_cell_phase_breakdown():
    from repro.scenarios.executor import run_spec
    from repro.scenarios.presets import get_preset

    spec = get_preset("gemini-5hospital").replace(rounds=2)
    with obs.recording():
        row = run_spec(spec)
    assert "phase_seconds" in row
    assert row["phase_seconds"]  # at least one phase accumulated time
    assert all(v >= 0 for v in row["phase_seconds"].values())
    assert "noise_topups" in row and "host_seconds" in row

    row_off = run_spec(spec)
    assert "phase_seconds" not in row_off
