"""`hypothesis` if installed, else a tiny deterministic fallback.

The property tests are written against the real hypothesis API.  When the
optional dependency is missing (the tier-1 container does not ship it), this
shim runs each ``@given`` test on a fixed number of seeded pseudo-random
draws instead — less coverage than hypothesis' shrinking search, but the
properties still execute and the suite collects cleanly.  CI installs the
real library (see .github/workflows/ci.yml), so full property testing runs
there.

Only the strategy surface the test files actually use is implemented:
``integers``, ``floats``, ``sampled_from``, ``booleans``, ``none``,
``one_of``, ``lists``.
"""

from __future__ import annotations

import functools
import inspect
import random

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def none():
            return _Strategy(lambda rng: None)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elements.draw(rng)
                    for _ in range(rng.randint(min_size, max_size))
                ]
            )

        @staticmethod
        def one_of(*strategies):
            return _Strategy(
                lambda rng: strategies[rng.randrange(len(strategies))].draw(rng)
            )

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        """Record max_examples on whatever callable it decorates."""

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        """Run the test body on seeded draws from each keyword strategy."""

        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_EXAMPLES))
                for i in range(n):
                    rng = random.Random(0xDECA9 + 31 * i)
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not mistake the strategy kwargs for fixtures:
            # hide the wrapped signature entirely.
            del runner.__wrapped__
            runner.__signature__ = inspect.Signature()
            return runner

        return deco
