"""Dropout-robust SecAgg: Shamir algebra, DH agreement, mask recovery."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.secagg import (
    DropoutRobustSession,
    SecAggConfig,
    secagg_recovery_bytes,
    secure_sum,
    secure_sum_with_dropouts,
    shamir_reconstruct,
    shamir_share,
)


def test_shamir_roundtrip_any_threshold_subset():
    rng = np.random.default_rng(0)
    secret = 987_654_321_012_345
    shares = shamir_share(secret, n_shares=7, threshold=4, rng=rng)
    assert shamir_reconstruct(shares[:4]) == secret
    assert shamir_reconstruct(shares[3:7]) == secret
    assert shamir_reconstruct([shares[0], shares[2], shares[4], shares[6]]) \
        == secret
    # fewer than threshold shares reconstruct garbage, not the secret
    assert shamir_reconstruct(shares[:3]) != secret


def test_shamir_validates_inputs():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        shamir_share(-1, 3, 2, rng)
    with pytest.raises(ValueError):
        shamir_share(5, 3, 4, rng)  # threshold > n_shares
    with pytest.raises(ValueError):
        shamir_reconstruct([])
    with pytest.raises(ValueError):
        shamir_reconstruct([(1, 5), (1, 6)])  # duplicate indices


def test_dh_pair_seeds_are_symmetric():
    cfg = SecAggConfig(4, seed=9)
    sess = DropoutRobustSession(cfg, jnp.zeros((3,)))
    for i in range(4):
        for j in range(4):
            if i != j:
                assert sess._pair_seed(i, j) == sess._pair_seed(j, i)


def test_no_dropout_equals_plain_secure_sum():
    rng = np.random.default_rng(1)
    n = 4
    vals = [jnp.asarray(rng.normal(0, 2, 8).astype(np.float32))
            for _ in range(n)]
    cfg = SecAggConfig(n, frac_bits=16, seed=5)
    out = secure_sum_with_dropouts(vals, cfg)
    expected = np.sum([np.asarray(v) for v in vals], axis=0)
    np.testing.assert_allclose(np.asarray(out), expected, atol=n * 2**-15)


def test_dropout_recovery_equals_survivor_sum():
    """The acceptance property: recovered aggregate == survivors' plain sum
    within fixed-point tolerance."""
    rng = np.random.default_rng(2)
    n = 5
    vals = [jnp.asarray(rng.normal(0, 3, 24).astype(np.float32))
            for _ in range(n)]
    cfg = SecAggConfig(n, frac_bits=16, seed=7)
    for dropped in ({2}, {0, 4}, {1, 2}):
        slots = [None if i in dropped else vals[i] for i in range(n)]
        out = secure_sum_with_dropouts(slots, cfg, threshold=3)
        expected = np.sum(
            [np.asarray(vals[i]) for i in range(n) if i not in dropped],
            axis=0,
        )
        np.testing.assert_allclose(
            np.asarray(out), expected, atol=n * 2**-15
        )


def test_dropout_recovery_pytree():
    tree_a = {"w": jnp.array([1.0, -2.0]), "b": {"c": jnp.array(0.5)}}
    tree_b = {"w": jnp.array([3.0, 4.0]), "b": {"c": jnp.array(-1.5)}}
    out = secure_sum_with_dropouts(
        [tree_a, tree_b, None], SecAggConfig(3, seed=3), threshold=2
    )
    np.testing.assert_allclose(np.asarray(out["w"]), [4.0, 2.0], atol=1e-4)
    np.testing.assert_allclose(float(out["b"]["c"]), -1.0, atol=1e-4)


def test_below_threshold_aborts():
    cfg = SecAggConfig(5, seed=0)
    sess = DropoutRobustSession(cfg, jnp.zeros((4,)), threshold=4)
    uploads = {i: sess.upload(i, jnp.ones((4,))) for i in range(3)}
    with pytest.raises(ValueError, match="threshold"):
        sess.aggregate(uploads)


def test_upload_is_masked_and_validated():
    cfg = SecAggConfig(3, seed=1)
    sess = DropoutRobustSession(cfg, jnp.zeros((64,)))
    up = sess.upload(0, jnp.ones((64,)))[0]
    plain = np.round(np.ones(64) * cfg.scale).astype(np.uint32)
    assert (up != plain).mean() > 0.9  # pads look uniform
    with pytest.raises(ValueError):
        sess.upload(0, jnp.ones((65,)))  # wrong shape fails loudly


def test_secure_sum_fails_loudly_on_short_lists():
    """Satellite: a dropped participant must never yield silent garbage."""
    vals = [jnp.ones((4,)), jnp.ones((4,))]
    with pytest.raises(ValueError, match="participants"):
        secure_sum(vals, SecAggConfig(3, seed=0))
    with pytest.raises(ValueError, match="empty"):
        secure_sum([], SecAggConfig(0, seed=0))


def test_all_dropped_rejected():
    with pytest.raises(ValueError, match="every participant"):
        secure_sum_with_dropouts([None, None], SecAggConfig(2, seed=0))


def test_recovery_cost_model_shape():
    c0 = secagg_recovery_bytes(8, 0)
    c2 = secagg_recovery_bytes(8, 2)
    assert c0["recovery_bytes"] == 0.0
    assert c2["recovery_bytes"] > 0.0
    assert c2["setup_bytes"] == c0["setup_bytes"]  # setup paid up front
    assert secagg_recovery_bytes(16)["setup_bytes"] \
        > 3 * secagg_recovery_bytes(8)["setup_bytes"]  # ~quadratic in n
