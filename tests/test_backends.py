"""Backend registry, capability negotiation, and the SPMD ``shard`` backend.

The shard-vs-ideal equivalence runs in a subprocess with 8 forced host
devices (the main test process keeps the single default device, like the
dry-run and SPMD-numeric suites).  Tolerance contract (DESIGN.md §8):
``shard`` sits in its own ``bit_exact_group`` because GSPMD's partitioned
reductions re-associate float sums — it must match the idealized backend to
the same atol=1e-5 class as the fused-vs-loop comparison, not bit for bit.
"""

import json
import os
import subprocess
import sys

import jax
import pytest

import repro.arms as arms
from repro.arms import backends
from repro.core.dp import DPConfig

from test_arms_equivalence import _cfg, _make_model, _silos

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- registry -----------------------------------------------------------------


def test_registry_enumerates_every_backend():
    names = backends.backend_names()
    assert {"ideal", "sim", "shard"} <= set(names)
    registry = backends.backend_registry()
    assert registry["sim"].supports_sim_time
    assert not registry["ideal"].supports_sim_time
    assert registry["shard"].fused_only
    assert not registry["shard"].supports_secagg
    assert registry["shard"].device_requirements  # documented, non-empty


def test_bit_exact_groups_partition_backends():
    groups = backends.bit_exact_groups()
    assert groups["host"] == ("ideal", "sim")
    assert groups["spmd"] == ("shard",)


def test_register_backend_rejects_duplicate_name():
    with pytest.raises(ValueError, match="already registered"):

        @backends.register_backend(backends.BackendInfo(name="ideal"))
        class Impostor:  # pragma: no cover - never instantiated
            pass


def test_get_backend_unknown_lists_the_registry():
    with pytest.raises(KeyError, match="registered backends"):
        backends.get_backend("cloud")


# -- capability negotiation ---------------------------------------------------


def test_run_rejects_secagg_arm_on_shard():
    """decaph's ciphertext uploads are ruled out before any compute."""
    with pytest.raises(ValueError, match="SecAgg"):
        arms.run("decaph", _make_model(5), _silos(),
                 _cfg(use_secagg=True), backend="shard")


def test_run_rejects_node_arms_on_shard():
    with pytest.raises(ValueError, match="fused-capable round arms"):
        arms.run("gossip", _make_model(5), _silos(), _cfg(),
                 backend="shard")


def test_run_rejects_loop_path_on_shard():
    with pytest.raises(ValueError, match="fused_rounds=False"):
        arms.run("decaph", _make_model(5), _silos(),
                 _cfg(fused_rounds=False), backend="shard")


@pytest.mark.skipif(jax.device_count() > 1,
                    reason="this process already has multiple XLA devices, "
                           "so shard is available here")
def test_shard_reports_device_requirements_on_one_device():
    """This process has one CPU device: availability names the fix, and
    construction fails loudly with it (negotiation passes first — the
    arm/config pair itself is fine)."""
    assert backends.availability("shard") is not None
    assert "XLA_FLAGS" in backends.availability("shard")
    with pytest.raises(RuntimeError, match="XLA_FLAGS"):
        arms.run("decaph", _make_model(5), _silos(), _cfg(),
                 backend="shard")


def test_sim_requires_nodes_via_setup():
    with pytest.raises(ValueError, match="nodes"):
        arms.run("decaph", _make_model(5), _silos(), _cfg(), backend="sim")


# -- CLI enumeration ----------------------------------------------------------


def test_cli_list_shows_backends(capsys):
    from repro.run import main

    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "backends:" in out
    for name in backends.backend_names():
        assert name in out
    if backends.availability("shard"):
        assert "unavailable here" in out  # the device requirement surfaces


# -- shard-vs-ideal equivalence (subprocess: needs 8 placeholder devices) -----

_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax

import repro.arms as arms
from repro.core.dp import DPConfig
from repro.data.synthetic import make_gemini_like
from repro.models.tabular import linear_model
from repro.launch.federated import ShardedRunner

assert jax.device_count() == 8

silos = arms.normalize_participants(
    make_gemini_like(seed=0, n_total=720, n_silos=5, n_features=8)
)
model = linear_model(8)

results = {}
fused_arms = sorted(
    n for n in arms.names()
    if getattr(arms.get(n), "fused_capable", False)
)
for name in fused_arms:
    cfg = arms.ArmConfig(
        rounds=3, batch_size=48, lr=0.3, seed=0, use_secagg=False,
        fl_local_steps=2,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
    )
    ideal = arms.run(name, model, silos, cfg)
    runner = ShardedRunner()
    shard = runner.run(arms.get(name)(model, silos, cfg))
    la = jax.tree_util.tree_leaves(ideal.params)
    lb = jax.tree_util.tree_leaves(shard.params)
    results[name] = {
        "max_abs_diff": max(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(la, lb)
        ),
        "rounds": [ideal.rounds_completed, shard.rounds_completed],
        "epsilon": [float(ideal.epsilon), float(shard.epsilon)],
        "sharded_puts": runner.executor.sharded_puts,
        "backend_label": shard.backend,
    }
print("RESULTS" + json.dumps({"arms": fused_arms, "cells": results}))
"""


@pytest.mark.slow
def test_shard_matches_ideal_within_documented_tolerance():
    """Every fused-capable arm, shard vs ideal, atol 1e-5 on final params —
    and the mesh genuinely sharded the cohort batches (sharded_puts > 0)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _EQUIV_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    payload = [ln for ln in proc.stdout.splitlines()
               if ln.startswith("RESULTS")][0]
    report = json.loads(payload[len("RESULTS"):])
    # the registry drives coverage: every fused-capable arm must be here
    assert {"decaph", "fl", "fedprox", "scaffold", "primia"} <= set(
        report["arms"]
    )
    for name, cell in report["cells"].items():
        assert cell["rounds"][0] == cell["rounds"][1], name
        assert cell["max_abs_diff"] <= 1e-5, (name, cell)
        assert cell["epsilon"][0] == pytest.approx(cell["epsilon"][1]), name
        assert cell["sharded_puts"] > 0, name  # SPMD actually engaged
        assert cell["backend_label"] == "shard"


_POD_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import numpy as np
import jax

import repro.arms as arms
from repro.configs import get_smoke_config
from repro.core.dp import DPConfig
from repro.data.synthetic import make_gemini_like
from repro.models.tabular import linear_model
from repro.launch.federated import ShardedRunner
from repro.launch.mesh import make_debug_mesh
from repro.serve.federation import token_silos, transformer_model

assert jax.device_count() == 8
mesh = make_debug_mesh(n_data=2, n_model=2, multi_pod=True)  # (2, 2, 2)

cfg_m = dataclasses.replace(get_smoke_config("smollm-360m"),
                            tie_embeddings=False)
lm_model = transformer_model(cfg_m)
# 4 hospitals divide the ("pod", "data") extent (2*2), so the participant
# axis genuinely splits across pods
lm_silos = token_silos(cfg_m, hospitals=4, n_per=16, seq_len=12, seed=0)
tab_model = linear_model(8)
tab_silos = arms.normalize_participants(
    make_gemini_like(seed=0, n_total=720, n_silos=4, n_features=8)
)

results = {}
cells = [
    ("decaph-lm-ghost", "decaph", lm_model, lm_silos, {"clipping": "ghost"}),
    ("decaph-lm-faithful", "decaph", lm_model, lm_silos,
     {"clipping": "per-example"}),
    ("decaph-tabular", "decaph", tab_model, tab_silos, {}),
]
for label, name, model, silos, extra in cells:
    cfg = arms.ArmConfig(
        rounds=3, batch_size=16, lr=0.1, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
        **extra,
    )
    ideal = arms.run(name, model, silos, cfg)
    runner = ShardedRunner(mesh=mesh)
    shard = runner.run(arms.get(name)(model, silos, cfg))
    la = jax.tree_util.tree_leaves(ideal.params)
    lb = jax.tree_util.tree_leaves(shard.params)
    results[label] = {
        "max_abs_diff": max(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(la, lb)
        ),
        "rounds": [ideal.rounds_completed, shard.rounds_completed],
        "epsilon": [float(ideal.epsilon), float(shard.epsilon)],
        "sharded_puts": runner.executor.sharded_puts,
        "participant_shards": runner.executor.participant_shards,
        "param_shards": runner.executor.param_shards,
        "backend_label": shard.backend,
    }
print("RESULTS" + json.dumps(results))
"""


@pytest.mark.slow
def test_pod_mesh_shard_matches_ideal():
    """("pod","data","model") mesh cells pass the same atol-1e-5 contract.

    Transformer cells must split the hospital axis over ("pod","data")
    (participant_shards > 0, never padded) and place model-parallel params
    over ("model",) (param_shards > 0); the tabular cell rides the same mesh
    with every param replicated (no encoded logical axes).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _POD_MESH_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    payload = [ln for ln in proc.stdout.splitlines()
               if ln.startswith("RESULTS")][0]
    report = json.loads(payload[len("RESULTS"):])
    assert set(report) == {"decaph-lm-ghost", "decaph-lm-faithful",
                           "decaph-tabular"}
    for label, cell in report.items():
        assert cell["rounds"][0] == cell["rounds"][1], label
        assert cell["max_abs_diff"] <= 1e-5, (label, cell)
        assert cell["epsilon"][0] == pytest.approx(cell["epsilon"][1]), label
        assert cell["sharded_puts"] > 0, label
        assert cell["participant_shards"] > 0, label  # pods own cohort slices
        assert cell["backend_label"] == "shard", label
        if label.startswith("decaph-lm"):
            assert cell["param_shards"] > 0, label  # TP over ("model",)
        else:
            assert cell["param_shards"] == 0, label  # tabular: replicated
