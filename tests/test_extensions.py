"""Extension features: DeepSeek MTP head, blocked-op property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_smoke_config
from repro.kernels.ghost_norm.ops import ghost_norm_blocked
from repro.kernels.ghost_norm.ref import ghost_norm_ref
from repro.models import transformer as tf
from repro.models.attention import _causal_mask, _sdpa, _sdpa_blocked


def test_mtp_loss_adds_second_horizon():
    cfg = get_smoke_config("deepseek-v3-671b").replace(mtp_depth=1)
    params = tf.init(cfg, jax.random.key(0))
    assert "mtp" in params
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 12), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (2, 12), 0,
                                     cfg.vocab_size),
    }
    loss_mtp = tf.loss_fn(cfg, params, batch)
    plain = {k: v for k, v in params.items() if k != "mtp"}
    loss_plain = tf.loss_fn(cfg.replace(mtp_depth=0), plain, batch)
    assert float(loss_mtp) > float(loss_plain)  # extra CE term
    g = jax.grad(lambda p: tf.loss_fn(cfg, p, batch))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(2, 96),
    d=st.sampled_from([8, 24]),
    block=st.sampled_from([16, 32]),
)
def test_ghost_norm_blocked_property(b, s, d, block):
    k = jax.random.key(s * 7 + d)
    a = jax.random.normal(jax.random.fold_in(k, 1), (b, s, d))
    g = 0.3 * jax.random.normal(jax.random.fold_in(k, 2), (b, s, d // 2 or 1))
    np.testing.assert_allclose(
        np.asarray(ghost_norm_ref(a, g)),
        np.asarray(ghost_norm_blocked(a, g, block=block)),
        rtol=5e-4, atol=1e-5,
    )


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(4, 80),
    window=st.one_of(st.none(), st.integers(2, 32)),
    bk=st.sampled_from([8, 32]),
)
def test_blocked_attention_property(s, window, bk):
    k = jax.random.key(s * 13 + (window or 0))
    q = 0.5 * jax.random.normal(jax.random.fold_in(k, 1), (1, s, 2, 8))
    kk = 0.5 * jax.random.normal(jax.random.fold_in(k, 2), (1, s, 1, 8))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, s, 1, 8))
    ref = _sdpa(q, kk, v, _causal_mask(s, s, 0, window))
    blk = _sdpa_blocked(q, kk, v, causal=True, window=window, block_k=bk)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(blk), atol=1e-5)
