"""SecAgg: exact mask cancellation, privacy of individual uploads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.secagg import (
    SecAggConfig,
    SecAggSession,
    secagg_message_bytes,
    secure_sum,
)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 6),
    dim=st.integers(1, 32),
    seed=st.integers(0, 1000),
)
def test_secure_sum_matches_plain_sum(n, dim, seed):
    rng = np.random.default_rng(seed)
    vals = [jnp.asarray(rng.normal(0, 3, dim).astype(np.float32))
            for _ in range(n)]
    out = secure_sum(vals, SecAggConfig(n, frac_bits=16, seed=seed))
    expected = np.sum([np.asarray(v) for v in vals], axis=0)
    # quantisation error: n participants x 2^-17 rounding each
    np.testing.assert_allclose(np.asarray(out), expected, atol=n * 2 ** -15)


def test_masks_cancel_exactly():
    cfg = SecAggConfig(5, frac_bits=16, seed=7)
    session = SecAggSession(cfg, {"w": jnp.zeros((8,))})
    with np.errstate(over="ignore"):
        total = sum(np.asarray(session.mask_for(i)[0], dtype=np.uint64)
                    for i in range(5)) % (1 << 32)
    assert (total == 0).all()


def test_upload_is_masked():
    """A single ciphertext must not reveal the plaintext."""
    cfg = SecAggConfig(3, frac_bits=16, seed=3)
    session = SecAggSession(cfg, {"w": jnp.zeros((64,))})
    x = {"w": jnp.ones((64,))}
    up = session.upload(0, x)[0]
    # uniform masks: ciphertext should look nothing like the fixed plaintext
    plain = np.round(np.ones(64) * cfg.scale).astype(np.uint32)
    assert (up != plain).mean() > 0.9


def test_aggregate_requires_all_uploads():
    cfg = SecAggConfig(3, seed=0)
    session = SecAggSession(cfg, jnp.zeros((4,)))
    ups = [session.upload(i, jnp.ones((4,))) for i in range(2)]
    with pytest.raises(ValueError):
        session.aggregate(ups)


def test_pytree_structure_roundtrip():
    tree = {"a": jnp.array([1.5, -2.0]), "b": {"c": jnp.array(3.25)}}
    out = secure_sum([tree, tree], SecAggConfig(2))
    assert set(out) == {"a", "b"}
    np.testing.assert_allclose(np.asarray(out["a"]), [3.0, -4.0], atol=1e-4)
    np.testing.assert_allclose(float(out["b"]["c"]), 6.5, atol=1e-4)


def test_comm_cost_model_matches_paper_shape():
    # cost grows linearly in params and in participants for the aggregator
    c1 = secagg_message_bytes(166_771, 8)   # GEMINI MLP row of Supp Table 1
    c2 = secagg_message_bytes(166_771, 16)
    assert c2["aggregator_bytes"] > 1.9 * c1["aggregator_bytes"]
    assert c1["per_participant_bytes"] > c1["plain_per_participant_bytes"]
