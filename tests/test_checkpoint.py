"""repro.checkpoint round-trip + corruption contract.

The serving handoff channel (``repro.serve.handoff``) leans on two promises
here: a rename-atomic write (a reader never sees a torn file under the
final name) and ``CorruptCheckpointError`` on anything that IS torn (so the
watcher can skip-and-retry instead of dying).  These tests pin both, plus
exact round-trips for the tree shapes that actually travel the channel —
transformer parameter trees and KV-cache-shaped nested structures.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CorruptCheckpointError,
    load_checkpoint,
    save_checkpoint,
)


def _assert_trees_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for xa, xb in zip(la, lb):
        xa, xb = np.asarray(xa), np.asarray(xb)
        assert xa.dtype == xb.dtype
        assert xa.shape == xb.shape
        np.testing.assert_array_equal(xa, xb)


def test_roundtrip_params_tree(tmp_path):
    tree = {
        "group0": {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((4,), jnp.float32),
        },
        "head": jnp.full((2, 2), -1.5, jnp.bfloat16),
    }
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, tree, step=7, metadata={"arm": "fl"})
    got, step, meta = load_checkpoint(path)
    assert step == 7
    assert meta["arm"] == "fl"
    _assert_trees_equal(tree, got)


def test_roundtrip_kv_cache_shaped_tree(tmp_path):
    # the serving engine's cache: nested dicts with tuples of
    # mixed-dtype arrays carrying a stacked-layer axis 0 and batch axis 1
    cache = {
        "group0": {
            "attn": (
                jnp.zeros((2, 3, 16, 4, 8), jnp.bfloat16),   # k
                jnp.ones((2, 3, 16, 4, 8), jnp.bfloat16),    # v
            ),
            "pos": jnp.arange(3, dtype=jnp.int32),
        },
        "group1": {
            "conv": jnp.full((2, 3, 4, 32), 0.25, jnp.float32),
            "ssm": [jnp.zeros((2, 3, 8, 8), jnp.float32)],
        },
    }
    path = str(tmp_path / "cache.msgpack")
    save_checkpoint(path, cache, step=0)
    got, _, _ = load_checkpoint(path)
    _assert_trees_equal(cache, got)
    # container kinds survive: tuples stay tuples, lists stay lists
    assert isinstance(got["group0"]["attn"], tuple)
    assert isinstance(got["group1"]["ssm"], list)


def test_missing_file_raises_file_not_found(tmp_path):
    # FileNotFoundError passes through UNwrapped: the watcher treats "not
    # there yet" (a just-pruned round) differently from "there but broken"
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "nope.msgpack"))


def test_truncated_file_is_corrupt(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, {"w": jnp.ones((64, 64), jnp.float32)}, step=3)
    raw = open(path, "rb").read()
    torn = str(tmp_path / "torn.msgpack")
    with open(torn, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(CorruptCheckpointError):
        load_checkpoint(torn)


def test_garbage_file_is_corrupt(tmp_path):
    path = str(tmp_path / "junk.msgpack")
    with open(path, "wb") as f:
        f.write(b"\xde\xad\xbe\xef not a checkpoint")
    with pytest.raises(CorruptCheckpointError):
        load_checkpoint(path)


def test_valid_msgpack_wrong_payload_is_corrupt(tmp_path):
    import msgpack

    path = str(tmp_path / "notckpt.msgpack")
    with open(path, "wb") as f:
        f.write(msgpack.packb({"hello": "world"}, use_bin_type=True))
    with pytest.raises(CorruptCheckpointError):
        load_checkpoint(path)


def test_mismatched_array_bytes_are_corrupt(tmp_path):
    import msgpack

    path = str(tmp_path / "ok.msgpack")
    save_checkpoint(path, {"w": jnp.ones((4, 4), jnp.float32)}, step=0)
    payload = msgpack.unpackb(open(path, "rb").read(), raw=False)
    # declared shape no longer matches the byte count
    payload["tree"]["__map__"]["w"]["shape"] = [5, 5]
    bad = str(tmp_path / "bad.msgpack")
    with open(bad, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    with pytest.raises(CorruptCheckpointError):
        load_checkpoint(bad)


def test_atomic_write_leaves_no_temp_droppings(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, {"w": jnp.zeros((2,), jnp.float32)}, step=1)
    assert sorted(os.listdir(tmp_path)) == ["ckpt.msgpack"]


def test_overwrite_is_atomic_replacement(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, {"w": jnp.zeros((2,), jnp.float32)}, step=1)
    save_checkpoint(path, {"w": jnp.ones((2,), jnp.float32)}, step=2)
    got, step, _ = load_checkpoint(path)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones(2))
