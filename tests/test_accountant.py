"""RDP accountant: closed forms, monotonicity, inversion round-trips."""

import math

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.accountant import (
    DEFAULT_ORDERS,
    RDPAccountant,
    compute_epsilon,
    compute_rdp_sgm,
    rdp_to_eps_delta,
    sigma_for_epsilon,
    steps_for_epsilon,
)


def test_gaussian_closed_form():
    # p=1 is the plain Gaussian mechanism: rdp(alpha) = alpha/(2 sigma^2)
    orders = [2.0, 4.0, 8.0]
    rdp = compute_rdp_sgm(1.0, 2.0, 1, orders)
    for a, r in zip(orders, rdp):
        assert r == pytest.approx(a / (2 * 4.0), rel=1e-9)


def test_zero_sampling_is_free():
    assert compute_epsilon(0.0, 1.0, 1000, 1e-5) == 0.0


def test_fractional_integer_continuity():
    # RDP should be continuous across integer orders.
    for alpha in [3, 7, 15]:
        lo = compute_rdp_sgm(0.02, 1.0, 1, [alpha - 1e-3])[0]
        mid = compute_rdp_sgm(0.02, 1.0, 1, [float(alpha)])[0]
        hi = compute_rdp_sgm(0.02, 1.0, 1, [alpha + 1e-3])[0]
        assert lo <= mid * 1.01 + 1e-9
        assert mid <= hi * 1.01 + 1e-9
        assert abs(hi - lo) / max(mid, 1e-12) < 0.05


@settings(max_examples=25, deadline=None)
@given(
    p=st.floats(1e-4, 0.5),
    sigma=st.floats(0.5, 5.0),
    steps=st.integers(1, 2000),
)
def test_monotone_in_steps(p, sigma, steps):
    e1 = compute_epsilon(p, sigma, steps, 1e-5)
    e2 = compute_epsilon(p, sigma, steps * 2, 1e-5)
    assert e2 >= e1 - 1e-9


@settings(max_examples=25, deadline=None)
@given(p=st.floats(1e-4, 0.5), sigma=st.floats(0.5, 4.0))
def test_monotone_in_sigma(p, sigma):
    e1 = compute_epsilon(p, sigma, 100, 1e-5)
    e2 = compute_epsilon(p, sigma * 1.5, 100, 1e-5)
    assert e2 <= e1 + 1e-9


def test_sigma_inversion_roundtrip():
    p, steps, delta, target = 0.01, 500, 1e-5, 2.0
    sigma = sigma_for_epsilon(p, steps, target, delta)
    eps = compute_epsilon(p, sigma, steps, delta)
    assert eps <= target * 1.001
    # slightly smaller sigma must violate the budget
    assert compute_epsilon(p, sigma * 0.97, steps, delta) > target * 0.999


def test_steps_inversion():
    p, sigma, delta, target = 0.02, 1.0, 1e-5, 3.0
    t = steps_for_epsilon(p, sigma, target, delta)
    assert compute_epsilon(p, sigma, t, delta) <= target
    assert compute_epsilon(p, sigma, t + 1, delta) > target


def test_accountant_state():
    acct = RDPAccountant(sampling_rate=0.01, noise_multiplier=1.0, delta=1e-5)
    assert acct.epsilon() == 0.0
    acct.step(100)
    e100 = acct.epsilon()
    acct.step(100)
    assert acct.epsilon() > e100
    assert acct.epsilon() == pytest.approx(
        compute_epsilon(0.01, 1.0, 200, 1e-5), rel=1e-9
    )


def test_paper_budget_settings_reachable():
    # Paper's budgets: eps 2.0 (GEMINI), 5.6 (pancreas), 0.62 (x-ray).
    for eps in [2.0, 5.6, 0.62]:
        sigma = sigma_for_epsilon(0.01, 300, eps, 1e-5)
        assert 0.3 < sigma < 60.0
