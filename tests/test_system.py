"""End-to-end behaviour: the paper's headline orderings on a scaled-down
GEMINI-like task (Fig. 2 qualitatively):

  collaborative (FL / DeCaPH)  >  silo-local training;
  DeCaPH  ~  FL with a small utility gap, but with epsilon accounted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig,
    run_decaph,
    run_fl,
    run_local,
    normalize_participants,
)
from repro.core.mia import auroc
from repro.data import make_gemini_like
from repro.data.partition import train_test_split_silos
from repro.models.tabular import make_mlp_classifier


@pytest.fixture(scope="module")
def gemini_setup():
    silos = make_gemini_like(seed=0, n_total=4000)
    silos = normalize_participants(silos)
    train, tx, ty = train_test_split_silos(silos, 0.2, seed=0)
    model = make_mlp_classifier([436, 64, 16, 1], "binary")
    return train, tx, ty, model


def _auc(model, params, tx, ty):
    scores = np.asarray(model.predict_fn(params, jnp.asarray(tx)))
    return auroc(scores, ty.astype(np.int32))


def test_collaboration_beats_local(gemini_setup):
    from repro.core.accountant import sigma_for_epsilon

    train, tx, ty, model = gemini_setup
    rate = 128 / sum(len(p) for p in train)
    sigma = sigma_for_epsilon(rate, 60, 4.0, 1e-5)  # self-calibrated (paper)
    cfg = FederationConfig(
        rounds=60, batch_size=128, lr=0.5, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=sigma, microbatch_size=16),
        epsilon_budget=4.0,
    )
    fl = run_fl(model, train, cfg)
    decaph = run_decaph(model, train, cfg)
    local = run_local(
        model, train,
        FederationConfig(rounds=60, batch_size=64, lr=0.5, seed=0),
    )
    auc_fl = _auc(model, fl.params, tx, ty)
    auc_dc = _auc(model, decaph.params, tx, ty)
    local_aucs = [_auc(model, p, tx, ty) for p in local.per_client_params]
    # the paper's qualitative ordering (Fig 2c)
    assert auc_fl > np.mean(local_aucs) + 0.02, (auc_fl, local_aucs)
    assert auc_dc > np.mean(local_aucs) + 0.02, (auc_dc, local_aucs)
    assert auc_dc > max(local_aucs) - 0.05
    # DeCaPH close to FL (paper: <3.2% drop; allow slack at this tiny scale)
    assert auc_dc > auc_fl - 0.10, (auc_dc, auc_fl)
    assert 0 < decaph.epsilon <= 4.05
