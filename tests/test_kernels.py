"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ghost_norm.kernel import ghost_norm_pallas
from repro.kernels.ghost_norm.ref import ghost_norm_ref

KEY = jax.random.key(42)


def _rand(shape, dtype, k, scale=0.5):
    return (scale * jax.random.normal(jax.random.fold_in(KEY, k), shape)).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,din,dout,bs,bt",
    [
        (2, 64, 32, 16, 32, 32),
        (1, 96, 48, 48, 32, 64),   # padding path (96 % 64 != 0)
        (3, 128, 64, 8, 128, 128),
        (2, 32, 16, 16, 64, 64),   # blocks larger than seq
    ],
)
def test_ghost_norm_sweep(b, s, din, dout, bs, bt, dtype):
    a = _rand((b, s, din), dtype, 1)
    g = _rand((b, s, dout), dtype, 2, scale=0.1)
    ref = ghost_norm_ref(a, g)
    out = ghost_norm_pallas(a, g, block_s=bs, block_t=bt, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,d,causal,window",
    [
        (1, 128, 4, 2, 32, True, None),
        (2, 128, 4, 4, 64, True, 32),
        (1, 256, 8, 2, 32, False, None),
        (1, 128, 2, 1, 128, True, None),   # MQA
    ],
)
def test_flash_attention_sweep(b, s, h, kv, d, causal, window, dtype):
    q = _rand((b, s, h, d), dtype, 1)
    k = _rand((b, s, kv, d), dtype, 2)
    v = _rand((b, s, kv, d), dtype, 3, scale=1.0)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(out, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,kv,d,index,window,bk",
    [
        (2, 256, 4, 2, 32, 100, None, 128),
        (1, 512, 8, 8, 64, 511, None, 256),
        (2, 256, 4, 1, 32, 200, 64, 64),
        (1, 1024, 4, 4, 128, 0, None, 512),   # first token
    ],
)
def test_decode_attention_sweep(b, l, h, kv, d, index, window, bk, dtype):
    q = _rand((b, 1, h, d), dtype, 1)
    k = _rand((b, l, kv, d), dtype, 2)
    v = _rand((b, l, kv, d), dtype, 3, scale=1.0)
    ref = decode_attention_ref(q, k, v, index, window=window)
    out = decode_attention_pallas(q, k, v, index, window=window,
                                  block_k=bk, interpret=True)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(out, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize(
    "b,s,din,dout,block",
    [
        (2, 96, 48, 16, 64),    # pad branch: 96 % 64 != 0
        (1, 100, 32, 48, 64),   # pad branch, non-square d_in != d_out
        (3, 130, 16, 8, 32),    # pad branch, multiple tiles before the pad
        (2, 64, 32, 16, 64),    # exact tiling (no pad) for contrast
    ],
)
def test_ghost_norm_dispatch_paths_agree(b, s, din, dout, block):
    """blocked == oracle == Pallas-interpret, including the pad branch.

    The blocked path's ``s % block != 0`` zero-padding and the Pallas
    kernel's own tile padding must both be invisible: zeros contribute
    nothing to the Gram products.
    """
    from repro.kernels.ghost_norm.ops import ghost_norm, ghost_norm_blocked

    a = _rand((b, s, din), jnp.float32, 11)
    g = _rand((b, s, dout), jnp.float32, 12, scale=0.1)
    oracle = ghost_norm_ref(a, g)
    blocked = ghost_norm_blocked(a, g, block=block)
    interp = ghost_norm_pallas(a, g, block_s=block, block_t=block,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(interp), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
    # public dispatch: blocked is the CPU default now, the full-Gram oracle
    # is opt-in — both must agree with the oracle's numbers
    np.testing.assert_allclose(np.asarray(ghost_norm(a, g)),
                               np.asarray(oracle), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(ghost_norm(a, g, prefer_oracle=True)),
        np.asarray(oracle), rtol=1e-6, atol=1e-6)


def test_ghost_norm_matches_outer_product_norms():
    """Cross-check vs literally materialised per-example weight grads."""
    b, s, din, dout = 3, 16, 8, 5
    a = _rand((b, s, din), jnp.float32, 7)
    g = _rand((b, s, dout), jnp.float32, 8)
    explicit = jnp.stack([
        jnp.sum(jnp.square(a[i].T @ g[i])) for i in range(b)
    ])
    out = ghost_norm_pallas(a, g, block_s=8, block_t=8, interpret=True)
    np.testing.assert_allclose(np.asarray(explicit), np.asarray(out), rtol=1e-5)


def test_flash_matches_model_attention_path():
    """Kernel output agrees with the model's einsum attention (GQA)."""
    from repro.models.attention import _sdpa, _causal_mask

    b, s, h, kv, d = 2, 128, 4, 2, 32
    q = _rand((b, s, h, d), jnp.float32, 1)
    k = _rand((b, s, kv, d), jnp.float32, 2)
    v = _rand((b, s, kv, d), jnp.float32, 3)
    model_out = _sdpa(q, k, v, _causal_mask(s, s))
    kernel_out = flash_attention_pallas(q, k, v, causal=True,
                                        block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kernel_out),
                               atol=3e-5)
