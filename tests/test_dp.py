"""DP mechanics: clipping invariants, sensitivity bound, ghost equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import dp as dp_lib
from repro.models.tabular import ghost_clipped_grad_sum_mlp, mlp_init


def _quad_loss(params, ex):
    pred = ex["x"] @ params["w"] + params["b"]
    return jnp.sum((pred - ex["y"]) ** 2)


def _make(batch_size, d, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "x": jnp.asarray(rng.normal(0, 1, (batch_size, d)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(0, 1, (batch_size,)).astype(np.float32)),
    }


@settings(max_examples=15, deadline=None)
@given(
    bs=st.integers(1, 12),
    c=st.floats(0.1, 5.0),
    micro=st.integers(1, 4),
)
def test_clipped_sum_norm_bound(bs, c, micro):
    params = {"w": jnp.ones((4,)) * 3.0, "b": jnp.ones(())}
    batch = _make(bs, 4)
    g, _ = dp_lib.per_example_clipped_grad_sum(
        _quad_loss, params, batch, clip_norm=c, microbatch_size=micro
    )
    norm = float(dp_lib.global_l2_norm(g))
    assert norm <= bs * c * (1 + 1e-5)


def test_sensitivity_bound():
    """Replacing one example changes the clipped sum by at most 2C."""
    params = {"w": jnp.ones((4,)), "b": jnp.zeros(())}
    c = 0.7
    b1 = _make(8, 4, seed=1)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["x"] = b2["x"].at[3].set(100.0)  # adversarial record
    b2["y"] = b2["y"].at[3].set(-50.0)
    g1, _ = dp_lib.per_example_clipped_grad_sum(_quad_loss, params, b1, clip_norm=c)
    g2, _ = dp_lib.per_example_clipped_grad_sum(_quad_loss, params, b2, clip_norm=c)
    diff = jax.tree_util.tree_map(lambda a, b: a - b, g1, g2)
    assert float(dp_lib.global_l2_norm(diff)) <= 2 * c * (1 + 1e-5)


def test_mask_zeroes_padded_examples():
    params = {"w": jnp.ones((4,)), "b": jnp.zeros(())}
    batch = _make(8, 4)
    mask = jnp.asarray([1, 1, 1, 0, 0, 0, 0, 0], jnp.float32)
    g_mask, _ = dp_lib.per_example_clipped_grad_sum(
        _quad_loss, params, batch, clip_norm=1.0, mask=mask
    )
    small = {k: v[:3] for k, v in batch.items()}
    g_small, _ = dp_lib.per_example_clipped_grad_sum(
        _quad_loss, params, small, clip_norm=1.0
    )
    for a, b in zip(jax.tree_util.tree_leaves(g_mask),
                    jax.tree_util.tree_leaves(g_small)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_noise_shares_compose():
    """Sum of H shares ~ N(0, (C sigma)^2): check variance statistically."""
    template = {"w": jnp.zeros((2000,))}
    c, sigma, h = 1.5, 2.0, 8
    key = jax.random.key(0)
    total = jnp.zeros((2000,))
    for i in range(h):
        nz = dp_lib.noise_share(
            jax.random.fold_in(key, i), template,
            clip_norm=c, noise_multiplier=sigma, n_shares=h,
        )
        total = total + nz["w"]
    emp_std = float(jnp.std(total))
    assert emp_std == pytest.approx(c * sigma, rel=0.1)


def test_ghost_norms_match_vmap_grads():
    """Ghost norms for an MLP == true per-example grad norms."""
    sizes = [10, 16, 8, 1]
    key = jax.random.key(0)
    params = mlp_init(key, sizes)
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(0, 1, (12, 10)).astype(np.float32)),
        "y": jnp.asarray((rng.random(12) > 0.5).astype(np.float32)),
    }

    from repro.models.tabular import make_mlp_classifier

    model = make_mlp_classifier(sizes, "binary")

    def one_norm(ex):
        g = jax.grad(model.loss_fn)(params, ex)
        return dp_lib.global_l2_norm(g)

    true_norms = jax.vmap(one_norm)(batch)
    _, ghost_norms = ghost_clipped_grad_sum_mlp(
        params, batch, sizes, "binary", clip_norm=1.0
    )
    np.testing.assert_allclose(
        np.asarray(true_norms), np.asarray(ghost_norms), rtol=2e-4
    )


def test_ghost_clipped_grads_match_vmap_clip():
    sizes = [6, 12, 4]
    key = jax.random.key(1)
    params = mlp_init(key, sizes)
    rng = np.random.default_rng(1)
    batch = {
        "x": jnp.asarray(rng.normal(0, 2, (10, 6)).astype(np.float32)),
        "y": jnp.asarray(rng.integers(0, 4, 10).astype(np.int32)),
    }
    from repro.models.tabular import make_mlp_classifier

    model = make_mlp_classifier(sizes, "multiclass")
    c = 0.5
    g_ref, _ = dp_lib.per_example_clipped_grad_sum(
        model.loss_fn, params, batch, clip_norm=c, microbatch_size=5
    )
    g_ghost, _ = ghost_clipped_grad_sum_mlp(params, batch, sizes, "multiclass", c)
    for k in g_ref:
        np.testing.assert_allclose(
            np.asarray(g_ref[k]["w"]), np.asarray(g_ghost[k]["w"]),
            atol=3e-5, rtol=1e-3,
        )
        np.testing.assert_allclose(
            np.asarray(g_ref[k]["b"]), np.asarray(g_ghost[k]["b"]),
            atol=3e-5, rtol=1e-3,
        )


def test_ghost_norms_seq_matches_2d_when_seq1():
    a = jnp.asarray(np.random.default_rng(0).normal(0, 1, (5, 1, 7)).astype(np.float32))
    g = jnp.asarray(np.random.default_rng(1).normal(0, 1, (5, 1, 3)).astype(np.float32))
    n_seq = dp_lib.ghost_norms_seq_ref(a, g)
    n_2d = dp_lib.ghost_norms_2d(a[:, 0], g[:, 0])
    np.testing.assert_allclose(np.asarray(n_seq), np.asarray(n_2d), rtol=1e-5)
