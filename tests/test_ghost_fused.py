"""Ghost-clipped fused rounds vs the faithful per-example path (DESIGN.md §12).

The seam contract: for a dense-decoder transformer preset the ghost path
must be a drop-in for ``dp.per_example_clipped_grad_sum`` inside the fused
cohort round-step — same norms (to float32 working precision: the two
algorithms compute ||g_i|| via different contractions, so "exact" means the
float32 tolerance class, rtol 5e-5, not bitwise), same round update within
a documented atol, the exact same privacy accounting (the clipping path
must never touch the accountant or the obs ledger), and the same
one-dispatch-per-round structural contract.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.arms as arms
import repro.obs as obs
from repro.arms import clipping as clipping_lib
from repro.arms import fused
from repro.configs import get_smoke_config
from repro.core.dp import DPConfig
from repro.serve.federation import token_silos, transformer_model

# Round-update tolerance between the two clipping paths: both compute the
# same clipped-grad sum, but ghost reconstitutes it as one factor-weighted
# backward vs the faithful path's per-example microbatch accumulation —
# float32 re-association only, observed ~3e-8 per round at smoke scale.
ROUND_ATOL = 1e-5
NORMS_RTOL = 5e-5


def _model_cfg():
    return dataclasses.replace(get_smoke_config("smollm-360m"),
                               tie_embeddings=False)


def _arm_cfg(**kw):
    base = dict(rounds=3, batch_size=12, lr=0.05, use_secagg=False,
                dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8,
                            microbatch_size=8))
    base.update(kw)
    return arms.ArmConfig(**base)


@pytest.fixture(scope="module")
def lm_setup():
    cfg_m = _model_cfg()
    model = transformer_model(cfg_m)
    silos = token_silos(cfg_m, hospitals=3, n_per=16, seq_len=12, seed=0)
    return cfg_m, model, silos


def test_capability_negotiation(lm_setup):
    cfg_m, model, silos = lm_setup
    assert model.ghost is not None
    assert clipping_lib.resolve(model, _arm_cfg()) == "ghost"
    assert clipping_lib.resolve(model, _arm_cfg(clipping="per-example")) \
        == "per-example"
    # tied embeddings: the head term is only an upper bound -> no capability
    tied = transformer_model(get_smoke_config("smollm-360m"))
    assert tied.ghost is None
    assert clipping_lib.resolve(tied, _arm_cfg()) == "per-example"
    with pytest.raises(ValueError, match="GhostCapability"):
        arms.run("decaph", tied, silos, _arm_cfg(clipping="ghost"))
    with pytest.raises(ValueError, match="clipping mode"):
        clipping_lib.resolve(model, _arm_cfg(clipping="bogus"))


def test_ghost_norms_match_per_example_grads_float32(lm_setup):
    """Ghost norms == vmap(grad) norms for real rows; pad rows norm 0."""
    from repro.core.ghost import ghost_clipped_grad_sum

    cfg_m, model, silos = lm_setup
    params = model.init_fn(jax.random.key(0))
    x = np.concatenate([silos[0].x[:4], np.zeros_like(silos[0].x[:2])])
    y = np.concatenate([silos[0].y[:4], np.zeros_like(silos[0].y[:2])])
    mask = jnp.asarray([1, 1, 1, 1, 0, 0], jnp.float32)
    batch = {"tokens": jnp.asarray(x, jnp.int32),
             "labels": jnp.asarray(y, jnp.int32)}
    _, _, norms = ghost_clipped_grad_sum(cfg_m, params, batch,
                                         clip_norm=1.0, mask=mask)

    def one_norm(ex_x, ex_y):
        g = jax.grad(model.loss_fn)(params, {"x": ex_x, "y": ex_y})
        return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                            for leaf in jax.tree_util.tree_leaves(g)))

    ref = jax.vmap(one_norm)(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(norms[:4]), np.asarray(ref[:4]),
                               rtol=NORMS_RTOL)
    # masked rows carry no cotangent -> pure collector seed -> zero norm
    np.testing.assert_array_equal(np.asarray(norms[4:]), 0.0)


def test_ghost_round_update_matches_faithful(lm_setup):
    cfg_m, model, silos = lm_setup
    rep_g = arms.run("decaph", model, silos, _arm_cfg(clipping="ghost"))
    rep_f = arms.run("decaph", model, silos, _arm_cfg(clipping="per-example"))
    for a, b in zip(jax.tree_util.tree_leaves(rep_g.params),
                    jax.tree_util.tree_leaves(rep_f.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=ROUND_ATOL)
    assert rep_g.rounds_completed == rep_f.rounds_completed
    # accounting is clipping-path independent — exactly equal, not approx
    assert rep_g.epsilon == rep_f.epsilon


def test_ledger_epsilon_identical_across_clipping_paths(lm_setup):
    cfg_m, model, silos = lm_setup

    def ledger_rows(mode):
        with obs.recording() as rec:
            arms.run("decaph", model, silos, _arm_cfg(clipping=mode))
            return [(e["round"], e["hospital"], e["eps"])
                    for e in rec.ledger.entries()]

    ghost_rows = ledger_rows("ghost")
    faithful_rows = ledger_rows("per-example")
    assert ghost_rows and ghost_rows == faithful_rows


def test_ghost_fused_round_is_one_dispatch(lm_setup):
    """Marginal dispatches/round == exactly 1 on the ghost fused path."""
    cfg_m, model, silos = lm_setup

    def dispatches(rounds):
        fused.reset_jit_dispatches()
        arms.run("decaph", model, silos,
                 _arm_cfg(rounds=rounds, clipping="ghost"))
        return fused.jit_dispatches()

    d2, d5 = dispatches(2), dispatches(5)
    assert (d5 - d2) == 3  # 1 dispatch per marginal round, exactly
