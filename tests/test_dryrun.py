"""Dry-run smoke (subprocess: needs its own XLA_FLAGS before jax init).

Full production meshes (16x16 and 2x16x16) are exercised by
``python -m repro.launch.dryrun --all`` (artifacts in benchmarks/artifacts);
these tests prove the same programs lower + compile on debug meshes with 8
placeholder devices, including a multi-pod (2,2,2) mesh, quickly enough for
CI.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax
from repro.launch.dryrun import run_one

arch, shape, multipod = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
mesh = (jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multipod
        else jax.make_mesh((4, 2), ("data", "model")))
rec = run_one(arch, shape, mesh=mesh, out_dir="/tmp/repro_dryrun_test")
print("RESULT::" + json.dumps({
    "flops": rec["corrected_flops"],
    "coll": rec["collective_bytes"],
    "bottleneck": rec["roofline"]["bottleneck"],
    "ratio": rec["useful_flops_ratio"],
}))
"""


def _run(arch, shape, multipod=False, timeout=520):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, shape, "1" if multipod else "0"],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][0]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
def test_dryrun_train_single_pod():
    rec = _run("smollm-360m", "train_4k")
    assert rec["flops"] > 1e14
    assert rec["coll"] > 0          # the DeCaPH secure-sum collectives exist
    # MODEL_FLOPS/HLO ratio: attention + DP overhead push it well below 1 on
    # small-d models; just assert it is a sane fraction.
    assert 0.005 < rec["ratio"] < 5.0


@pytest.mark.slow
def test_dryrun_train_multi_pod():
    rec = _run("olmo-1b", "train_4k", multipod=True)
    assert rec["flops"] > 1e14
    assert rec["coll"] > 0


@pytest.mark.slow
def test_dryrun_decode_long_context_ssm():
    rec = _run("rwkv6-3b", "long_500k")
    assert rec["flops"] > 1e8


@pytest.mark.slow
def test_dryrun_decode_whisper():
    rec = _run("whisper-small", "decode_32k")
    assert rec["flops"] > 1e8


@pytest.mark.slow
def test_dryrun_moe_prefill():
    rec = _run("qwen3-moe-30b-a3b", "prefill_32k")
    assert rec["coll"] > 0          # expert all-to-alls / gathers present
