"""Roofline report: reads the dry-run artifacts and emits the per
(arch x shape x mesh) three-term roofline table (EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(pattern: str = "*.json") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(fast: bool = True) -> list[dict]:
    rows = []
    for rec in load_records():
        if rec.get("tag"):
            continue  # perf-iteration artifacts reported in EXPERIMENTS.md
        r = rec["roofline"]
        rows.append({
            "name": f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}",
            "us_per_call": rec.get("compile_s", 0) * 1e6,
            "derived": (
                f"compute_s={r['compute_s']:.3e};"
                f"memory_s={r['memory_s']:.3e};"
                f"collective_s={r['collective_s']:.3e};"
                f"bottleneck={r['bottleneck']};"
                f"useful_ratio={rec.get('useful_flops_ratio') and round(rec['useful_flops_ratio'], 3)}"
            ),
        })
    if not rows:
        rows.append({
            "name": "roofline_missing",
            "us_per_call": 0.0,
            "derived": "run `python -m repro.launch.dryrun --all` first",
        })
    return rows


def markdown_table(records: list[dict]) -> str:
    """Full §Roofline markdown table (used to generate EXPERIMENTS.md)."""
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bottleneck | MODEL_FLOPS | HLO FLOPs | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        r = rec["roofline"]
        ratio = rec.get("useful_flops_ratio")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['bottleneck']}** "
            f"| {rec['model_flops']:.3e} | {rec['corrected_flops']:.3e} "
            f"| {ratio:.3f} |" if ratio else
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['bottleneck']}** "
            f"| {rec['model_flops']:.3e} | {rec['corrected_flops']:.3e} | - |"
        )
    return "\n".join(lines)
