"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs paper-scale sizes
(hours); the default fast mode validates every claim at reduced scale.
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    ("gemini_utility", "Fig 2c / Supp T4-5: GEMINI mortality (4 arms)"),
    ("pancreas_utility", "Fig 3c / Supp T6-7: pancreas cell typing (4 arms)"),
    ("xray_utility", "Fig 4c / Supp T8: chest radiology (4 arms)"),
    ("mia", "Fig 5: LiRA membership inference, FL vs DeCaPH"),
    ("secagg_cost", "Supp Fig 1 / Supp T1: SecAgg wall-clock + comm"),
    ("sim_report", "Systems: 5 arms on a heterogeneous trace + dropout recovery"),
    ("hotpath", "Systems: fused round-step vs loop (wall/round + dispatches)"),
    ("pate_ablation", "Supp (Existing frameworks): PATE vs DeCaPH ablation"),
    ("accountant_table", "Methods: RDP accounting for the paper's budgets"),
    ("kernel_bench", "Kernels: oracle timings + traffic ratios"),
    ("roofline_report", "Systems: roofline terms from dry-run artifacts"),
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true", help="paper-scale sizes")
    p.add_argument("--only", default=None,
                   help="comma-separated module names to run")
    args = p.parse_args()

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for mod_name, desc in MODULES:
        if only and mod_name not in only:
            continue
        print(f"# {mod_name}: {desc}", file=sys.stderr)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(fast=not args.full)
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            sys.stdout.flush()
        except Exception as e:
            traceback.print_exc(limit=6, file=sys.stderr)
            print(f"{mod_name}_FAILED,0,{type(e).__name__}:{e}")
            failed.append(mod_name)
    if failed:
        print(f"# FAILED modules: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
