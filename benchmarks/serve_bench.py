"""Serving-tier benchmark: open-loop traffic sweep + dispatch-count contract.

For each arrival rate the sweep replays a seeded Poisson schedule
(``repro.serve.traffic``) against a fresh ``ServeEngine`` while federation
checkpoints land mid-stream, and reports throughput, p50/p99 TTFT and
per-token latency, slot occupancy, and checkpoint freshness — the
utility-vs-epsilon-vs-freshness artifact (``BENCH_serve.json`` +
``BENCH_serve.md``, both committed).

Two structural contracts are ASSERTED (CI serve-smoke job):

  * **O(1) steady-state dispatch**: with every slot busy and no admissions,
    N decode steps are exactly N program launches — measured with the
    process-global ``instrumented_jit`` counter, the same meter DESIGN.md
    §7 pins on fused training rounds.  Additionally the whole traffic
    replay must launch exactly ``decode_steps + admit_dispatches``
    programs: continuous batching adds ZERO hidden dispatches.
  * **mid-stream hot swap**: a checkpoint published while slots are
    decoding is picked up (``swaps >= 1``) and every in-flight generation
    still completes its full budget.

Publish modes: ``--smoke`` publishes inline between decode steps
(single-threaded, deterministic — perturbed copies of the serving params);
the full sweep runs a REAL federation trainer thread per rate
(``repro.serve.federation.train_and_publish``, fl arm on the ideal
backend) so the freshness columns reflect actual round cadence.

``python benchmarks/serve_bench.py`` writes the committed artifacts;
``--smoke`` shrinks shapes and asserts the contracts above.
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import threading

import jax
import numpy as np

from repro.instrument import jit_dispatches, reset_jit_dispatches
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.handoff import CheckpointPublisher, CheckpointWatcher
from repro.serve.metrics import render_markdown, summarize
from repro.serve.traffic import (
    Request,
    TrafficConfig,
    generate_requests,
    run_open_loop,
)

ARCH = "smollm-360m"


def _engine(slots: int, max_len: int, seed: int = 0) -> ServeEngine:
    return ServeEngine(ServeConfig(
        arch=ARCH, slots=slots, max_len=max_len, temperature=1.0, seed=seed,
    ))


def steady_state_contract(slots: int, max_len: int, n_steps: int = 20) -> dict:
    """The dispatch-count + hot-swap invariant, measured in isolation.

    Fills every slot, then: (a) ``n_steps`` decode steps must be EXACTLY
    ``n_steps`` program launches on the global ``instrumented_jit`` meter;
    (b) a checkpoint published mid-segment hot-swaps without costing a
    launch or dropping an in-flight generation.
    """
    engine = _engine(slots, max_len)
    budget = max_len - 8 - 1  # outlive the segment: nobody evicts mid-test
    reqs = [
        Request(rid=i, arrival=0.0,
                prompt=np.full((8,), 7 + i, np.int32),
                max_new_tokens=budget)
        for i in range(slots)
    ]
    for r in reqs:
        finished = engine.admit(r)
        assert not finished, "steady-state request must outlive admission"
    with tempfile.TemporaryDirectory() as d:
        pub = CheckpointPublisher(d)
        watcher = CheckpointWatcher(d)
        swapped_at = n_steps // 2
        reset_jit_dispatches()
        for t in range(n_steps):
            done = engine.step()
            assert not done, "no eviction may occur inside the segment"
            if t == swapped_at:
                # publish + poll between steps — the hot-swap path; the
                # publish itself is host-side msgpack, zero device launches
                pub.publish(0, jax.tree_util.tree_map(
                    lambda x: x * 0.999, engine.params))
                assert engine.poll_watcher(watcher), "swap must land"
        launches = jit_dispatches()
    assert launches == n_steps, (
        f"steady-state contract violated: {n_steps} decode steps took "
        f"{launches} program launches (expected exactly {n_steps})"
    )
    assert engine.swaps == 1 and engine.serving_round == 0
    for r in reqs:
        # in-flight generations crossed the swap intact: every step
        # appended a token to every slot
        assert len(r.tokens) == 1 + n_steps
    return {"steps": n_steps, "launches": launches, "swaps": engine.swaps}


def _inline_publisher(engine: ServeEngine, pub: CheckpointPublisher,
                      every: int):
    """Deterministic smoke-mode publisher: every ``every``-th decode step
    publishes a perturbed copy of the serving params as the next round."""
    state = {"round": 0}

    def on_step(step_idx: int) -> None:
        if step_idx % every == every - 1:
            pub.publish(state["round"], jax.tree_util.tree_map(
                lambda x: x * 0.999, engine.params))
            state["round"] += 1

    return on_step


def measure_rate(rate: float, *, slots: int, max_len: int, requests: int,
                 smoke: bool, seed: int = 0) -> dict:
    engine = _engine(slots, max_len, seed=seed)
    tcfg = TrafficConfig(rate=rate, n_requests=requests,
                         vocab_size=engine.model_cfg.vocab_size, seed=seed)
    reqs = generate_requests(tcfg)
    with tempfile.TemporaryDirectory() as d:
        watcher = CheckpointWatcher(d)
        on_step, trainer = None, None
        if smoke:
            on_step = _inline_publisher(engine, CheckpointPublisher(d),
                                        every=5)
        else:
            from repro.serve.federation import train_and_publish

            # paced: at smoke scale a round is sub-ms, so without pacing
            # the watcher would only ever see the final round land
            trainer = threading.Thread(
                target=train_and_publish,
                args=("fl", engine.model_cfg, d),
                kwargs={"rounds": 6, "seed": seed, "pace_s": 0.5},
                daemon=True,
            )
            trainer.start()
        reset_jit_dispatches()
        result = run_open_loop(engine, reqs, watcher=watcher,
                               poll_interval=0.02, on_step=on_step)
        launches = jit_dispatches()
        if trainer is not None:
            trainer.join(timeout=120.0)
    row = summarize(result, slots=slots, rate=rate, extra={
        "publish_mode": "inline" if smoke else "federation-thread",
    })
    if smoke:
        # the trainer thread shares the global meter in full mode, so the
        # zero-hidden-dispatch ledger is only checkable inline
        expected = result.decode_dispatches + result.admit_dispatches
        assert launches == expected, (
            f"rate {rate}: traffic replay launched {launches} programs, "
            f"ledger says {expected} (decode + admit) — hidden dispatches"
        )
        assert row["dispatches_per_step"] == 1.0, row
        assert row["swaps"] >= 1, f"rate {rate}: no mid-stream hot swap"
        incomplete = [
            r for r in result.completed
            if len(r.tokens) < min(r.max_new_tokens,
                                   max_len - len(r.prompt))
            and (engine.cfg.eos_id is None
                 or engine.cfg.eos_id not in r.tokens)
        ]
        assert not incomplete, (
            f"rate {rate}: {len(incomplete)} generations dropped tokens "
            "across a hot swap"
        )
    return row


def collect(rates, *, slots: int, max_len: int, requests: int, smoke: bool,
            progress=lambda m: None) -> dict:
    contract = steady_state_contract(slots, max_len)
    progress(f"steady-state contract: {contract['steps']} steps = "
             f"{contract['launches']} launches, {contract['swaps']} swap")
    rows = []
    for rate in rates:
        row = measure_rate(rate, slots=slots, max_len=max_len,
                           requests=requests, smoke=smoke)
        rows.append(row)
        progress(f"rate {rate:6.1f} q/s: {row['throughput_tok_s']:8.1f} tok/s"
                 f"  TTFT p99 {row['ttft_p99_ms']:9.1f} ms"
                 f"  occ {row['occupancy']:.2f}  swaps {row['swaps']}"
                 f"  stale(mean) {row['staleness_rounds_mean']}")
    return {
        "arch": ARCH,
        "scale": "smoke",
        "slots": slots,
        "max_len": max_len,
        "n_requests": requests,
        "publish_mode": "inline" if smoke else "federation-thread",
        "steady_state": contract,
        "rows": rows,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes; assert the dispatch + hot-swap "
                        "contracts; inline (single-threaded) publishing")
    p.add_argument("--out", default="BENCH_serve.json")
    p.add_argument("--md", default=None,
                   help="markdown report path (default: --out with .md)")
    p.add_argument("--rates", type=float, nargs="+",
                   default=[2.0, 8.0, 32.0])
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=96)
    p.add_argument("--requests", type=int, default=40)
    args = p.parse_args(argv)

    if args.smoke:
        args.rates, args.slots = [4.0, 16.0], 2
        args.max_len, args.requests = 48, 10

    report = collect(args.rates, slots=args.slots, max_len=args.max_len,
                     requests=args.requests, smoke=args.smoke,
                     progress=lambda m: print(m, file=sys.stderr))

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    md_path = args.md or (args.out.rsplit(".", 1)[0] + ".md")
    publish_how = ("inline" if args.smoke
                   else "by a live federation trainer (fl, 6 rounds)")
    preamble = (
        f"Arch `{report['arch']}` (smoke scale), {report['slots']} slots, "
        f"max_len {report['max_len']}, {report['n_requests']} Poisson "
        f"arrivals per rate; checkpoints published {publish_how} and "
        f"hot-swapped mid-stream.  Steady-state contract: "
        f"{report['steady_state']['steps']} decode steps = "
        f"{report['steady_state']['launches']} program launches."
    )
    with open(md_path, "w") as f:
        f.write(render_markdown(
            report["rows"],
            title="BENCH_serve — continuous batching under open-loop traffic",
            preamble=preamble,
        ))
    print(f"wrote {args.out} and {md_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
