"""Kernel timings: Pallas path (interpret on CPU; real on TPU) vs jnp oracle.

On this CPU container the numbers compare the oracle against interpret mode
(a correctness harness, not a speed claim); on TPU the same harness times the
real kernels.  The derived column reports the oracle's HBM-traffic ratio —
the structural reason the kernel wins on TPU (see kernels/*/kernel.py).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ghost_norm.ref import ghost_norm_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(fast: bool = True) -> list[dict]:
    key = jax.random.key(0)
    rows = []

    # ghost_norm: oracle materialises 2 x [B,S,S] Grams in HBM; kernel keeps
    # them in VMEM. traffic ratio = (2 B S^2) / (B S (din + dout)).
    b, s, din, dout = (8, 256, 512, 512) if fast else (16, 1024, 1024, 1024)
    a = jax.random.normal(jax.random.fold_in(key, 1), (b, s, din))
    g = jax.random.normal(jax.random.fold_in(key, 2), (b, s, dout))
    us = _time(jax.jit(ghost_norm_ref), a, g)
    ratio = (2 * s * s) / (s * (din + dout) / 4)
    rows.append({
        "name": f"ghost_norm_oracle_b{b}_s{s}_d{din}",
        "us_per_call": us,
        "derived": f"hbm_gram_traffic_ratio={ratio:.2f}x",
    })

    # flash attention: oracle materialises [B,H,S,S] probs.
    b, s, h, kv, d = (2, 512, 8, 2, 64) if fast else (4, 2048, 16, 4, 128)
    q = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 4), (b, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 5), (b, s, kv, d))
    us = _time(jax.jit(lambda q_, k_, v_: attention_ref(q_, k_, v_)), q, k, v)
    rows.append({
        "name": f"flash_oracle_b{b}_s{s}_h{h}",
        "us_per_call": us,
        "derived": f"scores_hbm_bytes={b*h*s*s*4:.0f};kernel=vmem_only",
    })

    # decode attention at a long KV
    b, l, h, kv, d = (2, 8192, 8, 2, 64) if fast else (8, 32768, 16, 4, 128)
    q = jax.random.normal(jax.random.fold_in(key, 6), (b, 1, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 7), (b, l, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 8), (b, l, kv, d))
    idx = jnp.asarray(l - 1, jnp.int32)
    us = _time(jax.jit(
        lambda q_, k_, v_, i_: decode_attention_ref(q_, k_, v_, i_)
    ), q, k, v, idx)
    cache_gb = b * l * kv * d * 2 * 4 / 1e9
    rows.append({
        "name": f"decode_oracle_b{b}_l{l}",
        "us_per_call": us,
        "derived": f"cache_read_GB={cache_gb:.3f};min_time_at_819GBps="
                   f"{cache_gb/819*1e6:.1f}us",
    })
    return rows
