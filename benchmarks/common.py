"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.arms as arms
from repro.core.dp import DPConfig
from repro.core.mia import auroc
from repro.data.partition import train_test_split_silos


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6  # microseconds


def utility_comparison(model, silos, *, rounds, batch, lr, sigma, clip,
                       eps_budget, seed=0, microbatch=16):
    """Run the paper's four arms (via the arm registry) and return test
    metrics for each.

    sigma=None self-calibrates the noise multiplier so the DP arms can use
    all ``rounds`` within ``eps_budget`` (the paper: "carefully calibrating
    the privacy-related hyperparameters").
    """
    silos = arms.normalize_participants(silos)
    train, tx, ty = train_test_split_silos(silos, 0.2, seed=seed)
    if sigma is None:
        from repro.core.accountant import sigma_for_epsilon

        rate = batch / sum(len(p) for p in train)
        sigma = sigma_for_epsilon(rate, rounds, eps_budget, 1e-5)
    cfg = arms.ArmConfig(
        rounds=rounds, batch_size=batch, lr=lr, seed=seed, use_secagg=False,
        dp=DPConfig(clip_norm=clip, noise_multiplier=sigma,
                    microbatch_size=microbatch),
        epsilon_budget=eps_budget,
    )
    out = {}
    for arm in ("fl", "decaph", "primia", "local"):
        res, t_us = timed(arms.run, arm, model, train, cfg)
        params = res.per_node_params if arm == "local" else res.params
        out[arm] = (params, res.epsilon,
                    t_us / max(res.rounds_completed, 1))
    return out, tx, ty


def binary_auroc(model, params, tx, ty):
    scores = np.asarray(model.predict_fn(params, jnp.asarray(tx)))
    if scores.ndim > 1:
        scores = scores[..., 0]
    return auroc(scores, ty.astype(np.int32))


def multiclass_metrics(model, params, tx, ty, n_classes):
    probs = np.asarray(model.predict_fn(params, jnp.asarray(tx)))
    pred = probs.argmax(-1)
    f1s, ws_p, ws_r, ns = [], 0.0, 0.0, 0
    for c in range(n_classes):
        tp = ((pred == c) & (ty == c)).sum()
        fp = ((pred == c) & (ty != c)).sum()
        fn = ((pred != c) & (ty == c)).sum()
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1s.append(2 * tp / max(2 * tp + fp + fn, 1))
        nc = (ty == c).sum()
        ws_p += nc * prec
        ws_r += nc * rec
        ns += nc
    return {
        "median_f1": float(np.median(f1s)),
        "weighted_precision": float(ws_p / max(ns, 1)),
        "weighted_recall": float(ws_r / max(ns, 1)),
        "accuracy": float((pred == ty).mean()),
    }


def multilabel_auroc(model, params, tx, ty):
    probs = np.asarray(model.predict_fn(params, jnp.asarray(tx)))
    return [auroc(probs[:, j], ty[:, j].astype(np.int32))
            for j in range(ty.shape[1])]
