"""Paper Supplementary ("Existing frameworks"): why not PATE/CaPC?

The paper argues prediction-aggregation frameworks need a public dataset and
many participants; with 3-8 hospitals the noisy-vote margin is tiny and the
privacy cost per labelled example is high.  This ablation measures it: PATE
on the GEMINI-like task vs DeCaPH at comparable ε — supporting the paper's
choice of gradient merging.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import binary_auroc
from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig, normalize_participants, run_decaph, run_pate,
)
from repro.core.accountant import sigma_for_epsilon
from repro.data import make_gemini_like
from repro.data.partition import train_test_split_silos
from repro.models.tabular import make_mlp_classifier


def run(fast: bool = True) -> list[dict]:
    n_total = 4000 if fast else 40114
    rounds = 60 if fast else 400
    silos = normalize_participants(make_gemini_like(seed=0, n_total=n_total))
    train, tx, ty = train_test_split_silos(silos, 0.2, seed=0)
    # PATE needs a public pool: carve 25% of the test split (never used for
    # evaluation) — generous to PATE, as the paper notes such pools rarely
    # exist in healthcare at all.
    n_pub = len(tx) // 4
    pub_x, tx_eval, ty_eval = tx[:n_pub], tx[n_pub:], ty[n_pub:]

    model = make_mlp_classifier([436, 64, 16, 1], "binary")
    rate = 128 / sum(len(p) for p in train)
    sigma = sigma_for_epsilon(rate, rounds, 4.0, 1e-5)
    cfg = FederationConfig(
        rounds=rounds, batch_size=128, lr=0.5, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=sigma, microbatch_size=16),
        epsilon_budget=4.0,
    )
    rows = []
    t0 = time.time()
    dc = run_decaph(model, train, cfg)
    auc_dc = binary_auroc(model, dc.params, tx_eval, ty_eval)
    rows.append({
        "name": "pate_ablation_decaph",
        "us_per_call": (time.time() - t0) * 1e6 / rounds,
        "derived": f"auroc={auc_dc:.4f};eps={dc.epsilon:.2f}",
    })
    for gsigma in (2.0, 8.0):
        t0 = time.time()
        pate = run_pate(model, train, cfg, public_x=pub_x, n_classes=2,
                        gnmax_sigma=gsigma)
        auc_p = binary_auroc(model, pate.params, tx_eval, ty_eval)
        rows.append({
            "name": f"pate_ablation_pate_sigma{gsigma:g}",
            "us_per_call": (time.time() - t0) * 1e6 / rounds,
            "derived": f"auroc={auc_p:.4f};eps={pate.epsilon:.2f}",
        })
    rows.append({
        "name": "pate_ablation_claim",
        "us_per_call": 0.0,
        "derived": "paper_argument_supported:"
                   f"{auc_dc > auc_p or pate.epsilon > dc.epsilon}",
    })
    return rows
