"""Privacy accounting table (paper Methods / Experimental Setup).

Reproduces the paper's budget settings: the sigma needed for eps = 2.0
(GEMINI), 5.6 (pancreas), 0.62 (X-ray) at representative sampling rates and
round counts, plus eps-vs-steps curves — all from our RDP(SGM) accountant
(replacing Opacus).
"""

from __future__ import annotations

import time

from repro.core.accountant import compute_epsilon, sigma_for_epsilon

PAPER_SETTINGS = [
    # (task, target_eps, sample_rate, rounds)
    ("gemini", 2.0, 128 / 32000, 400),
    ("pancreas", 5.6, 96 / 8400, 300),
    ("xray", 0.62, 48 / 1400, 120),
]


def run(fast: bool = True) -> list[dict]:
    rows = []
    for task, eps, p, steps in PAPER_SETTINGS:
        t0 = time.time()
        sigma = sigma_for_epsilon(p, steps, eps, 1e-5)
        us = (time.time() - t0) * 1e6
        check = compute_epsilon(p, sigma, steps, 1e-5)
        rows.append({
            "name": f"accountant_sigma_for_{task}",
            "us_per_call": us,
            "derived": f"target_eps={eps};sigma={sigma:.4f};check_eps={check:.4f}",
        })
    # composition curve
    for steps in (10, 100, 1000):
        t0 = time.time()
        e = compute_epsilon(0.01, 1.0, steps, 1e-5)
        rows.append({
            "name": f"accountant_eps_T{steps}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"p=0.01;sigma=1.0;eps={e:.4f}",
        })
    return rows
