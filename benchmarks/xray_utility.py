"""Paper Fig. 4c / Supp. Table 8: chest-radiology pathology identification.

Fast mode uses eps=3.0: the paper trains at eps=0.62 on 268k images; at the
fast-mode 900-image scale that budget admits no learning signal (documented
scale substitution — --full restores eps=0.62 at the larger size).

3 studies, 4 multilabel outputs, mini-DenseNet (BN-free, as DP-SGD requires),
eps = 0.62 for the DP arms.  Reports per-label AUROC for each arm.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import multilabel_auroc, utility_comparison
from repro.data import make_xray_like
from repro.models.tabular import DenseNetConfig, make_densenet

LABELS = ["atelectasis", "effusion", "cardiomegaly", "no_finding"]


def _pretrain(model, size: int, n: int, steps: int, lr: float = 0.1):
    """Paper setup: the DenseNet is pre-trained (on MIMIC-CXR) before the
    collaborative run.  Stand-in: a disjoint synthetic study (seed 99)."""
    import jax
    import jax.numpy as jnp

    pre = make_xray_like(seed=99, n_total=n, image_size=size)
    x = np.concatenate([p.x for p in pre])
    y = np.concatenate([p.y for p in pre])
    params = model.init_fn(jax.random.key(7))

    @jax.jit
    def step(params, bx, by):
        def mean_loss(p):
            return jnp.mean(jax.vmap(
                lambda ex: model.loss_fn(p, ex))({"x": bx, "y": by}))

        g = jax.grad(mean_loss)(params)
        return jax.tree_util.tree_map(lambda p_, g_: p_ - lr * g_, params, g)

    rng = np.random.default_rng(7)
    for _ in range(steps):
        idx = rng.choice(len(x), 48)
        params = step(params, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
    return params


def run(fast: bool = True) -> list[dict]:
    size = 16 if fast else 32
    n_total = 900 if fast else 4000
    rounds = 120 if fast else 240
    silos = make_xray_like(seed=0, n_total=n_total, image_size=size)
    base_model = make_densenet(DenseNetConfig(
        growth=8, blocks=(2, 2), init_channels=8, image_size=size
    ))
    pretrained = _pretrain(base_model, size, n_total, 250 if fast else 600)
    from repro.core.federation import Model

    # every arm starts from the same pre-trained state (paper Fig 4 setup)
    model = Model(lambda key: pretrained, base_model.loss_fn,
                  base_model.predict_fn)
    out, tx, ty = utility_comparison(
        model, silos, rounds=rounds, batch=48, lr=0.1,
        sigma=None, clip=0.5, eps_budget=(3.0 if fast else 0.62), microbatch=8,
    )
    rows = []
    mets = {}
    for arm in ("fl", "decaph", "primia"):
        params, eps, us = out[arm]
        aucs = multilabel_auroc(model, params, tx, ty)
        mets[arm] = float(np.mean(aucs))
        rows.append({
            "name": f"xray_densenet_{arm}",
            "us_per_call": us,
            "derived": ";".join(
                f"{l}={a:.3f}" for l, a in zip(LABELS, aucs)
            ) + f";eps={eps:.2f}",
        })
    local_params, _, us = out["local"]
    local_mean = float(np.mean([
        np.mean(multilabel_auroc(model, p, tx, ty)) for p in local_params
    ]))
    rows.append({
        "name": "xray_densenet_local",
        "us_per_call": us,
        "derived": f"mean_auroc={local_mean:.4f}",
    })
    rows.append({
        "name": "xray_densenet_claim",
        "us_per_call": 0.0,
        "derived": (
            f"decaph_mean={mets['decaph']:.4f};"
            f"drop_vs_fl={(mets['fl'] - mets['decaph']):.4f};"
            f"decaph>=primia:{mets['decaph'] >= mets['primia'] - 0.02}"
        ),
    })
    return rows
