"""The training hot path: per-round wall time + jit dispatches, loop vs fused.

Starts the perf trajectory for the round hot path (DESIGN.md §7): for each
cohort size H, run the same arm/config through the legacy per-participant
contribution loop (``fused_rounds=False``) and the fused cohort round-step
(default), and report

  * marginal wall-clock per round — measured as
    ``(T(r_hi) - T(r_lo)) / (r_hi - r_lo)`` over two fresh runs, so one-time
    costs (jit compilation, arm construction, leader-schedule setup) cancel
    and the number is the steady-state per-round cost;
  * jit program launches per round, from the ``instrumented_jit`` counter in
    ``repro.arms.fused`` — O(H) on the loop path, O(1) on the fused path.

``python benchmarks/hotpath.py`` writes ``BENCH_hotpath.json`` (the
committed artifact).  ``--smoke`` runs tiny shapes and *asserts* the fused
path's dispatch count is O(1) per round — the CI perf-smoke job's contract.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import repro.arms as arms
from repro.arms import fused
from repro.core.dp import DPConfig
from repro.data.synthetic import make_gemini_like
from repro.models.tabular import linear_model

# the small tabular preset (scenarios preset "gemini/small": 32-feature
# linear model), sized so every silo draws a real Poisson batch each round
FEATURES = 32
EXAMPLES_PER_SILO = 240


def _make_setup(h: int, seed: int = 0):
    silos = arms.normalize_participants(
        make_gemini_like(seed=seed, n_total=EXAMPLES_PER_SILO * h,
                         n_silos=h, n_features=FEATURES)
    )
    return linear_model(FEATURES), silos


def _cfg(rounds: int, use_secagg: bool, fused_rounds: bool) -> arms.ArmConfig:
    return arms.ArmConfig(
        rounds=rounds, batch_size=64, lr=0.3, seed=0,
        use_secagg=use_secagg, fused_rounds=fused_rounds,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
    )


def _run_once(arm: str, model, silos, cfg) -> tuple[float, int, int]:
    """(wall seconds, jit dispatches, rounds completed) for one fresh run."""
    fused.reset_jit_dispatches()
    t0 = time.perf_counter()
    rep = arms.run(arm, model, silos, cfg)
    dt = time.perf_counter() - t0
    return dt, fused.jit_dispatches(), rep.rounds_completed


def measure(arm: str, h: int, *, use_secagg: bool, fused_rounds: bool,
            r_lo: int, r_hi: int, repeats: int) -> dict:
    """Marginal per-round wall/dispatch cost for one (arm, H, path) cell."""
    model, silos = _make_setup(h)
    # compile warmup: a fresh arm per run re-traces, so prime the XLA-level
    # caches for both round counts before timing
    _run_once(arm, model, silos, _cfg(2, use_secagg, fused_rounds))
    walls, disps = [], []
    for _ in range(repeats):
        t_lo, d_lo, n_lo = _run_once(
            arm, model, silos, _cfg(r_lo, use_secagg, fused_rounds))
        t_hi, d_hi, n_hi = _run_once(
            arm, model, silos, _cfg(r_hi, use_secagg, fused_rounds))
        if n_hi <= n_lo:
            raise RuntimeError(f"{arm} H={h}: no marginal rounds measured")
        walls.append((t_hi - t_lo) / (n_hi - n_lo))
        disps.append((d_hi - d_lo) / (n_hi - n_lo))
    # interference only ever ADDS time: a stall in the short run drives a
    # marginal negative, in the long run inflates it.  Drop the impossible
    # (non-positive) samples and keep the least-interfered one — the
    # standard min-of-repeats timing estimator, applied to marginals.  If
    # every repeat was corrupted, record the cell as unmeasured (null)
    # rather than fabricating a number.
    positive = sorted(w for w in walls if w > 0)
    return {
        "arm": arm,
        "hospitals": h,
        "use_secagg": use_secagg,
        "path": "fused" if fused_rounds else "loop",
        "wall_per_round_s": positive[0] if positive else None,
        "dispatches_per_round": min(disps),
    }


CELLS = [  # (arm, use_secagg) — the round arms the fused path covers
    ("decaph", True),
    ("decaph", False),
    ("fl", False),
    ("fedprox", False),
]


def collect(hs: list[int], r_lo: int, r_hi: int, repeats: int,
            progress=lambda msg: None) -> dict:
    rows = []
    for h in hs:
        for arm, secagg in CELLS:
            for fused_rounds in (False, True):
                row = measure(arm, h, use_secagg=secagg,
                              fused_rounds=fused_rounds,
                              r_lo=r_lo, r_hi=r_hi, repeats=repeats)
                rows.append(row)
                wall = row["wall_per_round_s"]
                progress(
                    f"{arm:8s} H={h:<3d} secagg={str(secagg):5s} "
                    f"{row['path']:5s} "
                    + (f"{wall*1e3:8.2f} ms/round" if wall is not None
                       else "  (unmeasured: interference)")
                    + f" {row['dispatches_per_round']:6.1f} disp/round"
                )
    speedups = {}
    for h in hs:
        for arm, secagg in CELLS:
            pair = {
                r["path"]: r for r in rows
                if r["arm"] == arm and r["hospitals"] == h
                and r["use_secagg"] == secagg
            }
            key = f"{arm}{'-secagg' if secagg else ''}-h{h}"
            f_wall = pair["fused"]["wall_per_round_s"]
            l_wall = pair["loop"]["wall_per_round_s"]
            speedups[key] = {
                # null when either side went unmeasured — never fabricated
                "speedup": (l_wall / f_wall
                            if f_wall is not None and l_wall is not None
                            else None),
                "loop_dispatches": pair["loop"]["dispatches_per_round"],
                "fused_dispatches": pair["fused"]["dispatches_per_round"],
            }
    return {
        "preset": "small-tabular (gemini/small: 32-feature linear model)",
        "rounds_marginal": [r_lo, r_hi],
        "repeats": repeats,
        "rows": rows,
        "speedups": speedups,
    }


def run(fast: bool = True) -> list[dict]:
    """benchmarks/run.py entry point."""
    hs = [5, 10] if fast else [5, 10, 20]
    report = collect(hs, r_lo=3, r_hi=9 if fast else 15, repeats=1,
                     progress=lambda m: print(m, file=sys.stderr))
    return [
        {
            "name": (f"hotpath_{r['arm']}_h{r['hospitals']}"
                     f"{'_secagg' if r['use_secagg'] else ''}_{r['path']}"),
            "us_per_call": (r["wall_per_round_s"] or 0.0) * 1e6,
            "derived": f"dispatches_per_round={r['dispatches_per_round']:.1f}",
        }
        for r in report["rows"]
    ]


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes; assert fused dispatches are O(1)")
    p.add_argument("--out", default="BENCH_hotpath.json")
    p.add_argument("--hospitals", type=int, nargs="+",
                   default=[5, 10, 20])
    p.add_argument("--rounds", type=int, nargs=2, default=[10, 50],
                   metavar=("R_LO", "R_HI"))
    p.add_argument("--repeats", type=int, default=5)
    args = p.parse_args(argv)

    if args.smoke:
        args.hospitals, args.rounds, args.repeats = [4], [2, 6], 1

    report = collect(args.hospitals, r_lo=args.rounds[0],
                     r_hi=args.rounds[1], repeats=args.repeats,
                     progress=lambda m: print(m, file=sys.stderr))

    failures = []
    for key, s in report["speedups"].items():
        # the structural contract, asserted even in --smoke: a fused round
        # is ONE cohort program launch, a loop round is >= H of them
        if s["fused_dispatches"] > 2.0:
            failures.append(
                f"{key}: fused path dispatches "
                f"{s['fused_dispatches']:.1f}/round (expected O(1))"
            )
        if s["loop_dispatches"] < s["fused_dispatches"]:
            failures.append(f"{key}: loop path dispatched less than fused?")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)
    for key, s in sorted(report["speedups"].items()):
        sp = (f"{s['speedup']:6.2f}x" if s["speedup"] is not None
              else "   n/a")
        print(f"{key:24s} speedup {sp}  "
              f"dispatches {s['loop_dispatches']:.1f} -> "
              f"{s['fused_dispatches']:.1f}")
    if failures:
        print("\n".join("FAIL: " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
