"""The training hot path: per-round wall time + jit dispatches, loop vs fused.

Starts the perf trajectory for the round hot path (DESIGN.md §7): for each
cohort size H, run the same arm/config through the legacy per-participant
contribution loop (``fused_rounds=False``) and the fused cohort round-step
(default), and report

  * marginal wall-clock per round — measured as
    ``(T(r_hi) - T(r_lo)) / (r_hi - r_lo)`` over two fresh runs, so one-time
    costs (jit compilation, arm construction, leader-schedule setup) cancel
    and the number is the steady-state per-round cost;
  * jit program launches per round, from the ``instrumented_jit`` counter in
    ``repro.arms.fused`` — O(H) on the loop path, O(1) on the fused path.

Every non-SecAgg fused cell also runs SPMD on the ``shard`` backend and the
report gains a ``shard`` column (``shard_vs_ideal`` wall ratio per cell) —
the trajectory record for carrying the fused path onto the pod fast path.
Shard cells are measured in a SUBPROCESS that forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for itself: forcing
host devices in the *parent* would split the XLA CPU thread pool and slow
every baseline cell, silently breaking the artifact's comparability with
earlier trajectory points.  On forced host devices the "mesh" shares one
CPU's cores, so the shard ratio records collective overhead, not a speedup
claim.

``python benchmarks/hotpath.py`` writes ``BENCH_hotpath.json`` (the
committed artifact).  ``--smoke`` runs tiny shapes and *asserts* the fused
path's dispatch count is O(1) per round (on every backend measured) — the
CI perf-smoke job's contract.

``--capacity`` runs the transformer capacity column (DESIGN.md §12): the
"lm" model-size ladder through decaph with ghost clipping vs the faithful
per-example path, writing ``BENCH_capacity.json`` + ``BENCH_capacity.md``.
Each row carries the marginal wall/round, dispatches/round (the ghost cell
must be EXACTLY one — also asserted by ``--smoke``), the fused step's AOT
memory high-water from ``compiled.memory_analysis()`` (where the faithful
path's per-example gradient materialisation shows up as temp bytes the
ghost path never allocates), and a %-of-roofline column from
``repro.launch.roofline.dp_round_roofline`` — a TPU-v5e hardware-model
figure on a CPU host, the same convention the serve BENCH rows use.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import repro.arms as arms
from repro.arms import backends as backends_lib
from repro.arms import fused
from repro.core.dp import DPConfig
from repro.data.synthetic import make_gemini_like
from repro.models.tabular import linear_model

# the small tabular preset (scenarios preset "gemini/small": 32-feature
# linear model), sized so every silo draws a real Poisson batch each round
FEATURES = 32
EXAMPLES_PER_SILO = 240


def _make_setup(h: int, seed: int = 0):
    silos = arms.normalize_participants(
        make_gemini_like(seed=seed, n_total=EXAMPLES_PER_SILO * h,
                         n_silos=h, n_features=FEATURES)
    )
    return linear_model(FEATURES), silos


def _cfg(rounds: int, use_secagg: bool, fused_rounds: bool) -> arms.ArmConfig:
    return arms.ArmConfig(
        rounds=rounds, batch_size=64, lr=0.3, seed=0,
        use_secagg=use_secagg, fused_rounds=fused_rounds,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
    )


def _run_once(arm: str, model, silos, cfg,
              backend: str = backends_lib.DEFAULT_BACKEND
              ) -> tuple[float, int, int]:
    """(wall seconds, jit dispatches, rounds completed) for one fresh run."""
    fused.reset_jit_dispatches()
    t0 = time.perf_counter()
    rep = arms.run(arm, model, silos, cfg, backend=backend)
    dt = time.perf_counter() - t0
    return dt, fused.jit_dispatches(), rep.rounds_completed


def measure(arm: str, h: int, *, use_secagg: bool, fused_rounds: bool,
            r_lo: int, r_hi: int, repeats: int,
            backend: str = backends_lib.DEFAULT_BACKEND) -> dict:
    """Marginal per-round wall/dispatch cost for one (arm, H, path) cell."""
    model, silos = _make_setup(h)
    # compile warmup: a fresh arm per run re-traces, so prime the XLA-level
    # caches for both round counts before timing
    _run_once(arm, model, silos, _cfg(2, use_secagg, fused_rounds), backend)
    t_los, t_his, disps = [], [], []
    n_lo = n_hi = 0
    for _ in range(repeats):
        t_lo, d_lo, n_lo = _run_once(
            arm, model, silos, _cfg(r_lo, use_secagg, fused_rounds), backend)
        t_hi, d_hi, n_hi = _run_once(
            arm, model, silos, _cfg(r_hi, use_secagg, fused_rounds), backend)
        if n_hi <= n_lo:
            raise RuntimeError(f"{arm} H={h}: no marginal rounds measured")
        t_los.append(t_lo)
        t_his.append(t_hi)
        disps.append((d_hi - d_lo) / (n_hi - n_lo))
    # interference only ever ADDS time, so min-of-repeats per ENDPOINT
    # converges on each clean total from above; differencing the minima
    # then cancels compile/setup.  (Differencing per pair and min-ing the
    # marginals — the earlier estimator — keeps a stall-deflated sample
    # whenever the short run stalls: observed as impossible sub-dispatch
    # cells like 27 µs/round on this container.)  A non-positive marginal
    # means every repeat of one endpoint was corrupted: record the cell as
    # unmeasured (null) rather than fabricating a number.
    wall = (min(t_his) - min(t_los)) / (n_hi - n_lo)
    return {
        "arm": arm,
        "hospitals": h,
        "use_secagg": use_secagg,
        "backend": backend,
        "path": "fused" if fused_rounds else "loop",
        "wall_per_round_s": wall if wall > 0 else None,
        "dispatches_per_round": min(disps),
    }


CELLS = [  # (arm, use_secagg) — the round arms the fused path covers
    ("decaph", True),
    ("decaph", False),
    ("fl", False),
    ("fedprox", False),
]

_SHARD_DEVICES = 8


def _measure_shard_cell(arm: str, h: int, r_lo: int, r_hi: int,
                        repeats: int) -> dict:
    """One shard cell, measured in a subprocess that forces its own host
    devices — the parent process stays unflagged so baseline cells keep
    the full XLA CPU thread pool (trajectory comparability)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_SHARD_DEVICES}"
    )
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    spec = json.dumps({"arm": arm, "h": h, "r_lo": r_lo, "r_hi": r_hi,
                       "repeats": repeats})
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--shard-cell", spec],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard cell {arm}/h{h} failed:\n{proc.stderr[-2000:]}"
        )
    payload = [ln for ln in proc.stdout.splitlines()
               if ln.startswith("ROW")][-1]
    return json.loads(payload[len("ROW"):])


def collect(hs: list[int], r_lo: int, r_hi: int, repeats: int,
            progress=lambda msg: None) -> dict:
    rows = []
    for h in hs:
        for arm, secagg in CELLS:
            plans = [(backends_lib.DEFAULT_BACKEND, False),
                     (backends_lib.DEFAULT_BACKEND, True)]
            if not secagg:
                # the SPMD column: fused only (shard has no loop path), and
                # never under SecAgg (the capabilities rule the pair out)
                plans.append(("shard", True))
            for backend, fused_rounds in plans:
                if backend == "shard":
                    row = _measure_shard_cell(arm, h, r_lo, r_hi, repeats)
                else:
                    row = measure(arm, h, use_secagg=secagg,
                                  fused_rounds=fused_rounds,
                                  r_lo=r_lo, r_hi=r_hi, repeats=repeats,
                                  backend=backend)
                rows.append(row)
                wall = row["wall_per_round_s"]
                progress(
                    f"{arm:8s} H={h:<3d} secagg={str(secagg):5s} "
                    f"{backend:5s} {row['path']:5s} "
                    + (f"{wall*1e3:8.2f} ms/round" if wall is not None
                       else "  (unmeasured: interference)")
                    + f" {row['dispatches_per_round']:6.1f} disp/round"
                )
    speedups = {}
    for h in hs:
        for arm, secagg in CELLS:
            cell_rows = [
                r for r in rows
                if r["arm"] == arm and r["hospitals"] == h
                and r["use_secagg"] == secagg
            ]
            pair = {r["path"]: r for r in cell_rows
                    if r["backend"] == backends_lib.DEFAULT_BACKEND}
            shard = next((r for r in cell_rows if r["backend"] == "shard"),
                         None)
            key = f"{arm}{'-secagg' if secagg else ''}-h{h}"
            f_wall = pair["fused"]["wall_per_round_s"]
            l_wall = pair["loop"]["wall_per_round_s"]
            entry = {
                # null when either side went unmeasured — never fabricated
                "speedup": (l_wall / f_wall
                            if f_wall is not None and l_wall is not None
                            else None),
                "loop_dispatches": pair["loop"]["dispatches_per_round"],
                "fused_dispatches": pair["fused"]["dispatches_per_round"],
            }
            if shard is not None:
                s_wall = shard["wall_per_round_s"]
                entry["shard_wall_per_round_s"] = s_wall
                entry["shard_dispatches"] = shard["dispatches_per_round"]
                # > 1 means the mesh run pays that factor over single-device
                # ideal; on forced host devices this records collective
                # overhead, not a speedup claim
                entry["shard_vs_ideal"] = (
                    s_wall / f_wall
                    if s_wall is not None and f_wall is not None else None
                )
            speedups[key] = entry
    return {
        "preset": "small-tabular (gemini/small: 32-feature linear model)",
        "rounds_marginal": [r_lo, r_hi],
        "repeats": repeats,
        "shard_devices": _SHARD_DEVICES,
        "rows": rows,
        "speedups": speedups,
    }


# ---------------------------------------------------------------------------
# Transformer capacity column (DESIGN.md §12): ghost vs faithful clipping
# over the "lm" model-size ladder.
# ---------------------------------------------------------------------------

LM_SIZES = ["small", "medium", "full"]
LM_HOSPITALS = 4        # divides the debug pod mesh's ("pod","data") extent
LM_N_PER = 32           # examples per silo; rate*n_per keeps batches real
LM_BATCH = 16


def _lm_setup(model_size: str, seed: int = 0):
    from repro.scenarios import presets as presets_lib
    from repro.serve.federation import token_silos, transformer_model

    model_cfg = presets_lib.lm_model_config(model_size)
    seq_len = presets_lib.lm_seq_len(model_size)
    model = transformer_model(model_cfg)
    silos = token_silos(model_cfg, hospitals=LM_HOSPITALS, n_per=LM_N_PER,
                        seq_len=seq_len, seed=seed)
    return model_cfg, seq_len, model, silos


def _lm_cfg(rounds: int, clipping: str) -> arms.ArmConfig:
    return arms.ArmConfig(
        rounds=rounds, batch_size=LM_BATCH, lr=0.1, seed=0,
        use_secagg=False, fused_rounds=True, clipping=clipping,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
    )


def _lm_memory(model, seq_len: int, clipping: str, pad: int) -> dict:
    """AOT memory high-water of the fused clipped-grad-sum for one silo.

    The faithful path's per-example gradient materialisation is visible
    here as temp bytes; the ghost path never allocates it.  Shapes match
    the arm's real fused step: the Poisson-padded [pad, seq] batch.
    """
    import jax
    import jax.numpy as jnp

    from repro.arms import clipping as clipping_lib
    from repro.launch import roofline

    fn = clipping_lib.clipped_grad_sum_fn(model, _lm_cfg(1, clipping), pad)
    params = model.init_fn(jax.random.PRNGKey(0))
    batch = {"x": jnp.zeros((pad, seq_len), jnp.int32),
             "y": jnp.zeros((pad, seq_len), jnp.int32)}
    mask = jnp.ones((pad,), jnp.float32)
    compiled = jax.jit(fn).lower(params, batch, mask).compile()
    mem = roofline.analyze_compiled(compiled)["memory_analysis"]
    if "error" in mem:
        return {"error": mem["error"]}
    high_water = sum(mem.get(k, 0) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes"))
    return {
        "temp_bytes": mem.get("temp_size_in_bytes"),
        "argument_bytes": mem.get("argument_size_in_bytes"),
        "output_bytes": mem.get("output_size_in_bytes"),
        "high_water_bytes": high_water,
    }


def measure_capacity_cell(model_size: str, clipping: str, *, r_lo: int,
                          r_hi: int, repeats: int) -> dict:
    """One (model size, clipping path) cell of the capacity column."""
    import jax
    import numpy as np

    from repro.arms.base import default_pad
    from repro.launch import roofline

    model_cfg, seq_len, model, silos = _lm_setup(model_size)
    rate = LM_BATCH / (LM_N_PER * LM_HOSPITALS)
    pad = default_pad(rate, silos, _lm_cfg(2, clipping))
    params = model.init_fn(jax.random.PRNGKey(0))
    n_params = int(sum(np.prod(np.shape(leaf)) or 1
                       for leaf in jax.tree_util.tree_leaves(params)))

    _run_once("decaph", model, silos, _lm_cfg(2, clipping))  # compile warmup
    t_los, t_his, disps = [], [], []
    n_lo = n_hi = 0
    for _ in range(repeats):
        t_lo, d_lo, n_lo = _run_once("decaph", model, silos,
                                     _lm_cfg(r_lo, clipping))
        t_hi, d_hi, n_hi = _run_once("decaph", model, silos,
                                     _lm_cfg(r_hi, clipping))
        if n_hi <= n_lo:
            raise RuntimeError(f"lm/{model_size}: no marginal rounds")
        t_los.append(t_lo)
        t_his.append(t_hi)
        disps.append((d_hi - d_lo) / (n_hi - n_lo))
    wall = (min(t_his) - min(t_los)) / (n_hi - n_lo)
    row = {
        "model_size": model_size,
        "clipping": clipping,
        "seq_len": seq_len,
        "model_params": n_params,
        "hospitals": LM_HOSPITALS,
        "batch_size": LM_BATCH,
        "pad": pad,
        "wall_per_round_s": wall if wall > 0 else None,
        "dispatches_per_round": min(disps),
        "memory": _lm_memory(model, seq_len, clipping, pad),
    }
    row.update(roofline.dp_round_roofline(
        model_cfg, cohort=LM_HOSPITALS, batch_per_silo=LM_BATCH,
        seq_len=seq_len, wall_seconds=row["wall_per_round_s"],
        clipping=clipping,
    ))
    return row


def collect_capacity(sizes: list[str], r_lo: int, r_hi: int, repeats: int,
                     progress=lambda msg: None) -> dict:
    rows = []
    for size in sizes:
        for clipping in ("ghost", "per-example"):
            row = measure_capacity_cell(size, clipping, r_lo=r_lo,
                                        r_hi=r_hi, repeats=repeats)
            rows.append(row)
            wall = row["wall_per_round_s"]
            progress(
                f"lm/{size:6s} {clipping:11s} "
                + (f"{wall*1e3:9.2f} ms/round" if wall is not None
                   else "  (unmeasured)")
                + f" {row['dispatches_per_round']:4.1f} disp/round"
                + (f" {row['pct_of_roofline']:.3f}%-roofline"
                   if "pct_of_roofline" in row else "")
            )
    speedups = {}
    for size in sizes:
        pair = {r["clipping"]: r for r in rows if r["model_size"] == size}
        g, f = pair["ghost"], pair["per-example"]
        g_wall, f_wall = g["wall_per_round_s"], f["wall_per_round_s"]
        g_mem = g["memory"].get("high_water_bytes")
        f_mem = f["memory"].get("high_water_bytes")
        speedups[size] = {
            "speedup": (f_wall / g_wall
                        if g_wall is not None and f_wall is not None
                        else None),
            "ghost_dispatches": g["dispatches_per_round"],
            "faithful_dispatches": f["dispatches_per_round"],
            "ghost_high_water_bytes": g_mem,
            "faithful_high_water_bytes": f_mem,
            "memory_ratio": (f_mem / g_mem if g_mem and f_mem else None),
            # the hardware-model column: faithful is memory-bound on per-
            # example grad traffic on the TPU roofline, ghost compute-bound
            "projected_tpu_speedup": (
                f["roofline_round_s"] / g["roofline_round_s"]
                if "roofline_round_s" in g and "roofline_round_s" in f
                else None),
        }
    return {
        "preset": ("lm transformer ladder (dense decoder stacks, untied "
                   "embeddings; decaph, ideal backend)"),
        "hospitals": LM_HOSPITALS,
        "batch_size": LM_BATCH,
        "examples_per_silo": LM_N_PER,
        "rounds_marginal": [r_lo, r_hi],
        "repeats": repeats,
        "roofline_target": "TPU-v5e (hardware-model figure on CPU hosts)",
        "rows": rows,
        "speedups": speedups,
    }


def capacity_markdown(report: dict) -> str:
    """BENCH_capacity.md — the human-readable capacity table."""
    lines = [
        "# Capacity: ghost-clipped fused rounds on the lm transformer ladder",
        "",
        f"decaph, ideal backend, H={report['hospitals']}, "
        f"batch={report['batch_size']}/silo, marginal rounds "
        f"{report['rounds_marginal']}, repeats={report['repeats']}.  "
        "%-of-roofline and the roofline round time are TPU-v5e "
        "hardware-model figures (`repro.launch.roofline.dp_round_roofline`); "
        "memory high-water is the fused clipped-grad-sum step's AOT "
        "`compiled.memory_analysis()` (argument + output + temp bytes).",
        "",
        "On this benchmark's CPU host both clipping paths are compute-bound, "
        "so the measured speedup understates the hardware story: on the TPU "
        "roofline the faithful path is **memory-bound** on per-example "
        "gradient traffic (8·N·B bytes/round it must write then re-read) "
        "while the ghost path never materialises a per-example gradient and "
        "stays compute-bound — the projected column below.",
        "",
        "| model | params | seq | clipping | ms/round | disp/round "
        "| %-roofline | roofline ms | bound | high-water MiB |",
        "|---|---:|---:|---|---:|---:|---:|---:|---|---:|",
    ]
    for r in report["rows"]:
        wall = r["wall_per_round_s"]
        hw = r["memory"].get("high_water_bytes")
        lines.append(
            f"| {r['model_size']} | {r['model_params']:,} | {r['seq_len']} "
            f"| {r['clipping']} "
            + (f"| {wall*1e3:.2f} " if wall is not None else "| n/a ")
            + f"| {r['dispatches_per_round']:.1f} "
            + (f"| {r['pct_of_roofline']:.3f} "
               if "pct_of_roofline" in r else "| n/a ")
            + f"| {r['roofline_round_s']*1e3:.3f} "
            + f"| {r['roofline_bottleneck']} "
            + (f"| {hw/2**20:.1f} |" if hw is not None else "| n/a |")
        )
    lines += ["",
              "| model | measured speedup | projected TPU speedup "
              "| memory ratio (faithful/ghost) |",
              "|---|---:|---:|---:|"]
    for size, s in report["speedups"].items():
        sp = f"{s['speedup']:.2f}x" if s["speedup"] is not None else "n/a"
        pj = (f"{s['projected_tpu_speedup']:.2f}x"
              if s["projected_tpu_speedup"] is not None else "n/a")
        mr = (f"{s['memory_ratio']:.2f}x"
              if s["memory_ratio"] is not None else "n/a")
        lines.append(f"| {size} | {sp} | {pj} | {mr} |")
    lines.append("")
    return "\n".join(lines)


def _capacity_failures(report: dict) -> list[str]:
    """The §12 dispatch contract over capacity rows: a ghost fused round is
    EXACTLY one program launch — not O(1), one.  The faithful path stays
    fused too (the microbatch loop lives inside the program), so it gets
    the same O(1) bound the tabular cells assert."""
    failures = []
    for r in report["rows"]:
        disp = r["dispatches_per_round"]
        key = f"lm/{r['model_size']}/{r['clipping']}"
        if r["clipping"] == "ghost" and disp != 1.0:
            failures.append(
                f"{key}: {disp:.2f} dispatches/round (expected exactly 1)"
            )
        elif r["clipping"] == "per-example" and disp > 2.0:
            failures.append(
                f"{key}: {disp:.2f} dispatches/round (expected O(1))"
            )
    return failures


def run(fast: bool = True) -> list[dict]:
    """benchmarks/run.py entry point."""
    hs = [5, 10] if fast else [5, 10, 20]
    report = collect(hs, r_lo=3, r_hi=9 if fast else 15, repeats=1,
                     progress=lambda m: print(m, file=sys.stderr))
    return [
        {
            "name": (f"hotpath_{r['arm']}_h{r['hospitals']}"
                     f"{'_secagg' if r['use_secagg'] else ''}"
                     + (f"_{r['backend']}"
                        if r["backend"] != backends_lib.DEFAULT_BACKEND
                        else "")
                     + f"_{r['path']}"),
            "us_per_call": (r["wall_per_round_s"] or 0.0) * 1e6,
            "derived": f"dispatches_per_round={r['dispatches_per_round']:.1f}",
        }
        for r in report["rows"]
    ]


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes; assert fused dispatches are O(1)")
    p.add_argument("--out", default="BENCH_hotpath.json")
    p.add_argument("--hospitals", type=int, nargs="+",
                   default=[5, 10, 20])
    p.add_argument("--rounds", type=int, nargs=2, default=[10, 50],
                   metavar=("R_LO", "R_HI"))
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--capacity", action="store_true",
                   help="run the lm transformer capacity column instead "
                        "(ghost vs per-example clipping; writes "
                        "BENCH_capacity.json + .md)")
    p.add_argument("--capacity-sizes", nargs="+", default=LM_SIZES,
                   choices=LM_SIZES)
    p.add_argument("--capacity-rounds", type=int, nargs=2, default=[3, 9],
                   metavar=("R_LO", "R_HI"))
    p.add_argument("--shard-cell", help=argparse.SUPPRESS)  # subprocess mode
    args = p.parse_args(argv)

    if args.shard_cell:
        # child mode: this process was spawned with forced host devices to
        # measure exactly one shard cell; print the row and exit
        spec = json.loads(args.shard_cell)
        row = measure(spec["arm"], spec["h"], use_secagg=False,
                      fused_rounds=True, r_lo=spec["r_lo"],
                      r_hi=spec["r_hi"], repeats=spec["repeats"],
                      backend="shard")
        print("ROW" + json.dumps(row))
        return 0

    if args.capacity:
        out = (args.out if args.out != "BENCH_hotpath.json"
               else "BENCH_capacity.json")
        sizes = ["small"] if args.smoke else list(args.capacity_sizes)
        r_lo, r_hi = ([2, 5] if args.smoke else args.capacity_rounds)
        repeats = 1 if args.smoke else args.repeats
        report = collect_capacity(
            sizes, r_lo, r_hi, repeats,
            progress=lambda m: print(m, file=sys.stderr))
        failures = _capacity_failures(report)
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        md_out = os.path.splitext(out)[0] + ".md"
        with open(md_out, "w") as f:
            f.write(capacity_markdown(report))
        print(f"wrote {out} and {md_out}", file=sys.stderr)
        for size, s in report["speedups"].items():
            sp = (f"{s['speedup']:6.2f}x" if s["speedup"] is not None
                  else "   n/a")
            mr = (f"{s['memory_ratio']:5.2f}x"
                  if s["memory_ratio"] is not None else "  n/a")
            print(f"lm/{size:8s} ghost speedup {sp}  memory {mr}  "
                  f"dispatches {s['faithful_dispatches']:.1f} -> "
                  f"{s['ghost_dispatches']:.1f}")
        if failures:
            print("\n".join("FAIL: " + f for f in failures), file=sys.stderr)
            return 1
        return 0

    if args.smoke:
        args.hospitals, args.rounds, args.repeats = [4], [2, 6], 1

    report = collect(args.hospitals, r_lo=args.rounds[0],
                     r_hi=args.rounds[1], repeats=args.repeats,
                     progress=lambda m: print(m, file=sys.stderr))

    failures = []
    for key, s in report["speedups"].items():
        # the structural contract, asserted even in --smoke: a fused round
        # is ONE cohort program launch, a loop round is >= H of them —
        # on the SPMD backend too (the mesh must not reintroduce per-
        # participant or per-shard dispatch)
        if s["fused_dispatches"] > 2.0:
            failures.append(
                f"{key}: fused path dispatches "
                f"{s['fused_dispatches']:.1f}/round (expected O(1))"
            )
        if s.get("shard_dispatches", 0.0) > 2.0:
            failures.append(
                f"{key}: shard path dispatches "
                f"{s['shard_dispatches']:.1f}/round (expected O(1))"
            )
        if s["loop_dispatches"] < s["fused_dispatches"]:
            failures.append(f"{key}: loop path dispatched less than fused?")

    if args.smoke:
        # the CI perf-smoke contract for the ghost transformer path: one
        # fused DP round with ghost clipping is EXACTLY one program launch
        ghost_row = measure_capacity_cell("small", "ghost", r_lo=2, r_hi=5,
                                          repeats=1)
        report["ghost_smoke_cell"] = ghost_row
        disp = ghost_row["dispatches_per_round"]
        print(f"ghost-lm smoke cell: {disp:.1f} dispatches/round",
              file=sys.stderr)
        if disp != 1.0:
            failures.append(
                f"ghost transformer cell: {disp:.2f} dispatches/round "
                f"(expected exactly 1)"
            )

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)
    for key, s in sorted(report["speedups"].items()):
        sp = (f"{s['speedup']:6.2f}x" if s["speedup"] is not None
              else "   n/a")
        line = (f"{key:24s} speedup {sp}  "
                f"dispatches {s['loop_dispatches']:.1f} -> "
                f"{s['fused_dispatches']:.1f}")
        if s.get("shard_vs_ideal") is not None:
            line += f"  shard/ideal {s['shard_vs_ideal']:5.2f}x"
        print(line)
    if failures:
        print("\n".join("FAIL: " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
