"""Paper Supp. Fig. 1 / Supp. Table 1: SecAgg wall-clock + communication.

(a) wall-clock scaling of one secure_sum round with participants and with
input dimension, (b) the communication-cost table for the paper's three
case-study model sizes (GEMINI MLP 166,771 / linear 437; pancreas MLP
15.7M / linear 62k; X-ray DenseNet 7.0M).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.secagg import SecAggConfig, secagg_message_bytes, secure_sum

PAPER_SIZES = {
    "gemini_mlp": (166_771, 8),
    "gemini_linear": (437, 8),
    "pancreas_mlp": (15_659_504, 5),
    "pancreas_linear": (62_236, 5),
    "xray_densenet": (7_035_453, 3),
}


def run(fast: bool = True) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # (a) wall-clock scaling
    dims = [10_000, 100_000] if fast else [10_000, 100_000, 1_000_000]
    clients_sweep = [2, 4, 8] if fast else [2, 4, 8, 16, 30]
    for dim in dims:
        vals = [jnp.asarray(rng.normal(0, 1, dim).astype(np.float32))
                for _ in range(4)]
        t0 = time.time()
        secure_sum(vals, SecAggConfig(4, seed=1))
        rows.append({
            "name": f"secagg_wallclock_dim{dim}_n4",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"dim={dim};clients=4",
        })
    for n in clients_sweep:
        vals = [jnp.asarray(rng.normal(0, 1, 50_000).astype(np.float32))
                for _ in range(n)]
        t0 = time.time()
        secure_sum(vals, SecAggConfig(n, seed=2))
        rows.append({
            "name": f"secagg_wallclock_n{n}_dim50k",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"clients={n};dim=50000",
        })

    # (b) communication table (exact model, matches Supp. Table 1 structure)
    for task, (n_params, n_clients) in PAPER_SIZES.items():
        c = secagg_message_bytes(n_params, n_clients)
        rows.append({
            "name": f"secagg_comm_{task}",
            "us_per_call": 0.0,
            "derived": (
                f"per_participant_MB={c['per_participant_bytes']/1e6:.3f};"
                f"aggregator_MB={c['aggregator_bytes']/1e6:.3f};"
                f"plain_per_participant_MB={c['plain_per_participant_bytes']/1e6:.3f}"
            ),
        })
    return rows
