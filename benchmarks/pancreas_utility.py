"""Paper Fig. 3c / Supp. Tables 6-7: single-cell pancreas cell typing.

5 studies (one tiny, like Wang), 4 cell types; MLP and SVC models; eps = 5.6
for the DP arms.  Validates the collaborative > local ordering and the
DeCaPH > PriMIA gap the paper attributes to local-DP dropout.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import multiclass_metrics, utility_comparison
from repro.data import make_pancreas_like
from repro.models.tabular import make_mlp_classifier, make_svc


def run(fast: bool = True) -> list[dict]:
    n_genes = 2000 if fast else 15558
    n_total = 1400 if fast else 10548
    rounds = 40 if fast else 300
    silos = make_pancreas_like(seed=0, n_total=n_total, n_genes=n_genes)
    rows = []
    for arch_name, model in [
        ("mlp", make_mlp_classifier([n_genes, 128, 32, 4], "multiclass")),
        ("svc", make_svc(n_genes, 4)),
    ]:
        out, tx, ty = utility_comparison(
            model, silos, rounds=rounds, batch=96, lr=0.3,
            sigma=None, clip=0.5, eps_budget=5.6, microbatch=8,
        )
        mets = {}
        for arm in ("fl", "decaph", "primia"):
            params, eps, us = out[arm]
            mets[arm] = multiclass_metrics(model, params, tx, ty, 4)
            rows.append({
                "name": f"pancreas_{arch_name}_{arm}",
                "us_per_call": us,
                "derived": (
                    f"median_f1={mets[arm]['median_f1']:.4f};"
                    f"wprec={mets[arm]['weighted_precision']:.4f};"
                    f"eps={eps:.2f}"
                ),
            })
        local_params, _, us = out["local"]
        local_f1 = [multiclass_metrics(model, p, tx, ty, 4)["median_f1"]
                    for p in local_params]
        rows.append({
            "name": f"pancreas_{arch_name}_local",
            "us_per_call": us,
            "derived": (
                f"median_f1_mean={np.mean(local_f1):.4f};"
                f"median_f1_min={np.min(local_f1):.4f}"  # P4 (tiny silo)
            ),
        })
        rows.append({
            "name": f"pancreas_{arch_name}_claim",
            "us_per_call": 0.0,
            "derived": (
                f"decaph>worst_local:{mets['decaph']['median_f1'] > np.min(local_f1)};"
                f"decaph>=primia:{mets['decaph']['median_f1'] >= mets['primia']['median_f1'] - 0.01}"
            ),
        })
    return rows
