"""Systems report: every registered arm on a 5-hospital heterogeneous trace.

For each arm in the registry (decaph, fl, primia, local, gossip, gossip-dp)
the sim backend reports simulated wall-clock, bytes-on-wire, rounds
completed, epsilon and final utility — answering the deployment questions
(stragglers, flaky networks, dropout) the idealized backend cannot.  The
table enumerates ``repro.arms.names()``, so a newly registered arm shows up
here without touching this file.

Also certifies the dropout-recovery acceptance property end to end: a
hospital dropping mid-round on the decaph arm completes via Shamir mask
recovery, and the recovered aggregate equals the plain sum of the surviving
participants' contributions within fixed-point tolerance (raises otherwise).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.arms as arms
from repro.core.dp import DPConfig
from repro.core.secagg import DropoutRobustSession, SecAggConfig
from repro.data.synthetic import make_gemini_like
from repro.models.tabular import linear_model, pooled_accuracy
from repro.scenarios.presets import FIVE_HOSPITAL_TRACE
from repro.sim import Topology, nodes_from_trace

# The canonical 5-hospital heterogeneous cohort — defined exactly once, in
# the scenario preset library (shared with examples/ and the sweep presets).
SCENARIO = FIVE_HOSPITAL_TRACE


def _topology_for(arm_cls, n: int, center: int) -> Topology:
    """The arm's natural topology, carrying the scenario's link model."""
    default = SCENARIO["topology"]["default"]
    if arm_cls.topology_kind == "star":
        spec = {"kind": "star", "center": center, "default": default}
    elif arm_cls.topology_kind == "ring":
        spec = {"kind": "ring", "default": default}
    else:
        spec = dict(SCENARIO["topology"])  # full mesh incl. slow-WAN links
    spec.setdefault("n", n)
    return Topology.from_trace(spec)


def certify_dropout_recovery(
    n: int = 5, dim: int = 64, seed: int = 3
) -> float:
    """Acceptance property at the protocol level: recovered == survivor sum."""
    rng = np.random.default_rng(seed)
    vals = [jnp.asarray(rng.normal(0, 2, dim).astype(np.float32))
            for _ in range(n)]
    cfg = SecAggConfig(n, frac_bits=16, seed=seed)
    session = DropoutRobustSession(cfg, vals[0], threshold=3)
    dropped = {1, 3}
    uploads = {i: session.upload(i, vals[i])
               for i in range(n) if i not in dropped}
    out = np.asarray(session.aggregate(uploads))
    expected = np.sum([np.asarray(vals[i]) for i in range(n)
                       if i not in dropped], axis=0)
    err = float(np.abs(out - expected).max())
    tol = n * 2.0 ** -(cfg.frac_bits - 1)
    if err > tol:
        raise AssertionError(
            f"Shamir recovery off by {err} (> fixed-point tolerance {tol})"
        )
    return err


def run(fast: bool = True) -> list[dict]:
    n_features = 32 if fast else 436
    rounds = 12 if fast else 60
    silos = arms.normalize_participants(
        make_gemini_like(seed=0, n_total=1200 if fast else 5000,
                         n_silos=5, n_features=n_features)
    )
    model = linear_model(n_features)
    cfg = arms.ArmConfig(
        rounds=rounds, batch_size=64, lr=0.4, seed=0,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
    )

    rows = []
    err = certify_dropout_recovery()
    rows.append({
        "name": "sim_dropout_recovery_certified",
        "us_per_call": 0.0,
        "derived": f"max_abs_err={err:.2e};survivors=3of5;threshold=3",
    })

    for arm in arms.names():
        arm_cls = arms.get(arm)
        nodes = nodes_from_trace(SCENARIO["nodes"])
        topo = _topology_for(arm_cls, len(nodes), cfg.fl_server)
        t0 = time.time()
        rep = arms.run(arm, model, silos, cfg, backend="sim",
                       nodes=nodes, topo=topo)
        elapsed_us = (time.time() - t0) * 1e6
        # rep.params is the arm's headline model (node arms pick it in
        # consensus(): local -> node 0, gossip -> the average)
        acc = pooled_accuracy(model, rep.params, silos)
        rows.append({
            "name": f"sim_{arm}",
            "us_per_call": elapsed_us,
            "derived": (
                f"sim_wall_clock_s={rep.wall_clock:.3f};"
                f"bytes_on_wire={rep.bytes_on_wire:.0f};"
                f"rounds={rep.rounds_completed};"
                f"epsilon={rep.epsilon:.2f};"
                f"accuracy={acc:.3f};"
                f"dropouts={rep.dropout_events};"
                f"recoveries={rep.recoveries};"
                f"events={rep.events}"
            ),
        })
        if arm == "decaph" and rep.recoveries < 1:
            raise AssertionError(
                "scenario injects a mid-run dropout but decaph performed "
                "no Shamir recovery — dropout did not land mid-round"
            )
    return rows


if __name__ == "__main__":
    header = (f"{'arm':<10} {'sim wall (s)':>12} {'bytes on wire':>14} "
              f"{'rounds':>6} {'epsilon':>8} {'accuracy':>8} {'recov':>5}")
    rows = run(fast=True)
    print(header)
    print("-" * len(header))
    for r in rows:
        d = dict(kv.split("=") for kv in r["derived"].split(";"))
        if r["name"] == "sim_dropout_recovery_certified":
            print(f"dropout recovery certified: max_abs_err={d['max_abs_err']}"
                  f" ({d['survivors']} survivors, threshold={d['threshold']})")
            continue
        print(f"{r['name'][4:]:<10} {float(d['sim_wall_clock_s']):>12.3f} "
              f"{float(d['bytes_on_wire']):>14.0f} {d['rounds']:>6} "
              f"{float(d['epsilon']):>8.2f} {float(d['accuracy']):>8.3f} "
              f"{d.get('recoveries', '0'):>5}")
