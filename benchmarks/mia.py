"""Paper Fig. 5: membership inference (LiRA) on FL vs DeCaPH targets.

Trains target models with and without DeCaPH's DP mechanics on the
GEMINI-like task, runs the online LiRA with shadow models, and reports the
attack AUROC per target — the paper's claim is that DP targets sit near 0.5
while non-private FL targets are materially above it.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_lib
from repro.core.mia import lira_attack
from repro.data import make_gemini_like
from repro.models.tabular import make_mlp_classifier


def _train_fn_factory(model, *, dp: bool, rounds: int, lr: float,
                      clip: float = 1.0, sigma: float = 0.8):
    def train_fn(x, y, seed):
        key = jax.random.key(seed)
        params = model.init_fn(key)
        n = len(x)
        bs = min(64, n)
        rng = np.random.default_rng(seed)
        for t in range(rounds):
            idx = rng.choice(n, bs, replace=False)
            batch = {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
            if dp:
                g, _ = dp_lib.per_example_clipped_grad_sum(
                    model.loss_fn, params, batch, clip_norm=clip,
                    microbatch_size=16,
                )
                g = dp_lib.tree_add_noise(
                    g, jax.random.fold_in(key, t), clip_norm=clip,
                    noise_multiplier=sigma,
                )
                g = jax.tree_util.tree_map(lambda v: v / bs, g)
            else:
                def mean_loss(p):
                    return jnp.mean(jax.vmap(
                        lambda ex: model.loss_fn(p, ex)
                    )(batch))

                g = jax.grad(mean_loss)(params)
            params = jax.tree_util.tree_map(
                lambda p_, g_: p_ - lr * g_, params, g
            )
        return params

    return train_fn


def run(fast: bool = True) -> list[dict]:
    n = 400 if fast else 4000
    rounds = 60 if fast else 300
    n_shadows = 8 if fast else 32
    silos = make_gemini_like(seed=0, n_total=n)
    x = np.concatenate([p.x for p in silos])[: n]
    y = np.concatenate([p.y for p in silos])[: n]
    x = (x - x.mean(0)) / (x.std(0) + 1e-8)
    model = make_mlp_classifier([436, 64, 16, 1], "binary")

    def conf_fn(params, xq, yq):
        p = np.asarray(model.predict_fn(params, jnp.asarray(xq)))
        return np.where(yq > 0.5, p, 1 - p)

    rows = []
    for arm, dp in [("fl", False), ("decaph", True)]:
        t0 = time.time()
        res = lira_attack(
            _train_fn_factory(model, dp=dp, rounds=rounds, lr=1.0),
            conf_fn, x, y, n_shadows=n_shadows, seed=0,
        )
        rows.append({
            "name": f"mia_lira_{arm}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": (
                f"attack_auroc={res.auroc:.4f};"
                f"tpr@1%fpr={res.tpr_at_1pct_fpr:.4f}"
            ),
        })
    fl_auc = float(rows[0]["derived"].split("=")[1].split(";")[0])
    dc_auc = float(rows[1]["derived"].split("=")[1].split(";")[0])
    rows.append({
        "name": "mia_claim",
        "us_per_call": 0.0,
        "derived": f"decaph_less_vulnerable:{dc_auc < fl_auc};"
                   f"gap={fl_auc - dc_auc:.4f}",
    })
    return rows
