"""Paper Fig. 2c / Supp. Tables 4-5: GEMINI mortality prediction.

Four arms (Local / FL / PriMIA / DeCaPH) on the GEMINI-like synthetic EHR
task (436 features, 8 hospitals, skewed sizes, eps = 2.0 for the DP arms).
Validates: FL ≈ DeCaPH > Local; DeCaPH > PriMIA at equal eps.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import binary_auroc, utility_comparison
from repro.data import make_gemini_like
from repro.models.tabular import make_logistic, make_mlp_classifier


def run(fast: bool = True) -> list[dict]:
    n_total = 4000 if fast else 40114
    rounds = 60 if fast else 400
    silos = make_gemini_like(seed=0, n_total=n_total)
    rows = []
    for arch_name, model in [
        ("mlp", make_mlp_classifier([436, 64, 16, 1], "binary")),
        ("logistic", make_logistic(436)),
    ]:
        out, tx, ty = utility_comparison(
            model, silos, rounds=rounds, batch=128, lr=0.5,
            sigma=None, clip=1.0, eps_budget=2.0,
        )
        aucs = {}
        for arm in ("fl", "decaph", "primia"):
            params, eps, us = out[arm]
            aucs[arm] = binary_auroc(model, params, tx, ty)
            rows.append({
                "name": f"gemini_{arch_name}_{arm}",
                "us_per_call": us,
                "derived": f"auroc={aucs[arm]:.4f};eps={eps:.2f}",
            })
        local_params, _, us = out["local"]
        local_auc = float(np.mean([
            binary_auroc(model, p, tx, ty) for p in local_params
        ]))
        rows.append({
            "name": f"gemini_{arch_name}_local",
            "us_per_call": us,
            "derived": f"auroc={local_auc:.4f};eps=0",
        })
        rows.append({
            "name": f"gemini_{arch_name}_claim",
            "us_per_call": 0.0,
            "derived": (
                f"decaph>local:{aucs['decaph'] > local_auc};"
                f"decaph>=primia:{aucs['decaph'] >= aucs['primia'] - 0.01};"
                f"drop_vs_fl={(aucs['fl'] - aucs['decaph']):.4f}"
            ),
        })
    return rows
