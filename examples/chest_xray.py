"""Case study 3 (paper Fig. 4): multilabel pathology identification.

3 studies, 4 outputs (Atelectasis / Effusion / Cardiomegaly / No Finding),
BN-free mini-DenseNet (DP-SGD forbids BatchNorm, as the paper discusses).

Run:  PYTHONPATH=src python examples/chest_xray.py [--rounds 25]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

import jax.numpy as jnp
import numpy as np

from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig, run_decaph, run_fl, run_local,
)
from repro.core.mia import auroc
from repro.data import make_xray_like
from repro.data.partition import train_test_split_silos
from repro.models.tabular import DenseNetConfig, make_densenet

LABELS = ["Atelectasis", "Effusion", "Cardiomegaly", "No Finding"]


def per_label_auroc(model, params, tx, ty):
    probs = np.asarray(model.predict_fn(params, jnp.asarray(tx)))
    return [auroc(probs[:, j], ty[:, j].astype(np.int32)) for j in range(4)]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--rounds", type=int, default=120)
    p.add_argument("--size", type=int, default=16)
    args = p.parse_args()

    silos = make_xray_like(seed=0, n_total=900, image_size=args.size)
    print("study sizes:", [len(s) for s in silos])
    train, tx, ty = train_test_split_silos(silos, 0.2, seed=0)

    base = make_densenet(DenseNetConfig(
        growth=8, blocks=(2, 2), init_channels=8, image_size=args.size
    ))
    # Paper setup: start from a model pre-trained on MIMIC-CXR — a disjoint
    # synthetic study stands in (see benchmarks/xray_utility.py).
    from benchmarks.xray_utility import _pretrain
    from repro.core.federation import Model

    print("pre-training on the MIMIC-like study ...")
    pretrained = _pretrain(base, args.size, 900, 250)
    model = Model(lambda key: pretrained, base.loss_fn, base.predict_fn)
    cfg = FederationConfig(
        rounds=args.rounds, batch_size=48, lr=0.1, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=0.5, noise_multiplier=2.2, microbatch_size=8),
        epsilon_budget=3.0,  # paper uses 0.62 at 268k images; see benchmarks/xray_utility.py
    )

    header = "  ".join(f"{l:>12s}" for l in LABELS)
    print(f"{'arm':10s} {header} {'eps':>7s}")
    fl = run_fl(model, train, cfg)
    aucs = per_label_auroc(model, fl.params, tx, ty)
    print(f"{'FL':10s} " + "  ".join(f"{a:12.3f}" for a in aucs) + f" {'-':>7s}")
    dc = run_decaph(model, train, cfg)
    aucs = per_label_auroc(model, dc.params, tx, ty)
    print(f"{'DeCaPH':10s} " + "  ".join(f"{a:12.3f}" for a in aucs)
          + f" {dc.epsilon:7.3f}")
    lo = run_local(model, train, cfg)
    for i, params in enumerate(lo.per_client_params):
        aucs = per_label_auroc(model, params, tx, ty)
        print(f"{'local P%d' % (i+1):10s} "
              + "  ".join(f"{a:12.3f}" for a in aucs) + f" {'-':>7s}")


if __name__ == "__main__":
    main()
