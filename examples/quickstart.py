"""Quickstart: three hospitals train a mortality model with DeCaPH.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig,
    normalize_participants,
    run_decaph,
)
from repro.data import make_gemini_like
from repro.data.partition import train_test_split_silos
from repro.core.mia import auroc
from repro.models.tabular import make_mlp_classifier

import jax.numpy as jnp


def main() -> None:
    # Three of the eight GEMINI-like hospitals, scaled down for a quick demo.
    silos = make_gemini_like(seed=0, n_total=4000)[:3]
    silos = normalize_participants(silos)            # SecAgg'd global stats
    train, test_x, test_y = train_test_split_silos(silos, 0.2, seed=0)

    model = make_mlp_classifier([436, 64, 16, 1], "binary")
    cfg = FederationConfig(
        rounds=40,
        batch_size=64,                 # aggregate mini-batch B
        lr=0.5,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=1.2, microbatch_size=16),
        epsilon_budget=2.0,            # the paper's GEMINI budget
        use_secagg=True,               # the real fixed-point protocol
        leader_strategy="uniform",
        seed=0,
    )
    result = run_decaph(model, train, cfg)

    scores = np.asarray(model.predict_fn(result.params, jnp.asarray(test_x)))
    print(f"rounds completed : {result.rounds_completed}")
    print(f"epsilon spent    : {result.epsilon:.3f} (budget 2.0)")
    print(f"test AUROC       : {auroc(scores, test_y.astype(np.int32)):.4f}")
    print(f"leaders (first 8): {[l.leader for l in result.logs[:8]]}")


if __name__ == "__main__":
    main()
