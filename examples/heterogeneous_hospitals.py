"""Heterogeneous hospitals: what deployment actually costs.

Five hospitals with a 8x compute spread and a flaky mid-tier site that
drops off the network mid-training and rejoins.  Each protocol is written
ONCE as a registered arm (``repro.arms``); here the discrete-event backend
replays DeCaPH, async-gossip D-PSGD, and the local-DP gossip variant under
these conditions and reports what the idealized backend cannot: simulated
wall-clock, bytes on wire, and a real Shamir mask recovery when the dropout
lands mid-round.

Run:  PYTHONPATH=src python examples/heterogeneous_hospitals.py
"""

import jax.numpy as jnp
import numpy as np

import repro.arms as arms
from repro.core.dp import DPConfig
from repro.data import make_gemini_like
from repro.models.tabular import linear_model
from repro.scenarios.presets import FIVE_HOSPITAL_NODES
from repro.sim import Topology, nodes_from_trace


def main() -> None:
    silos = arms.normalize_participants(
        make_gemini_like(seed=0, n_total=1500, n_silos=5, n_features=32)
    )
    model = linear_model(32)

    # The canonical 5-hospital trace from the scenario preset library:
    # research centre (500 ex/s) down to community hospital (60 ex/s), with
    # the flaky mid-tier site dropping off mid-run and rejoining.
    trace = FIVE_HOSPITAL_NODES
    cfg = arms.ArmConfig(
        rounds=15, batch_size=64, lr=0.4, seed=0,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=0.8, microbatch_size=8),
    )

    def accuracy(params):
        x = np.concatenate([p.x for p in silos])
        y = np.concatenate([p.y for p in silos])
        return ((np.asarray(model.predict_fn(params, jnp.asarray(x))) > 0.5)
                == y).mean()

    dec = arms.run("decaph", model, silos, cfg, backend="sim",
                   nodes=nodes_from_trace(trace), topo=Topology.full(5))
    print("DeCaPH (synchronous rounds, dropout-robust SecAgg)")
    print(f"  simulated wall-clock : {dec.wall_clock:.2f} s")
    print(f"  bytes on wire        : {dec.bytes_on_wire:,.0f}")
    print(f"  Shamir recoveries    : {dec.recoveries} "
          f"(hospital 3 dropped mid-round)")
    print(f"  epsilon spent        : {dec.epsilon:.2f}")
    print(f"  pooled accuracy      : {accuracy(dec.params):.3f}")

    gos = arms.run("gossip", model, silos, cfg, backend="sim",
                   nodes=nodes_from_trace(trace), topo=Topology.k_regular(5, 2))
    print("\nAsync gossip D-PSGD (no rounds, 2-regular graph)")
    print(f"  simulated wall-clock : {gos.wall_clock:.2f} s "
          f"(straggler-paced, but compute overlaps communication)")
    print(f"  bytes on wire        : {gos.bytes_on_wire:,.0f}")
    print(f"  consensus accuracy   : {accuracy(gos.params):.3f}")
    spread = [float(np.linalg.norm(np.asarray(p['w'])
                                   - np.asarray(gos.params['w'])))
              for p in gos.per_node_params]
    print(f"  model disagreement   : max |w_i - w_avg| = {max(spread):.4f} "
          f"(gossip keeps nodes approximately synced)")

    # The same numerics as "gossip" plus local clip+noise and a per-node
    # accountant — registered once, both backends for free (ROADMAP item).
    gdp = arms.run("gossip-dp", model, silos, cfg, backend="sim",
                   nodes=nodes_from_trace(trace), topo=Topology.k_regular(5, 2))
    print("\nDP gossip (local clip+noise, per-node accountants)")
    print(f"  simulated wall-clock : {gdp.wall_clock:.2f} s")
    print(f"  bytes on wire        : {gdp.bytes_on_wire:,.0f}")
    print(f"  epsilon spent (max)  : {gdp.epsilon:.2f}  "
          f"(vs DeCaPH's {dec.epsilon:.2f} for the same rounds)")
    print(f"  consensus accuracy   : {accuracy(gdp.params):.3f}  "
          f"(the local-DP utility tax relative to gossip's "
          f"{accuracy(gos.params):.3f})")


if __name__ == "__main__":
    main()
