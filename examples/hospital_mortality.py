"""Case study 1 (paper Fig. 2): eight-hospital mortality prediction.

Runs all four arms — Local / FL / PriMIA / DeCaPH — on the GEMINI-like
synthetic EHR task and prints the comparison table.

Run:  PYTHONPATH=src python examples/hospital_mortality.py [--rounds 60]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig, normalize_participants,
    run_decaph, run_fl, run_local, run_primia,
)
from repro.core.mia import auroc
from repro.data import make_gemini_like
from repro.data.partition import train_test_split_silos
from repro.models.tabular import make_mlp_classifier


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--rounds", type=int, default=60)
    p.add_argument("--n-total", type=int, default=2400)
    p.add_argument("--eps", type=float, default=2.0)
    args = p.parse_args()

    silos = normalize_participants(make_gemini_like(seed=0, n_total=args.n_total))
    train, tx, ty = train_test_split_silos(silos, 0.2, seed=0)
    sizes = [len(s) for s in train]
    print(f"hospitals: {len(train)}, sizes: {sizes}")

    model = make_mlp_classifier([436, 64, 16, 1], "binary")
    # Calibrate sigma so the DP arms can use every round within the budget
    # (the paper: "carefully calibrating the privacy-related hyperparameters")
    from repro.core.accountant import sigma_for_epsilon

    rate = 128 / sum(sizes)
    sigma = sigma_for_epsilon(rate, args.rounds, args.eps, 1e-5)
    print(f"calibrated sigma = {sigma:.3f} for eps = {args.eps}")
    cfg = FederationConfig(
        rounds=args.rounds, batch_size=128, lr=0.5, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=1.0, noise_multiplier=sigma, microbatch_size=16),
        epsilon_budget=args.eps,
    )

    def evaluate(params):
        s = np.asarray(model.predict_fn(params, jnp.asarray(tx)))
        return auroc(s, ty.astype(np.int32))

    print(f"{'arm':10s} {'AUROC':>8s} {'epsilon':>8s}")
    fl = run_fl(model, train, cfg)
    print(f"{'FL':10s} {evaluate(fl.params):8.4f} {'-':>8s}")
    dc = run_decaph(model, train, cfg)
    print(f"{'DeCaPH':10s} {evaluate(dc.params):8.4f} {dc.epsilon:8.3f}")
    pm = run_primia(model, train, cfg)
    print(f"{'PriMIA':10s} {evaluate(pm.params):8.4f} {pm.epsilon:8.3f}")
    lo = run_local(model, train, cfg)
    for i, params in enumerate(lo.per_client_params):
        print(f"{'local P%d' % (i+1):10s} {evaluate(params):8.4f} {'-':>8s}")


if __name__ == "__main__":
    main()
