"""End-to-end driver: DP (DeCaPH) language-model training at ~100M scale.

Wraps launch/train.py's machinery: a smollm-family model trained with
per-example clipping + aggregate noise on a synthetic multi-silo token
stream, a few hundred steps.  At the default demo scale this finishes in a
few minutes on CPU; pass --scale 100m --steps 300 for the full exercise.

Run:  PYTHONPATH=src python examples/llm_decaph.py [--steps 50]
"""

import argparse
import subprocess
import sys
import os


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--scale", default="smoke", choices=["smoke", "100m"])
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--arch", default="smollm-360m")
    args = p.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch,
        "--scale", args.scale,
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--sigma", "0.6",
        "--clip", "1.0",
        "--n-silos", "4",
        "--log-every", "10",
    ]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", os.path.join(os.path.dirname(__file__), "..", "src"))
    raise SystemExit(subprocess.run(cmd, env=env).returncode)


if __name__ == "__main__":
    main()
