"""Case study 2 (paper Fig. 3): cell-type classification across 5 studies.

The tiny "Wang"-like silo (P4) shows why collaboration matters: its local
model is far worse than any collaborative arm.

Run:  PYTHONPATH=src python examples/pancreas_cells.py [--genes 2000]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.dp import DPConfig
from repro.core.federation import (
    FederationConfig, normalize_participants,
    run_decaph, run_fl, run_local, run_primia,
)
from repro.data import make_pancreas_like
from repro.data.partition import train_test_split_silos
from repro.models.tabular import make_mlp_classifier

TYPES = ["alpha", "beta", "gamma", "delta"]


def median_f1(model, params, tx, ty):
    pred = np.asarray(model.predict_fn(params, jnp.asarray(tx))).argmax(-1)
    f1s = []
    for c in range(4):
        tp = ((pred == c) & (ty == c)).sum()
        fp = ((pred == c) & (ty != c)).sum()
        fn = ((pred != c) & (ty == c)).sum()
        f1s.append(2 * tp / max(2 * tp + fp + fn, 1))
    return float(np.median(f1s))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--genes", type=int, default=2000,
                   help="15558 for the paper's full dimension")
    p.add_argument("--rounds", type=int, default=40)
    args = p.parse_args()

    silos = make_pancreas_like(seed=0, n_total=1000, n_genes=args.genes)
    print("study sizes:", [len(s) for s in silos], "(P4 is the tiny study)")
    silos = normalize_participants(silos)
    train, tx, ty = train_test_split_silos(silos, 0.2, seed=0)

    model = make_mlp_classifier([args.genes, 128, 32, 4], "multiclass")
    cfg = FederationConfig(
        rounds=args.rounds, batch_size=96, lr=0.3, seed=0, use_secagg=False,
        dp=DPConfig(clip_norm=0.5, noise_multiplier=1.0, microbatch_size=8),
        epsilon_budget=5.6,            # the paper's pancreas budget
    )

    print(f"{'arm':10s} {'medianF1':>9s} {'epsilon':>8s}")
    fl = run_fl(model, train, cfg)
    print(f"{'FL':10s} {median_f1(model, fl.params, tx, ty):9.4f} {'-':>8s}")
    dc = run_decaph(model, train, cfg)
    print(f"{'DeCaPH':10s} {median_f1(model, dc.params, tx, ty):9.4f} "
          f"{dc.epsilon:8.3f}")
    pm = run_primia(model, train, cfg)
    print(f"{'PriMIA':10s} {median_f1(model, pm.params, tx, ty):9.4f} "
          f"{pm.epsilon:8.3f}")
    lo = run_local(model, train, cfg)
    for i, params in enumerate(lo.per_client_params):
        print(f"{'local P%d' % (i+1):10s} "
              f"{median_f1(model, params, tx, ty):9.4f} {'-':>8s}")


if __name__ == "__main__":
    main()
