"""Open-loop traffic: Poisson arrivals, reproducible from one seed.

``generate_requests`` materialises the WHOLE arrival schedule up front from
a single ``np.random.default_rng(seed)`` stream consumed in a fixed order
(gaps, then per-request prompt length / generation budget / prompt tokens),
so a ``BENCH_serve.json`` delta between two commits is attributable to
code, never to RNG (``tests/test_serve.py`` pins the schedule).  Prompt
lengths come from a small discrete bucket set — the prefill program traces
once per distinct length, so buckets bound compilation.

``run_open_loop`` replays the schedule against a ``ServeEngine`` in real
time: arrivals enter an admission queue, the queue drains into free slots,
and the engine decodes as fast as it can (open loop: the arrival process
never waits for the server, which is what makes p99 TTFT meaningful under
overload).  Between steps it polls a ``CheckpointWatcher`` for newly
published federation rounds and samples slot occupancy and checkpoint
staleness for the freshness trajectory.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One query: an arrival time, a prompt, and a generation budget.
    Timing fields are filled in by the engine as the request progresses."""

    rid: int
    arrival: float                 # seconds since traffic start
    prompt: np.ndarray             # int32 [prompt_len]
    max_new_tokens: int
    t_admit: float | None = None
    t_first: float | None = None   # first generated token (end of prefill)
    t_done: float | None = None
    round_at_first: int = -1       # checkpoint round serving the first token
    tokens: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Arrival process + per-request draws, all seeded."""

    rate: float                    # mean arrivals / second (Poisson)
    n_requests: int
    vocab_size: int
    prompt_lens: tuple[int, ...] = (8, 16, 32)
    prompt_probs: tuple[float, ...] | None = None   # None -> uniform
    gen_lens: tuple[int, ...] = (8, 16, 32)
    gen_probs: tuple[float, ...] | None = None
    seed: int = 0


def generate_requests(cfg: TrafficConfig) -> list[Request]:
    """The full schedule, deterministic in ``cfg.seed`` (and nothing else)."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.rate, cfg.n_requests)
    arrivals = np.cumsum(gaps)
    plens = rng.choice(cfg.prompt_lens, size=cfg.n_requests,
                       p=cfg.prompt_probs)
    gens = rng.choice(cfg.gen_lens, size=cfg.n_requests, p=cfg.gen_probs)
    return [
        Request(
            rid=i,
            arrival=float(arrivals[i]),
            prompt=rng.integers(0, cfg.vocab_size, int(plens[i])
                                ).astype(np.int32),
            max_new_tokens=int(gens[i]),
        )
        for i in range(cfg.n_requests)
    ]


@dataclasses.dataclass
class StepSample:
    """Per-decode-step observability row."""

    t: float                       # seconds since traffic start
    n_active: int                  # occupied slots during the step
    queue_depth: int
    serving_round: int
    latest_round: int              # newest published round at last poll

    @property
    def rounds_behind(self) -> int:
        if self.latest_round < 0:
            return 0
        return max(self.latest_round - max(self.serving_round, -1), 0)


@dataclasses.dataclass
class TraceResult:
    completed: list[Request]
    steps: list[StepSample]
    wall: float                    # harness wall-clock span (seconds)
    swaps: int
    decode_steps: int
    decode_dispatches: int
    admit_dispatches: int


def run_open_loop(
    engine,
    requests: Sequence[Request],
    *,
    watcher=None,
    poll_interval: float = 0.05,
    on_step: Callable[[int], None] | None = None,
    max_wall: float = 300.0,
    clock: Callable[[], float] = time.perf_counter,
) -> TraceResult:
    """Replay ``requests`` against ``engine`` in real time.

    ``on_step(step_idx)`` runs between decode steps (CI uses it to publish
    checkpoints inline — single-threaded and deterministic); ``watcher`` is
    polled every ``poll_interval`` seconds of harness time.  ``max_wall``
    is a hard stop so an overloaded configuration ends with truncated
    completions rather than a hung harness.
    """
    pending = collections.deque(sorted(requests, key=lambda r: r.arrival))
    queue: collections.deque[Request] = collections.deque()
    completed: list[Request] = []
    steps: list[StepSample] = []
    t0 = clock()
    last_poll = -poll_interval
    latest_round = -1
    swaps0 = engine.swaps
    steps0 = engine.decode_steps
    dd0, ad0 = engine.decode_dispatches, engine.admit_dispatches
    step_idx = 0
    while pending or queue or engine.busy():
        now = clock() - t0
        if now > max_wall:
            break
        while pending and pending[0].arrival <= now:
            queue.append(pending.popleft())
        while queue and engine.free_slots():
            r = queue.popleft()
            if engine.admit(r, now=clock() - t0):
                completed.append(r)   # finished at admission
        if watcher is not None and now - last_poll >= poll_interval:
            engine.poll_watcher(watcher)
            got = watcher.latest_round()
            latest_round = got if got is not None else latest_round
            last_poll = now
        if engine.busy():
            n_active = engine.active_count()
            done = engine.step(now=clock() - t0)
            completed.extend(done)
            steps.append(StepSample(
                t=now, n_active=n_active, queue_depth=len(queue),
                serving_round=engine.serving_round,
                latest_round=latest_round,
            ))
            if on_step is not None:
                on_step(step_idx)
            step_idx += 1
        elif pending:
            # idle: nothing decodable until the next arrival
            time.sleep(min(max(pending[0].arrival - now, 0.0), 0.002))
    return TraceResult(
        completed=completed, steps=steps, wall=clock() - t0,
        swaps=engine.swaps - swaps0,
        decode_steps=engine.decode_steps - steps0,
        decode_dispatches=engine.decode_dispatches - dd0,
        admit_dispatches=engine.admit_dispatches - ad0,
    )
