"""repro.serve — continuous-batching serving fed live by federation rounds.

The serving tier (DESIGN.md §9) closes the loop the paper leaves open: a
model that hospitals train collaboratively has to be *served* somewhere,
and the federation keeps improving it round by round.  Three pieces:

  * ``ServeEngine`` (``engine``) — fixed-slot continuous batching over any
    decoder-only arch; exactly one program launch + one host sync per
    steady-state decode step;
  * ``CheckpointPublisher`` / ``CheckpointWatcher`` (``handoff``) — the
    training→serving channel: atomic per-round snapshots in a watched
    directory, hot-swapped between decode steps without touching in-flight
    KV caches;
  * ``generate_requests`` / ``run_open_loop`` (``traffic``) + ``summarize``
    (``metrics``) — the open-loop Poisson harness behind the committed
    ``BENCH_serve.json``.

``python -m repro.serve`` runs a live demo or the bench sweep (see
``cli``); ``federation.train_and_publish`` wires any registered arm into
the publish side.
"""

from repro.serve.engine import ServeConfig, ServeEngine, batch_generate
from repro.serve.handoff import (
    CheckpointPublisher,
    CheckpointWatcher,
    checkpoint_path,
    list_rounds,
)
from repro.serve.metrics import render_markdown, summarize
from repro.serve.traffic import (
    Request,
    StepSample,
    TraceResult,
    TrafficConfig,
    generate_requests,
    run_open_loop,
)

__all__ = [
    "CheckpointPublisher",
    "CheckpointWatcher",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "StepSample",
    "TraceResult",
    "TrafficConfig",
    "batch_generate",
    "checkpoint_path",
    "generate_requests",
    "list_rounds",
    "render_markdown",
    "run_open_loop",
    "summarize",
]
