"""Continuous-batching serving engine (DESIGN.md §9).

A fixed-slot decode batch with per-slot KV-cache lifecycle:

  * **admit**: a request gets a free slot; its prompt is prefilled by ONE
    jitted program (``tf.prefill`` — a scan of the decode step, exact for
    every mixer family) which also samples the first generated token, and
    the prefilled per-request cache is spliced into the running batch cache
    by one more program (``dynamic_update_slice`` along the slot axis);
  * **decode**: one jitted program per step for the WHOLE batch —
    ``tf.decode_step_positions`` advances every slot at its own sequence
    position and the next token is sampled in-jit, so steady state is
    exactly 1 program launch + 1 host sync per token regardless of
    arrival/completion churn (the ``instrumented_jit`` counter certifies
    this in tests and CI, the same invariant DESIGN.md §7 pins for fused
    training rounds);
  * **evict**: EOS / token budget / context exhaustion frees the slot —
    pure host bookkeeping, zero dispatches; the stale KV rows are inert
    (free slots decode a dummy token but nothing reads their output) and
    are fully overwritten by the next admission's splice.

Params are just an argument to the decode program: hot-swapping a newly
published federation checkpoint (``handoff.CheckpointWatcher``) between
steps changes no shapes, triggers no recompile, and never touches the KV
cache — in-flight generations simply continue under the new weights.

Attention archs route single-query attention through the
``decode_attention`` kernel (Pallas on TPU, oracle elsewhere) via
``use_decode_kernel``.  MoE archs note: per-slot decode routes experts
with per-row capacity (no cross-request routing interference), which
deviates from aligned-batch ``decode_step`` at the dropped-token level.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.configs import get_config, get_smoke_config
from repro.instrument import instrumented_jit
from repro.models import transformer as tf
from repro.serve.traffic import Request

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (the model itself comes from ``arch``/``model_cfg``)."""

    arch: str = "smollm-360m"
    slots: int = 4                 # fixed decode-batch width
    max_len: int = 96              # per-slot KV capacity (prompt + generation)
    temperature: float = 1.0       # 0 = greedy
    eos_id: int | None = None      # None = budget-only termination
    seed: int = 0
    smoke: bool = True             # smoke-scale model config
    decode_kernel: bool = True     # route attn through decode_attention


@dataclasses.dataclass
class _Slot:
    request: Request
    position: int                  # next KV write index
    token: int                     # last sampled token (next step's input)
    emitted: int                   # generated tokens so far


class ServeEngine:
    """Fixed-slot continuous batching over any decoder-only arch."""

    def __init__(self, cfg: ServeConfig, *, model_cfg=None,
                 params: PyTree | None = None, round_idx: int = -1) -> None:
        self.cfg = cfg
        if model_cfg is None:
            model_cfg = (get_smoke_config(cfg.arch) if cfg.smoke
                         else get_config(cfg.arch))
        if model_cfg.is_encoder_decoder:
            raise ValueError(
                f"{model_cfg.name}: encoder-decoder archs need an encoder "
                "pass per request; the serving tier is decoder-only"
            )
        if cfg.decode_kernel:
            model_cfg = model_cfg.replace(use_decode_kernel=True)
        self.model_cfg = model_cfg
        self.params = (params if params is not None
                       else tf.init(model_cfg, jax.random.key(cfg.seed)))
        self.serving_round = round_idx   # -1 = seed weights, else ckpt round
        self.swaps = 0

        self.slots: list[_Slot | None] = [None] * cfg.slots
        self.cache = tf.init_cache(model_cfg, cfg.slots, cfg.max_len)
        self._key = jax.random.key(cfg.seed + 1)
        self._step_counter = 0
        self._admit_counter = 0
        # engine-local dispatch bookkeeping (the process-global counter in
        # ``repro.instrument`` also ticks; these let a harness attribute
        # launches to decode vs admission even when training shares the
        # process)
        self.decode_steps = 0
        self.decode_dispatches = 0
        self.admit_dispatches = 0

        mcfg, temp, max_len = model_cfg, cfg.temperature, cfg.max_len

        def _sample(logits, key):
            lg = logits[:, -1].astype(jnp.float32)
            if temp > 0:
                return jax.random.categorical(key, lg / temp, axis=-1
                                              ).astype(jnp.int32)
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)

        def decode_fn(params, cache, tokens, positions, key):
            logits, cache = tf.decode_step_positions(
                mcfg, params, cache, tokens, positions
            )
            return _sample(logits, key), cache

        def prefill_fn(params, tokens, key):
            cache = tf.init_cache(mcfg, 1, max_len)
            logits, cache = tf.prefill(mcfg, params, cache, tokens)
            return _sample(logits, key), cache

        def insert_fn(cache, slot_cache, slot):
            return jax.tree_util.tree_map(
                lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                    big, one.astype(big.dtype), slot, axis=1
                ),
                cache, slot_cache,
            )

        # exactly one program launch per steady-state decode step; admission
        # costs two (prefill + slot splice), amortised over the request
        self._decode = instrumented_jit(decode_fn, donate_argnums=(1,))
        self._prefill = instrumented_jit(prefill_fn)
        self._insert = instrumented_jit(insert_fn, donate_argnums=(0,))

    # -- params / handoff -----------------------------------------------------

    def set_params(self, params: PyTree, round_idx: int) -> None:
        """Hot-swap weights between decode steps.  Same pytree shapes ->
        same compiled programs; in-flight generations keep their KV cache
        and continue under the new params."""
        self.params = params
        self.serving_round = round_idx
        self.swaps += 1
        obs.counter("serve.swaps", 1, round=round_idx)

    def poll_watcher(self, watcher) -> bool:
        """Swap in the newest published checkpoint, if any.  True on swap."""
        got = watcher.poll()
        if got is None:
            return False
        params, round_idx, _meta = got
        self.set_params(params, round_idx)
        return True

    # -- slot lifecycle -------------------------------------------------------

    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    def active_count(self) -> int:
        return sum(s is not None for s in self.slots)

    def busy(self) -> bool:
        return any(s is not None for s in self.slots)

    def admit(self, request: Request, now: float = 0.0) -> bool:
        """Prefill ``request`` into a free slot.  Returns True if the
        request already finished at admission (1-token budget or instant
        EOS) — it then never occupies the slot."""
        if len(request.prompt) + 1 > self.cfg.max_len:
            raise ValueError(
                f"request {request.rid}: prompt of {len(request.prompt)} "
                f"tokens leaves no room to generate within max_len="
                f"{self.cfg.max_len}"
            )
        idx = next(i for i, s in enumerate(self.slots) if s is None)
        key = jax.random.fold_in(self._key, (self._admit_counter << 1) | 1)
        self._admit_counter += 1
        tokens = jnp.asarray(request.prompt, jnp.int32)[None]
        with obs.span("serve.admit", cat="serve", rid=request.rid,
                      prompt=len(request.prompt), slot=idx):
            tok0, slot_cache = self._prefill(self.params, tokens, key)
            self.cache = self._insert(self.cache, slot_cache,
                                      jnp.asarray(idx, jnp.int32))
            tok0 = int(np.asarray(tok0)[0])
        self.admit_dispatches += 2
        obs.counter("serve.admits", 1)
        request.t_admit = request.t_first = now
        request.round_at_first = self.serving_round
        request.tokens.append(tok0)
        budget = self._budget(request)
        if tok0 == self.cfg.eos_id or len(request.tokens) >= budget:
            request.t_done = now
            return True
        self.slots[idx] = _Slot(request, position=len(request.prompt),
                                token=tok0, emitted=1)
        return False

    def _budget(self, request: Request) -> int:
        """Generation budget: the request's ask, clamped to KV capacity."""
        return min(request.max_new_tokens,
                   self.cfg.max_len - len(request.prompt))

    def step(self, now: float = 0.0) -> list[Request]:
        """One decode step for the whole batch: 1 dispatch + 1 host sync.
        Returns the requests that finished this step (their slots are
        freed — pure host bookkeeping, no extra dispatch)."""
        tokens = np.zeros((self.cfg.slots, 1), np.int32)
        positions = np.zeros((self.cfg.slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i, 0] = s.token
                positions[i] = s.position
        key = jax.random.fold_in(self._key, self._step_counter << 1)
        self._step_counter += 1
        # span covers the dispatch AND the host sync: together they are the
        # per-token latency the metrics layer reports as TPOT
        with obs.span("serve.decode_step", cat="serve",
                      active=self.active_count()):
            nxt, self.cache = self._decode(
                self.params, self.cache, tokens, positions, key
            )
            nxt = np.asarray(nxt)  # the single per-token host sync
        self.decode_steps += 1
        self.decode_dispatches += 1
        obs.counter("serve.decode_steps", 1)
        finished: list[Request] = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tok = int(nxt[i])
            s.request.tokens.append(tok)
            s.position += 1
            s.token = tok
            s.emitted += 1
            if (tok == self.cfg.eos_id
                    or s.emitted >= self._budget(s.request)
                    or s.position + 1 > self.cfg.max_len):
                s.request.t_done = now
                finished.append(s.request)
                self.slots[i] = None   # evict: host bookkeeping only
        if finished:
            obs.counter("serve.evictions", len(finished))
        return finished


def batch_generate(engine: ServeEngine, prompts: np.ndarray, gen: int
                   ) -> np.ndarray:
    """Static-batch convenience used by the ``launch/serve`` shim: admit
    ``B <= slots`` equal-length prompts, decode until every request has
    ``gen`` tokens.  Returns the generated tokens [B, gen]."""
    b = prompts.shape[0]
    if b > engine.cfg.slots:
        raise ValueError(f"{b} prompts > {engine.cfg.slots} slots")
    requests = [
        Request(rid=i, arrival=0.0, prompt=np.asarray(prompts[i], np.int32),
                max_new_tokens=gen)
        for i in range(b)
    ]
    pending = [r for r in requests if not engine.admit(r)]
    while pending:
        done = engine.step()
        pending = [r for r in pending if r not in done]
    return np.stack([np.asarray(r.tokens[:gen], np.int64) for r in requests])
