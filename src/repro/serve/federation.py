"""Federation → serving glue: train a real transformer arm, publish rounds.

The serving tier consumes checkpoints through the ``on_round`` seam that
every backend honours (``repro.arms.backends.RunSetup.on_round``); this
module supplies the three pieces a live demo or CI job needs:

  * ``transformer_model`` — wraps the ``repro.models.transformer`` stack as
    the functional ``arms.Model`` triple, so ANY registered arm (decaph,
    fl, scaffold, gossip, ...) can train it unchanged;
  * ``token_silos`` — synthetic per-hospital next-token corpora (each silo
    draws from its own biased token distribution, the language-model
    analogue of the paper's non-IID hospital shards);
  * ``train_and_publish`` — ``arms.run(...)`` with a
    ``CheckpointPublisher.publish`` wired to ``on_round``, so a watcher on
    the publish directory sees round-N params the moment round N commits.

SecAgg defaults OFF here: the fixed-point encode of a transformer's
parameter tree is orders of magnitude heavier than the paper's MLP and
adds nothing to the handoff being exercised.
"""

from __future__ import annotations

import time
from typing import Sequence

import jax.numpy as jnp
import numpy as np

import repro.arms as arms
from repro.models import transformer as tf
from repro.serve.handoff import CheckpointPublisher

__all__ = ["transformer_model", "token_silos", "train_and_publish"]


def transformer_model(model_cfg, *, ghost_chunk: int | None = None) -> arms.Model:
    """The transformer stack as an ``arms.Model`` (per-example loss).

    Arms call ``loss_fn(params, ex)`` under ``vmap`` with ``ex = {"x", "y"}``
    one example per call: ``x`` is a token sequence ``[S] int32``, ``y`` the
    shifted labels (``-1`` = masked).  Padded rows are zero-weighted by the
    arm's mask, so the all-zeros pad examples never contribute.

    Dense decoder stacks with untied embeddings additionally declare the
    ghost-clipping capability (DESIGN.md §12): DP arms then compute their
    per-example-clipped gradient sums via ``core.ghost`` — exact norms, no
    per-example gradients — instead of vmapping ``loss_fn``.  ``ghost_chunk``
    bounds the ghost path's residual-activation memory per silo batch.
    """

    def init_fn(key):
        return tf.init(model_cfg, key)

    def loss_fn(params, ex):
        batch = {
            "tokens": ex["x"][None].astype(jnp.int32),
            "labels": ex["y"][None].astype(jnp.int32),
        }
        return tf.loss_fn(model_cfg, params, batch)

    def predict_fn(params, x):
        logits, _aux = tf.forward(
            model_cfg, params, {"tokens": x.astype(jnp.int32)}
        )
        return jnp.argmax(logits[:, -1], axis=-1)

    from repro.core import ghost as ghost_lib

    cap = None
    if ghost_lib._supported(model_cfg) and not model_cfg.tie_embeddings:
        # tied heads make the ghost head term an upper bound, not exact —
        # those configs (and MoE/SSM stacks, which mix examples inside a
        # dispatch) stay on the faithful per-example path.
        cap = arms.GhostCapability(model_cfg, chunk_size=ghost_chunk)
    return arms.Model(init_fn, loss_fn, predict_fn, ghost=cap)


def token_silos(
    model_cfg,
    *,
    hospitals: int,
    n_per: int,
    seq_len: int,
    seed: int = 0,
    skew: float = 2.0,
) -> list[arms.Participant]:
    """Synthetic non-IID next-token shards, one per hospital.

    Each silo samples from its own Zipf-tilted token distribution (silo h
    permutes the vocab differently, ``skew`` controls how peaked), so
    federated training has real cross-silo heterogeneity to average over.
    Labels are inputs shifted left with the final position masked (``-1``).
    Do NOT run these through ``normalize_participants`` — token ids are
    categorical, not features.
    """
    rng = np.random.default_rng(seed)
    vocab = model_cfg.vocab_size
    base = 1.0 / np.arange(1, vocab + 1) ** skew
    silos = []
    for h in range(hospitals):
        perm = rng.permutation(vocab)
        probs = base[perm] / base.sum()
        x = rng.choice(vocab, size=(n_per, seq_len), p=probs).astype(np.int32)
        y = np.full_like(x, -1)
        y[:, :-1] = x[:, 1:]
        silos.append(arms.Participant(x, y))
    return silos


def train_and_publish(
    arm: str,
    model_cfg,
    publish_dir: str,
    *,
    rounds: int,
    hospitals: int = 4,
    n_per: int = 32,
    seq_len: int = 16,
    batch_size: int = 16,
    lr: float = 0.05,
    seed: int = 0,
    backend: str = "ideal",
    keep_last: int | None = None,
    pace_s: float = 0.0,
    silos: Sequence[arms.Participant] | None = None,
    **run_kwargs,
):
    """Run ``arm`` on ``backend`` and publish every completed round.

    Returns ``(report, publisher)``; ``publisher.published`` lists the
    published round indices in order.  A ``CheckpointWatcher`` pointed at
    ``publish_dir`` (typically in the serving process) picks each one up on
    its next poll.  ``pace_s`` sleeps after each publish — at smoke scale a
    round completes in milliseconds, so pacing stands in for the real
    cross-hospital round cadence and lets a concurrent serving tier observe
    consecutive rounds instead of only the last.
    """
    model = transformer_model(model_cfg)
    if silos is None:
        silos = token_silos(model_cfg, hospitals=hospitals, n_per=n_per,
                            seq_len=seq_len, seed=seed)
    publisher = CheckpointPublisher(
        publish_dir, keep_last=keep_last,
        metadata={"arm": arm, "arch": model_cfg.name},
    )
    cfg = arms.ArmConfig(
        rounds=rounds, batch_size=batch_size, lr=lr, seed=seed,
        use_secagg=False,
    )
    on_round = publisher.publish
    if pace_s > 0:
        def on_round(t, params):  # noqa: F811 — paced variant
            publisher.publish(t, params)
            time.sleep(pace_s)
    report = arms.run(arm, model, list(silos), cfg, backend=backend,
                      on_round=on_round, **run_kwargs)
    return report, publisher
