"""Serving metrics: latency percentiles, occupancy, checkpoint freshness.

One ``TraceResult`` (a replayed arrival schedule) reduces to one flat
``summarize`` dict — the row format of ``BENCH_serve.json`` — and a set of
rows renders to the committed markdown report.  Latency definitions:

  * **TTFT**  (time-to-first-token): ``t_first - arrival`` — includes queue
    wait, so it is THE overload signal in an open-loop harness;
  * **TPOT**  (per-token latency): ``(t_done - t_first) / (n_tokens - 1)``
    for requests that generated more than one token;
  * **throughput**: generated tokens / harness wall-clock;
  * **occupancy**: mean occupied slots / slot count, over decode steps;
  * **freshness**: mean/max rounds-behind (newest published federation
    round minus the round being served) over decode steps, plus the number
    of mid-stream hot swaps.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

import numpy as np

from repro.serve.traffic import TraceResult


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def summarize(result: TraceResult, *, slots: int, rate: float,
              extra: Mapping[str, Any] | None = None) -> dict:
    """Flatten one trace into a ``BENCH_serve.json`` row."""
    done = [r for r in result.completed if r.t_first is not None]
    ttft = [r.t_first - r.arrival for r in done]
    tpot = [
        (r.t_done - r.t_first) / (len(r.tokens) - 1)
        for r in done
        if r.t_done is not None and len(r.tokens) > 1
    ]
    n_tokens = sum(len(r.tokens) for r in done)
    occ = [s.n_active for s in result.steps]
    behind = [s.rounds_behind for s in result.steps]
    # every ratio below must survive degenerate traces: zero completed
    # requests, zero decode steps (all 1-token budgets), zero wall (empty
    # schedule), or a slots=0 probe config
    row = {
        "rate_qps": rate,
        "slots": slots,
        "n_requests": len(result.completed),
        "n_tokens": n_tokens,
        "wall_s": round(result.wall, 4),
        "throughput_tok_s": round(n_tokens / result.wall, 2)
        if result.wall > 0 else 0.0,
        "ttft_p50_ms": round(_pct(ttft, 50) * 1e3, 2),
        "ttft_p95_ms": round(_pct(ttft, 95) * 1e3, 2),
        "ttft_p99_ms": round(_pct(ttft, 99) * 1e3, 2),
        "tpot_p50_ms": round(_pct(tpot, 50) * 1e3, 2),
        "tpot_p95_ms": round(_pct(tpot, 95) * 1e3, 2),
        "tpot_p99_ms": round(_pct(tpot, 99) * 1e3, 2),
        "occupancy": round(float(np.mean(occ)) / slots, 4)
        if occ and slots > 0 else 0.0,
        "decode_steps": result.decode_steps,
        "decode_dispatches": result.decode_dispatches,
        "dispatches_per_step": round(
            result.decode_dispatches / result.decode_steps, 4
        ) if result.decode_steps else 0.0,
        "admit_dispatches": result.admit_dispatches,
        "swaps": result.swaps,
        "staleness_rounds_mean": round(float(np.mean(behind)), 3)
        if behind else 0.0,
        "staleness_rounds_max": int(max(behind)) if behind else 0,
    }
    if extra:
        row.update(extra)
    return row


_MD_COLS = (
    ("rate_qps", "rate (q/s)"),
    ("throughput_tok_s", "tok/s"),
    ("ttft_p50_ms", "TTFT p50 (ms)"),
    ("ttft_p95_ms", "TTFT p95 (ms)"),
    ("ttft_p99_ms", "TTFT p99 (ms)"),
    ("tpot_p50_ms", "TPOT p50 (ms)"),
    ("tpot_p95_ms", "TPOT p95 (ms)"),
    ("tpot_p99_ms", "TPOT p99 (ms)"),
    ("occupancy", "occupancy"),
    ("dispatches_per_step", "disp/step"),
    ("swaps", "swaps"),
    ("staleness_rounds_mean", "stale (mean rounds)"),
    ("staleness_rounds_max", "stale (max)"),
)


def render_markdown(rows: Sequence[Mapping[str, Any]], *, title: str,
                    preamble: str = "") -> str:
    """The committed ``BENCH_serve.md``: one table row per arrival rate."""
    out = [f"# {title}", ""]
    if preamble:
        out += [preamble, ""]
    out.append("| " + " | ".join(h for _, h in _MD_COLS) + " |")
    out.append("|" + "|".join("---" for _ in _MD_COLS) + "|")
    for row in rows:
        out.append(
            "| " + " | ".join(str(row.get(k, "")) for k, _ in _MD_COLS) + " |"
        )
    out.append("")
    return "\n".join(out)
