"""Checkpoint handoff: training publishes rounds, serving hot-swaps them.

The channel is a watched directory of ``repro.checkpoint`` files named
``ckpt-<round>.msgpack``.  The writer side (``CheckpointPublisher``) is a
round-end hook for any training loop — ``repro.arms.run(..., on_round=
publisher.publish)`` wires it into every arm on every backend.  The reader
side (``CheckpointWatcher``) polls for the newest round it has not yet
served and loads it.

No locking anywhere: ``save_checkpoint`` renames a complete temp file into
place, so the watcher either sees the old directory listing or a complete
new file.  A file that is nonetheless broken (torn copy from another
machine, a crashed non-atomic writer) raises ``CorruptCheckpointError``
inside the watcher, which skips it and retries on the next poll instead of
taking the serving tier down.

Staleness is tracked in *rounds-behind*: ``latest_round - serving_round``.
The serving engine samples it every decode step, which is what turns the
utility-vs-epsilon story into utility-vs-epsilon-vs-freshness
(``BENCH_serve.json``).
"""

from __future__ import annotations

import logging
import os
import re
import time
from typing import Any

import jax

from repro.checkpoint import (
    CorruptCheckpointError,
    load_checkpoint,
    save_checkpoint,
)

PyTree = Any

logger = logging.getLogger(__name__)

_CKPT_RE = re.compile(r"^ckpt-(\d{8})\.msgpack$")


def checkpoint_path(root: str, round_idx: int) -> str:
    return os.path.join(root, f"ckpt-{round_idx:08d}.msgpack")


def list_rounds(root: str) -> list[int]:
    """Published round indices in ``root``, ascending."""
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return []
    rounds = []
    for name in names:
        m = _CKPT_RE.match(name)
        if m:
            rounds.append(int(m.group(1)))
    return sorted(rounds)


class CheckpointPublisher:
    """Round-end publish hook: snapshot params into the watched directory.

    ``publish(round_idx, params)`` matches the ``on_round`` callback
    signature of ``repro.arms.run``, so wiring federation training to a
    serving tier is one keyword argument.  ``keep_last`` bounds disk usage
    (old rounds are pruned after each publish; the newest always survives).
    """

    def __init__(self, root: str, *, keep_last: int | None = None,
                 metadata: dict | None = None) -> None:
        self.root = root
        self.keep_last = keep_last
        self.metadata = dict(metadata or {})
        self.published: list[int] = []
        os.makedirs(root, exist_ok=True)

    def publish(self, round_idx: int, params: PyTree) -> str:
        path = checkpoint_path(self.root, round_idx)
        meta = dict(self.metadata)
        meta["published_unix"] = time.time()
        save_checkpoint(path, params, step=round_idx, metadata=meta)
        self.published.append(round_idx)
        if self.keep_last is not None:
            for old in list_rounds(self.root)[: -self.keep_last]:
                try:
                    os.unlink(checkpoint_path(self.root, old))
                except OSError:
                    pass
        return path


class CheckpointWatcher:
    """Reader side: poll the directory, surface the newest unseen round.

    ``poll()`` returns ``(params, round_idx, metadata)`` when a round newer
    than everything previously returned is fully readable, else ``None``.
    Params come back as host-backed jax arrays; the caller decides where to
    put them (the serving engine just passes them as the next step's
    ``params`` argument — same shapes, no recompile).
    """

    def __init__(self, root: str) -> None:
        self.root = root
        self.seen_round = -1

    def latest_round(self) -> int | None:
        """Newest *published* round (cheap: one directory listing)."""
        rounds = list_rounds(self.root)
        return rounds[-1] if rounds else None

    def poll(self) -> tuple[PyTree, int, dict] | None:
        latest = self.latest_round()
        if latest is None or latest <= self.seen_round:
            return None
        try:
            tree, step, meta = load_checkpoint(
                checkpoint_path(self.root, latest)
            )
        except (CorruptCheckpointError, FileNotFoundError) as e:
            # skip-and-retry: a broken (or just-pruned) file must never
            # take serving down; the next publish supersedes it anyway
            logger.warning("watcher: skipping round %d: %s", latest, e)
            return None
        self.seen_round = latest
        return jax.device_put(tree), step, meta
