"""``python -m repro.serve`` — live serving demo / single-rate harness run.

Examples::

    # open-loop traffic against seed weights, smoke-scale model
    python -m repro.serve --arch smollm-360m --rate 4 --slots 4

    # serve while WATCHING a checkpoint directory someone else publishes to
    python -m repro.serve --watch /tmp/ckpts --rate 2

    # the full loop in one process: a federation trainer thread publishes
    # round checkpoints that the engine hot-swaps mid-traffic
    python -m repro.serve --train-rounds 6 --arm fl --rate 4

The multi-rate sweep that writes the committed ``BENCH_serve.json`` lives
in ``benchmarks/serve_bench.py``.
"""

from __future__ import annotations

import argparse
import json
import tempfile
import threading

import repro.obs as obs
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.handoff import CheckpointWatcher
from repro.serve.metrics import render_markdown, summarize
from repro.serve.traffic import TrafficConfig, generate_requests, run_open_loop


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="continuous-batching serving demo fed by federation "
                    "checkpoints",
    )
    p.add_argument("--arch", default="smollm-360m",
                   help="decoder-only arch name (repro.configs)")
    p.add_argument("--rate", type=float, default=4.0,
                   help="mean Poisson arrival rate, requests/second")
    p.add_argument("--slots", type=int, default=4,
                   help="fixed decode-batch width")
    p.add_argument("--max-len", type=int, default=96,
                   help="per-slot KV capacity (prompt + generation)")
    p.add_argument("--requests", type=int, default=32,
                   help="number of arrivals to replay")
    p.add_argument("--temperature", type=float, default=1.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--full", action="store_true",
                   help="full paper-scale config instead of smoke scale")
    p.add_argument("--watch", default=None, metavar="DIR",
                   help="hot-swap checkpoints published into DIR")
    p.add_argument("--train-rounds", type=int, default=0, metavar="N",
                   help="also run an in-process federation trainer thread "
                        "publishing N rounds (into --watch, or a temp dir)")
    p.add_argument("--arm", default="fl",
                   help="federation arm for --train-rounds")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the summary row as JSON")
    p.add_argument("--obs", default=None, metavar="DIR",
                   help="record obs spans/counters for the whole run and "
                        "export events + ledger + Chrome trace into DIR")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    rec = obs.enable() if args.obs else None
    engine = ServeEngine(ServeConfig(
        arch=args.arch, slots=args.slots, max_len=args.max_len,
        temperature=args.temperature, seed=args.seed, smoke=not args.full,
    ))
    watch_dir = args.watch
    trainer = None
    if args.train_rounds > 0:
        if watch_dir is None:
            watch_dir = tempfile.mkdtemp(prefix="repro-serve-ckpt-")
        from repro.serve.federation import train_and_publish

        # the trainer MUST train the arch being served: hot-swap relies on
        # identical parameter shapes (same compiled decode program)
        trainer = threading.Thread(
            target=train_and_publish,
            args=(args.arm, engine.model_cfg, watch_dir),
            kwargs={"rounds": args.train_rounds, "seed": args.seed,
                    "pace_s": 0.5},
            daemon=True,
        )
        trainer.start()
        print(f"trainer: {args.arm} x {args.train_rounds} rounds "
              f"-> {watch_dir}")
    watcher = CheckpointWatcher(watch_dir) if watch_dir else None

    tcfg = TrafficConfig(rate=args.rate, n_requests=args.requests,
                         vocab_size=engine.model_cfg.vocab_size,
                         seed=args.seed)
    requests = generate_requests(tcfg)
    print(f"serving {args.arch} ({'full' if args.full else 'smoke'} scale): "
          f"{args.requests} requests @ {args.rate} q/s, "
          f"{args.slots} slots, max_len {args.max_len}")
    result = run_open_loop(engine, requests, watcher=watcher)
    if trainer is not None:
        trainer.join(timeout=60.0)
    row = summarize(result, slots=args.slots, rate=args.rate,
                    extra={"arch": args.arch})
    print(render_markdown([row], title="repro.serve — single run"))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(row, f, indent=2)
        print(f"wrote {args.json}")
    if rec is not None:
        paths = obs.export(args.obs, rec)
        obs.disable()
        print(f"obs: wrote {', '.join(str(v) for v in paths.values())}")
    return 0
