"""RWKV6-3B ("Finch") — attention-free, data-dependent decay [arXiv:2404.05892]."""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        arch_type="ssm",
        citation="arXiv:2404.05892",
        d_model=2560,
        n_layers=32,
        n_heads=40,                  # d_model / rwkv_head_size
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        stack=((32, (LayerSpec("rwkv6", "dense"),)),),
        ffn_kind="relu2",            # RWKV channel-mix uses squared ReLU
        norm="rmsnorm",
        rope_type="none",
        tie_embeddings=False,
        rwkv_head_size=64,
        rwkv_decay_lora=64,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=16,
        remat=True,
        optimizer="adamw",
        lr=3e-4,
        long_context_mode="native",  # O(1) recurrent state
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        stack=((2, (LayerSpec("rwkv6", "dense"),)),),
        rwkv_head_size=32, rwkv_decay_lora=16,
        param_dtype="float32", compute_dtype="float32",
    )
