"""Architecture configs: one module per assigned architecture.

``get_config(arch_id)`` resolves the CLI ``--arch`` ids (dashes allowed) to
the full-size config; ``get_smoke_config(arch_id)`` returns the reduced
same-family variant used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

ARCHITECTURES = {
    "smollm-360m": "repro.configs.smollm_360m",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "gemma-7b": "repro.configs.gemma_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "whisper-small": "repro.configs.whisper_small",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
}

INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(arch_id: str):
    mod = importlib.import_module(ARCHITECTURES[arch_id])
    return mod.config()


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(ARCHITECTURES[arch_id])
    return mod.smoke_config()


def list_archs() -> list[str]:
    return list(ARCHITECTURES)
