"""Gemma-7B — dense, GeGLU, head_dim 256 [arXiv:2403.08295]."""

from repro.configs.base import ModelConfig, dense_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        arch_type="dense",
        citation="arXiv:2403.08295",
        d_model=3072,
        n_layers=28,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        stack=dense_stack(28),
        ffn_kind="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=16,
        remat=True,
        optimizer="adafactor",
        lr=1e-4,
        long_context_mode="window",
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=512, stack=dense_stack(2),
        param_dtype="float32", compute_dtype="float32",
    )
