"""Jamba-v0.1-52B — Mamba+attention 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887]."""

from repro.configs.base import LayerSpec, ModelConfig


def _pattern() -> tuple:
    # One 8-layer Jamba block: attention at index 3, MoE every other layer.
    return tuple(
        LayerSpec(
            mixer="attn" if j == 3 else "mamba",
            ffn="moe" if j % 2 == 1 else "dense",
        )
        for j in range(8)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        citation="arXiv:2403.19887",
        d_model=4096,
        n_layers=32,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        stack=((4, _pattern()),),
        ffn_kind="swiglu",
        norm="rmsnorm",
        tie_embeddings=False,
        n_experts=16,
        moe_top_k=2,
        expert_d_ff=14336,
        capacity_factor=1.25,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        mamba_dt_rank=256,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=1,
        remat=True,
        optimizer="adafactor",
        lr=1e-4,
        long_context_mode="native",   # hybrid: Mamba state + few attn layers
        long_context_window=8192,     # the 1:8 attn layers window at 500k
        sliding_window=None,
    )


def smoke_config() -> ModelConfig:
    pattern = (
        LayerSpec("mamba", "dense"),
        LayerSpec("attn", "moe"),
    )
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, expert_d_ff=256, vocab_size=512, n_experts=4, moe_top_k=2,
        stack=((1, pattern),), mamba_dt_rank=8,
        param_dtype="float32", compute_dtype="float32",
    )
