"""Input specs (ShapeDtypeStructs) for every (architecture x input shape).

``input_specs(cfg, shape_name)`` returns ``(cfg', specs, kind)`` where cfg'
carries any shape-specific overrides (e.g. the sliding-window variant dense
archs use at long_500k) and ``specs`` feeds ``jax.jit(...).lower(**specs)``
directly — nothing is allocated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES


class ShapeSkip(Exception):
    """Raised when an (arch, shape) pair is skipped (recorded in DESIGN.md)."""


def apply_shape_overrides(cfg, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k":
        if cfg.long_context_mode == "skip":
            raise ShapeSkip(
                f"{cfg.name}: long_500k skipped ({cfg.arch_type}; see DESIGN.md)"
            )
        if cfg.long_context_mode == "window":
            cfg = cfg.replace(sliding_window=cfg.long_context_window or 8192)
    if shape["kind"] == "decode" and cfg.arch_type == "audio":
        pass  # decoder self-KV spans seq_len; cross-KV fixed at n_audio_ctx
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_specs(cfg, shape_name: str) -> dict:
    shape = INPUT_SHAPES[shape_name]
    b, s = shape["global_batch"], shape["seq_len"]
    if cfg.arch_type == "vlm":
        sv = int(s * cfg.vision_prefix_frac)
        st = s - sv
        return {
            "tokens": _sds((b, st), jnp.int32),
            "labels": _sds((b, st), jnp.int32),
            "vision_embeds": _sds((b, sv, cfg.d_model), cfg.cdtype),
            "mrope_positions": _sds((b, s, 3), jnp.int32),
        }
    if cfg.arch_type == "audio":
        return {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
            "frames": _sds((b, cfg.n_audio_ctx, cfg.d_model), cfg.cdtype),
        }
    return {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }


def prefill_specs(cfg, shape_name: str) -> dict:
    specs = train_specs(cfg, shape_name)
    specs.pop("labels", None)
    return specs


def decode_specs(cfg, shape_name: str) -> dict:
    from repro.models import transformer

    shape = INPUT_SHAPES[shape_name]
    b, s = shape["global_batch"], shape["seq_len"]
    cache = transformer.cache_spec(cfg, b, s)
    specs = {
        "tokens": _sds((b, 1), jnp.int32),
        "cache": cache,
        "index": _sds((), jnp.int32),
    }
    return specs


def input_specs(cfg, shape_name: str):
    """-> (cfg_with_overrides, specs_dict, kind in {train, prefill, decode})."""
    cfg = apply_shape_overrides(cfg, shape_name)
    kind = INPUT_SHAPES[shape_name]["kind"]
    if kind == "train":
        return cfg, train_specs(cfg, shape_name), kind
    if kind == "prefill":
        return cfg, prefill_specs(cfg, shape_name), kind
    return cfg, decode_specs(cfg, shape_name), kind
