"""OLMo-1B — dense, non-parametric LayerNorm [arXiv:2402.00838]."""

from repro.configs.base import ModelConfig, dense_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        arch_type="dense",
        citation="arXiv:2402.00838",
        d_model=2048,
        n_layers=16,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        stack=dense_stack(16),
        ffn_kind="swiglu",
        norm="ln_nonparam",          # OLMo's non-parametric LN
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=16,
        remat=True,
        optimizer="adamw",
        lr=3e-4,
        long_context_mode="window",
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, stack=dense_stack(2),
        param_dtype="float32", compute_dtype="float32",
    )
