"""ModelConfig schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"       # attn | mla | mamba | rwkv6
    ffn: str = "dense"        # dense | moe
    cross_attn: bool = False  # whisper decoder blocks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # (repeat, pattern) groups; sum(repeat*len(pattern)) == n_layers
    stack: tuple[tuple[int, tuple[LayerSpec, ...]], ...] = ()
    ffn_kind: str = "swiglu"             # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"                # rmsnorm | ln_nonparam
    rope_type: str = "standard"          # standard | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (0, 0, 0)
    tie_embeddings: bool = True
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1                  # token groups (align w/ data shards)
    router_aux_coef: float = 0.01
    # --- MLA (DeepSeek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MTP (DeepSeek-V3 multi-token prediction; opt-in) ---
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3
    # --- Mamba ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0
    # --- RWKV6 ---
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_chunk_impl: str = "states"   # states | quadratic (§Perf optimized)
    rwkv_chunk: int = 32
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    n_audio_ctx: int = 0
    # --- VLM ---
    vision_prefix_frac: float = 0.0      # fraction of seq filled by patch embeds
    # --- attention windows ---
    sliding_window: int | None = None
    long_context_window: int | None = None  # window override used at long_500k
    # --- dtypes / perf ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    use_flash: bool = False
    use_decode_kernel: bool = False
    remat: bool = False
    scan_layers: bool = True
    # --- training defaults ---
    optimizer: str = "adamw"
    lr: float = 3e-4
    dp_clip: float = 1.0
    dp_sigma: float = 1.0
    dp_microbatch: int = 1
    ghost_chunk: int = 64     # examples per chunk on the ghost-clipping path
    # long_500k support: "native" | "window" | "skip"
    long_context_mode: str = "window"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def stack_layers(self) -> int:
        return sum(r * len(p) for r, p in self.stack)

    def validate(self) -> None:
        assert self.stack, "stack must be defined"
        assert self.stack_layers() == self.n_layers, (
            f"{self.name}: stack layers {self.stack_layers()} != n_layers {self.n_layers}"
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def dense_stack(n_layers: int, ffn: str = "dense") -> tuple:
    return ((n_layers, (LayerSpec("attn", ffn),)),)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for roofline MODEL_FLOPS)."""
    d, v = cfg.d_model, cfg.vocab_size
    total = v * d  # embeddings
    if not cfg.tie_embeddings:
        total += v * d
    enc_layers = cfg.encoder_layers

    def attn_params():
        return d * cfg.n_heads * cfg.head_dim * 2 + d * cfg.n_kv_heads * cfg.head_dim * 2

    def mla_params():
        return (
            d * cfg.q_lora_rank
            + cfg.q_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
            + d * cfg.kv_lora_rank
            + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            + d * cfg.qk_rope_dim
            + cfg.n_heads * cfg.v_head_dim * d
        )

    def mamba_params():
        di = cfg.mamba_expand * d
        return (
            d * 2 * di + cfg.mamba_d_conv * di
            + di * (2 * cfg.mamba_d_state + cfg.mamba_dt_rank)
            + cfg.mamba_dt_rank * di + di * cfg.mamba_d_state + 2 * di + di * d
        )

    def rwkv_params():
        return 5 * d * d + 2 * d * cfg.rwkv_decay_lora + 2 * d

    def ffn_params(kind: str):
        if kind == "moe":
            per_exp = 3 * d * cfg.expert_d_ff
            shared = 3 * d * cfg.expert_d_ff * cfg.n_shared_experts
            return cfg.n_experts * per_exp + shared + d * cfg.n_experts
        gated = cfg.ffn_kind in ("swiglu", "geglu")
        return (3 if gated else 2) * d * cfg.d_ff

    mixer_fns = {"attn": attn_params, "mla": mla_params,
                 "mamba": mamba_params, "rwkv6": rwkv_params}
    for repeat, pattern in cfg.stack:
        for spec in pattern:
            total += repeat * (mixer_fns[spec.mixer]() + ffn_params(spec.ffn))
            if spec.cross_attn:
                total += repeat * attn_params()
    total += enc_layers * (attn_params() + ffn_params("dense"))
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Params active per token (MoE: top-k + shared experts only)."""
    if cfg.n_experts == 0:
        return param_count(cfg)
    full = param_count(cfg)
    d = cfg.d_model
    per_exp = 3 * d * cfg.expert_d_ff
    n_moe_layers = sum(
        r for r, p in cfg.stack for s in p if s.ffn == "moe"
    )
    inactive = n_moe_layers * (cfg.n_experts - cfg.moe_top_k) * per_exp
    return full - inactive
