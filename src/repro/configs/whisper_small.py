"""Whisper-small — encoder-decoder audio backbone [arXiv:2212.04356].

Mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
input_specs ships frame embeddings [B, 1500, d_model].  Decoder positions are
sinusoidal (deviation from Whisper's learned embeddings, noted in DESIGN.md —
a 32k learned table would be pure padding at the contract shapes).
long_500k is SKIPPED for this arch (full-attention enc-dec; DESIGN.md §5).
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        arch_type="audio",
        citation="arXiv:2212.04356",
        d_model=768,
        n_layers=12,                  # decoder layers
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        stack=((12, (LayerSpec("attn", "dense", cross_attn=True),)),),
        ffn_kind="gelu",
        norm="layernorm",
        rope_type="none",
        tie_embeddings=True,
        encoder_layers=12,
        n_audio_ctx=1500,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=16,
        remat=True,
        optimizer="adamw",
        lr=1e-4,
        long_context_mode="skip",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        stack=((2, (LayerSpec("attn", "dense", cross_attn=True),)),),
        encoder_layers=2, n_audio_ctx=64,
        param_dtype="float32", compute_dtype="float32",
    )
