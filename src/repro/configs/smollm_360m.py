"""SmolLM-360M — llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import ModelConfig, dense_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        arch_type="dense",
        citation="hf:HuggingFaceTB/SmolLM-135M",
        d_model=960,
        n_layers=32,
        n_heads=15,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        stack=dense_stack(32),
        ffn_kind="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=16,
        remat=True,
        optimizer="adamw",
        lr=3e-4,
        long_context_mode="window",   # dense: long_500k via sliding window
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, stack=dense_stack(2),
        param_dtype="float32", compute_dtype="float32",
    )
