"""DeepSeek-V3-671B — MLA, 1 shared + 256 routed experts top-8 [arXiv:2412.19437].

First 3 layers dense FFN (d_ff 18432), remaining 58 MoE (expert d_ff 2048),
per the V3 report.  MLA decode uses the absorbed compressed-latent cache
(576 B/token/layer) — the native sub-quadratic-memory long-context path.
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        citation="arXiv:2412.19437",
        d_model=7168,
        n_layers=61,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,                   # dense-layer FFN width
        vocab_size=129280,
        stack=(
            (3, (LayerSpec("mla", "dense"),)),
            (58, (LayerSpec("mla", "moe"),)),
        ),
        ffn_kind="swiglu",
        norm="rmsnorm",
        tie_embeddings=False,
        n_experts=256,
        moe_top_k=8,
        n_shared_experts=1,
        expert_d_ff=2048,
        capacity_factor=1.25,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=1,
        optimizer="adafactor",
        lr=1e-4,
        remat=True,
        long_context_mode="native",   # MLA compressed cache
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, expert_d_ff=64, vocab_size=512,
        n_experts=4, moe_top_k=2, n_shared_experts=1,
        q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        stack=(
            (1, (LayerSpec("mla", "dense"),)),
            (1, (LayerSpec("mla", "moe"),)),
        ),
        remat=False,
        param_dtype="float32", compute_dtype="float32",
    )
