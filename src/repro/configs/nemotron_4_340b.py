"""Nemotron-4-340B — dense GQA, squared-ReLU FFN [arXiv:2402.16819]."""

from repro.configs.base import ModelConfig, dense_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        arch_type="dense",
        citation="arXiv:2402.16819",
        d_model=18432,
        n_layers=96,
        n_heads=96,
        n_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        stack=dense_stack(96),
        ffn_kind="relu2",
        norm="rmsnorm",
        tie_embeddings=False,
        rope_theta=10000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=1,
        optimizer="adafactor",     # factored states: fits the pod (DESIGN.md)
        lr=1e-4,
        remat=True,
        long_context_mode="window",
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=256, n_layers=2, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, stack=dense_stack(2), remat=False,
        param_dtype="float32", compute_dtype="float32",
    )
