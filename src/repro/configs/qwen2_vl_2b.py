"""Qwen2-VL-2B — VLM backbone with M-RoPE, dynamic resolution [arXiv:2409.12191].

Vision tower is a STUB per the assignment carve-out: input_specs provides
pre-projected patch embeddings of shape [B, S_v, d_model].
"""

from repro.configs.base import ModelConfig, dense_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        citation="arXiv:2409.12191",
        d_model=1536,
        n_layers=28,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        stack=dense_stack(28),
        ffn_kind="swiglu",
        norm="rmsnorm",
        rope_type="mrope",
        mrope_sections=(16, 24, 24),   # freq pairs per (t, h, w); sum = 64
        rope_theta=1000000.0,
        tie_embeddings=True,
        vision_prefix_frac=0.25,       # quarter of the sequence is patches
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=16,
        remat=True,
        optimizer="adamw",
        lr=1e-4,
        long_context_mode="window",
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, stack=dense_stack(2),
        mrope_sections=(4, 6, 6),
        param_dtype="float32", compute_dtype="float32",
    )
