"""Qwen3-30B-A3B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        citation="hf:Qwen/Qwen3-30B-A3B",
        d_model=2048,
        n_layers=48,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,                     # (per-expert hidden; all-MoE stack)
        vocab_size=151936,
        stack=((48, (LayerSpec("attn", "moe"),)),),
        ffn_kind="swiglu",
        norm="rmsnorm",
        tie_embeddings=False,
        n_experts=128,
        moe_top_k=8,
        n_shared_experts=0,
        expert_d_ff=768,
        capacity_factor=1.25,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        dp_microbatch=1,
        remat=True,
        optimizer="adafactor",
        lr=1e-4,
        long_context_mode="window",
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        d_model=128, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, expert_d_ff=64, vocab_size=512, n_experts=4, moe_top_k=2,
        stack=((2, (LayerSpec("attn", "moe"),)),),
        param_dtype="float32", compute_dtype="float32",
    )
