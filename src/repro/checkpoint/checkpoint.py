"""Pytree checkpointing on msgpack (no orbax dependency).

Arrays are gathered to host (fully-addressable) and serialised with dtype /
shape; the tree structure is stored as nested msgpack maps.  Step metadata
travels in the same file.  Atomic write via temp-file rename — a reader can
never observe a half-written checkpoint under the final name, which is what
lets the serving tier (``repro.serve.handoff``) watch a directory and load
whatever appears without coordinating with the writer.

Corrupted or truncated files (a torn copy, a crashed writer using plain
``open``) raise ``CorruptCheckpointError`` instead of whatever msgpack's
internals happen to throw, so watchers can skip-and-retry cleanly.
"""

from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_ARR = "__arr__"

# Stamped into every payload; load rejects files that don't carry it (an
# arbitrary msgpack blob that happens to parse is still not a checkpoint).
_FORMAT = "repro-ckpt-v1"


class CorruptCheckpointError(RuntimeError):
    """The file is not a complete, well-formed checkpoint."""


def _pack_leaf(x):
    arr = np.asarray(x)
    return {
        _ARR: True,
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _pack(tree):
    if isinstance(tree, dict):
        return {"__map__": {k: _pack(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": [_pack(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    return _pack_leaf(tree)


def _unpack(obj):
    if isinstance(obj, dict) and "__map__" in obj:
        return {k: _unpack(v) for k, v in obj["__map__"].items()}
    if isinstance(obj, dict) and "__seq__" in obj:
        seq = [_unpack(v) for v in obj["__seq__"]]
        return tuple(seq) if obj.get("__tuple__") else seq
    if isinstance(obj, dict) and obj.get(_ARR):
        arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"]))
        return jnp.asarray(arr.reshape(obj["shape"]))
    return obj


def save_checkpoint(path: str, tree: PyTree, step: int = 0,
                    metadata: dict | None = None) -> None:
    tree = jax.device_get(tree)
    payload = {
        "format": _FORMAT,
        "step": step,
        "metadata": metadata or {},
        "tree": _pack(tree),
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str) -> tuple[PyTree, int, dict]:
    """Load ``path`` -> (tree, step, metadata).

    Raises ``CorruptCheckpointError`` on truncated, torn, or non-checkpoint
    files (msgpack decode failures, missing payload keys, or array bytes
    that do not match their declared dtype/shape); ``FileNotFoundError``
    passes through untouched so watchers can distinguish "not there yet"
    from "there but broken".
    """
    with open(path, "rb") as f:
        raw = f.read()
    try:
        payload = msgpack.unpackb(raw, raw=False, strict_map_key=False)
    except Exception as e:
        raise CorruptCheckpointError(
            f"{path}: not a complete msgpack document ({e})"
        ) from e
    if not isinstance(payload, dict) or "tree" not in payload \
            or "step" not in payload:
        raise CorruptCheckpointError(
            f"{path}: msgpack document is not a checkpoint payload"
        )
    fmt = payload.get("format", _FORMAT)  # pre-format files pass
    if fmt != _FORMAT:
        raise CorruptCheckpointError(
            f"{path}: unsupported checkpoint format {fmt!r}"
        )
    try:
        tree = _unpack(payload["tree"])
    except Exception as e:
        raise CorruptCheckpointError(
            f"{path}: array payload does not match its declared "
            f"dtype/shape ({e})"
        ) from e
    return tree, payload["step"], payload.get("metadata", {})
