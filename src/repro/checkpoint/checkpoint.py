"""Pytree checkpointing on msgpack (no orbax dependency).

Arrays are gathered to host (fully-addressable) and serialised with dtype /
shape; the tree structure is stored as nested msgpack maps.  Step metadata
travels in the same file.  Atomic write via temp-file rename.
"""

from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_ARR = "__arr__"


def _pack_leaf(x):
    arr = np.asarray(x)
    return {
        _ARR: True,
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _pack(tree):
    if isinstance(tree, dict):
        return {"__map__": {k: _pack(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": [_pack(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    return _pack_leaf(tree)


def _unpack(obj):
    if isinstance(obj, dict) and "__map__" in obj:
        return {k: _unpack(v) for k, v in obj["__map__"].items()}
    if isinstance(obj, dict) and "__seq__" in obj:
        seq = [_unpack(v) for v in obj["__seq__"]]
        return tuple(seq) if obj.get("__tuple__") else seq
    if isinstance(obj, dict) and obj.get(_ARR):
        arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"]))
        return jnp.asarray(arr.reshape(obj["shape"]))
    return obj


def save_checkpoint(path: str, tree: PyTree, step: int = 0,
                    metadata: dict | None = None) -> None:
    tree = jax.device_get(tree)
    payload = {
        "step": step,
        "metadata": metadata or {},
        "tree": _pack(tree),
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str) -> tuple[PyTree, int, dict]:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    return _unpack(payload["tree"]), payload["step"], payload["metadata"]
