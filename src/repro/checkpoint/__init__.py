"""Msgpack pytree checkpointing."""

from repro.checkpoint.checkpoint import (
    CorruptCheckpointError,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "CorruptCheckpointError"]
