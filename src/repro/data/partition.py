"""Silo partitioners for turning a pooled dataset into participants."""

from __future__ import annotations

import numpy as np

from repro.arms.base import Participant


def sized_partition(x, y, proportions, seed: int = 0) -> list[Participant]:
    """Random partition with given size proportions."""
    rng = np.random.default_rng(seed)
    n = len(x)
    idx = rng.permutation(n)
    props = np.asarray(proportions, np.float64)
    props = props / props.sum()
    bounds = np.floor(np.cumsum(props) * n).astype(int)
    out, start = [], 0
    for b in bounds:
        sel = idx[start:b]
        out.append(Participant(x[sel], y[sel]))
        start = b
    return out


def dirichlet_partition(x, y, n_silos: int, alpha: float = 0.5,
                        seed: int = 0, n_classes: int | None = None
                        ) -> list[Participant]:
    """Label-skewed (non-IID) partition via per-class Dirichlet shares."""
    rng = np.random.default_rng(seed)
    y_int = y.astype(int) if y.ndim == 1 else y.argmax(-1).astype(int)
    classes = np.unique(y_int) if n_classes is None else np.arange(n_classes)
    silo_idx: list[list[int]] = [[] for _ in range(n_silos)]
    for c in classes:
        rows = np.nonzero(y_int == c)[0]
        rng.shuffle(rows)
        shares = rng.dirichlet(alpha * np.ones(n_silos))
        bounds = np.floor(np.cumsum(shares) * len(rows)).astype(int)
        bounds[-1] = len(rows)  # rounding must not drop examples
        start = 0
        for s, b in enumerate(bounds):
            silo_idx[s].extend(rows[start:b].tolist())
            start = b
    return [
        Participant(x[np.asarray(ix, int)], y[np.asarray(ix, int)])
        for ix in silo_idx
        if len(ix) > 0
    ]


def train_test_split_silos(silos, test_frac: float = 0.2, seed: int = 0):
    """Per-silo split (paper: 20% of each participant's data is test)."""
    rng = np.random.default_rng(seed)
    train, test_x, test_y = [], [], []
    for p in silos:
        idx = rng.permutation(len(p))
        k = int(len(p) * (1 - test_frac))
        train.append(Participant(p.x[idx[:k]], p.y[idx[:k]]))
        test_x.append(p.x[idx[k:]])
        test_y.append(p.y[idx[k:]])
    return train, np.concatenate(test_x), np.concatenate(test_y)
