"""Synthetic multi-silo datasets matching the paper's published statistics.

GEMINI EHR and the PhysioNet X-ray sets are access-gated (paper Data Sharing
section), so the reproduction uses synthetic generators engineered to match
the *published* dimensions, silo counts, silo-size skews, class imbalance and
inter-silo covariate shift — everything the framework's behaviour depends on.
DESIGN.md §2 records this substitution.

  * GEMINI-like: 436 features (categorical one-hot + numeric), 8 silos with
    the paper's heavy size skew, ~17% mortality rate, per-silo covariate shift.
  * Pancreas-like: 15,558 gene-count features (log1p), 5 silos (one tiny, as
    Wang is in the paper), 4 cell types, strong class signal.
  * X-ray-like: [H, W, 1] images, 3 silos, 4 multi-label outputs with
    label-dependent structured patterns.
  * LM stream: token sequences from a deterministic mixture process for the
    pod-scale training driver.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.arms.base import Participant


def _silo_props(published: "np.ndarray", n_silos: int) -> "np.ndarray":
    """Per-silo size proportions for any cohort size.

    Up to the published count the paper's proportions are used verbatim
    (same silo sizes as before for any given seed); beyond it the tail
    decays geometrically from the smallest published silo — capacity sweeps
    run H=10/20 cohorts the papers never enumerated.  Always renormalised
    to sum to 1.
    """
    if n_silos <= len(published):
        props = published[:n_silos]
    else:
        tail = published.min() * 0.8 ** np.arange(
            1, n_silos - len(published) + 1
        )
        props = np.concatenate([published, tail])
    return props / props.sum()


def _latent_binary_task(rng, n, d_feat, d_latent, w_scale=1.0):
    """Linear-logit ground truth in a latent space + nuisance dims."""
    w = rng.normal(0, w_scale, d_latent)
    proj = rng.normal(0, 1.0 / np.sqrt(d_latent), (d_latent, d_feat))
    z = rng.normal(0, 1, (n, d_latent))
    logits = z @ w
    y = (logits + rng.logistic(0, 1, n) > 0).astype(np.float32)
    x = z @ proj + rng.normal(0, 0.5, (n, d_feat))
    return x.astype(np.float32), y, (w, proj)


def make_gemini_like(
    seed: int = 0,
    n_total: int = 40114 // 8,   # scaled-down default; pass full for paper runs
    n_silos: int = 8,
    n_features: int = 436,
    mortality_rate: float = 0.17,
) -> list[Participant]:
    """8-hospital EHR-like binary mortality task with silo skew + shift."""
    rng = np.random.default_rng(seed)
    # Paper Fig 2a: hospital sizes are heavily skewed.
    props = _silo_props(
        np.array([0.22, 0.18, 0.15, 0.12, 0.10, 0.09, 0.08, 0.06]), n_silos
    )
    d_latent = 24
    shift_std = 0.8
    w = rng.normal(0, 1.2, d_latent)
    proj = rng.normal(0, 1.0 / np.sqrt(d_latent), (d_latent, n_features))
    # marginal z variance includes the inter-silo shift component
    bias = _solve_rate_bias(rng, w, d_latent, mortality_rate,
                            z_std=float(np.sqrt(1.0 + shift_std**2)))
    silos = []
    for i in range(n_silos):
        n = max(16, int(n_total * props[i]))
        # inter-hospital case-mix shift: calibrated so silo-local models
        # generalise poorly to the pooled test set (paper Fig 2c shows
        # per-hospital AUROC ~0.5) while collaborative models don't.
        shift = rng.normal(0, shift_std, d_latent)
        z = rng.normal(0, 1, (n, d_latent)) + shift
        logits = z @ w + bias
        y = (logits + rng.logistic(0, 1, n) > 0).astype(np.float32)
        x = z @ proj + rng.normal(0, 0.5, (n, n_features))
        # ~half the features behave like one-hot categoricals
        n_cat = n_features // 2
        x[:, :n_cat] = (x[:, :n_cat] > 0.8).astype(np.float32)
        silos.append(Participant(x.astype(np.float32), y))
    return silos


def _solve_rate_bias(rng, w, d_latent, rate, z_std=1.0, n_probe=20000):
    z = rng.normal(0, z_std, (n_probe, d_latent))
    logits = np.sort(z @ w)
    return -logits[int((1 - rate) * n_probe)]


def make_pancreas_like(
    seed: int = 0,
    n_total: int = 10548 // 4,
    n_silos: int = 5,
    n_genes: int = 15558,
    n_types: int = 4,
) -> list[Participant]:
    """5-study scRNA-like 4-class task; silo 4 tiny (paper's Wang study)."""
    rng = np.random.default_rng(seed)
    props = _silo_props(np.array([0.55, 0.20, 0.13, 0.02, 0.10]), n_silos)
    # informative genes per type (marker genes)
    n_marker = 120
    markers = rng.choice(n_genes, (n_types, n_marker), replace=True)
    class_probs = np.array([0.45, 0.35, 0.07, 0.13])[:n_types]
    class_probs = class_probs / class_probs.sum()
    silos = []
    for i in range(n_silos):
        n = max(24, int(n_total * props[i]))
        y = rng.choice(n_types, n, p=class_probs)
        base = rng.poisson(0.3, (n, n_genes)).astype(np.float32)
        batch_effect = rng.normal(0, 0.15, n_genes)   # study batch effect
        for c in range(n_types):
            rows = y == c
            base[np.ix_(rows, markers[c])] += rng.poisson(
                6.0, (rows.sum(), n_marker)
            )
        x = np.log10(base + 1.0) + batch_effect
        silos.append(Participant(x.astype(np.float32), y.astype(np.int32)))
    return silos


def make_xray_like(
    seed: int = 0,
    n_total: int = 1800,
    n_silos: int = 3,
    image_size: int = 32,
) -> list[Participant]:
    """3-study image task, 4 multilabel outputs with structured patterns."""
    rng = np.random.default_rng(seed)
    props = _silo_props(np.array([0.31, 0.24, 0.45]), n_silos)
    silos = []
    hw = image_size
    for i in range(n_silos):
        n = max(32, int(n_total * props[i]))
        has = rng.random((n, 3)) < np.array([0.18, 0.22, 0.12])
        no_finding = ~has.any(axis=1)
        y = np.concatenate([has, no_finding[:, None]], axis=1).astype(np.float32)
        x = rng.normal(0.45 + 0.05 * i, 0.18, (n, hw, hw, 1))  # silo intensity shift
        yy, xx = np.mgrid[0:hw, 0:hw] / hw
        for j in range(n):
            if has[j, 0]:  # "atelectasis": horizontal band in the upper half
                r = rng.integers(hw // 8, hw // 2)
                x[j, r - 1 : r + 2, :, 0] += 1.2
            if has[j, 1]:  # "effusion": bright lower wedge
                x[j, int(0.7 * hw) :, :, 0] += 1.0 * xx[int(0.7 * hw) :, :]
            if has[j, 2]:  # "cardiomegaly": strong central blob
                cx, cy = 0.5 + 0.05 * rng.standard_normal(2)
                blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 0.03))
                x[j, :, :, 0] += 1.8 * blob
        silos.append(
            Participant(x.astype(np.float32), y)
        )
    return silos


@dataclasses.dataclass
class LMStream:
    """Deterministic synthetic token stream for the pod-scale driver."""

    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, batch_size: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        # order-2 mixture process: next token depends on previous via a
        # banded transition, giving a learnable low-entropy structure
        v = self.vocab_size
        toks = np.empty((batch_size, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, batch_size)
        drift = rng.integers(1, 7, (batch_size, 1))
        noise = rng.integers(0, v, (batch_size, self.seq_len))
        use_noise = rng.random((batch_size, self.seq_len)) < 0.15
        for t in range(self.seq_len):
            nxt = (toks[:, t] + drift[:, 0]) % v
            toks[:, t + 1] = np.where(use_noise[:, t], noise[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


def make_lm_stream(vocab_size: int, seq_len: int, seed: int = 0) -> LMStream:
    return LMStream(vocab_size, seq_len, seed)
