"""Synthetic multi-silo data pipeline (real datasets are access-gated)."""

from repro.data.synthetic import (
    make_gemini_like,
    make_pancreas_like,
    make_xray_like,
    make_lm_stream,
)
from repro.data.partition import dirichlet_partition, sized_partition

__all__ = [
    "make_gemini_like",
    "make_pancreas_like",
    "make_xray_like",
    "make_lm_stream",
    "dirichlet_partition",
    "sized_partition",
]
