"""DeCaPH — the paper's framework, Steps 1-7, as a registered arm.

Shared Poisson rate, per-example clipping, per-participant noise shares
sized so the secure **sum** carries N(0, (C sigma)^2), SecAgg aggregation,
rotating facilitator, one shared RDP accountant over the aggregate dataset.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np

from repro.arms.base import (
    AggregationServices,
    ArmConfig,
    Contribution,
    Model,
    Participant,
    RoundArm,
    RoundOutcome,
    default_pad,
    poisson_batch,
    sgd_update,
    tree_div,
)
from repro.arms import fused
from repro.arms.registry import register
from repro.core import dp as dp_lib
from repro.core.accountant import RDPAccountant, steps_for_epsilon
from repro.core.leader import leader_schedule

_NOISE_SALT = 17  # legacy key derivation: fold_in(fold_in(key, 17 + t), i)


@register("decaph")
class DeCaPHArm(RoundArm):
    """The DeCaPH protocol (distributed-noise DP-SGD behind SecAgg)."""

    private = True
    secure_uploads = True
    void_logs = True            # an empty Poisson round is logged as NaN
    topology_kind = "full"      # any participant can facilitate
    fused_capable = True
    distributed_noise = True    # per-participant noise shares sum to (Cσ)²

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        n_total = sum(len(p) for p in self.participants)
        self.rate = cfg.batch_size / n_total
        self.pad = default_pad(self.rate, self.participants, cfg)
        self.leaders = leader_schedule(
            self.h, cfg.rounds, seed=cfg.seed, strategy=cfg.leader_strategy
        )
        # With cohort subsampling (participation_rate q < 1) an example's
        # marginal inclusion probability per round is q * rate — hospital
        # Poisson at q, then example Poisson at rate inside sampled
        # hospitals — so the accountant composes at that product (see
        # population.sampler for why this stays an upper bound).
        self.acct = RDPAccountant(
            sampling_rate=self.rate * cfg.participation_rate,
            noise_multiplier=cfg.dp.noise_multiplier,
            delta=cfg.dp.delta,
        )
        self._key = jax.random.key(cfg.seed)
        # Model-aware clipped-grad-sum seam (DESIGN.md §12): ghost clipping
        # for dense decoder stacks declaring the capability, faithful
        # per-example clipping otherwise.  Noise, keys and accounting are
        # identical either way — the path only changes how the clipped sum
        # is computed.
        clip_fn = self.clipped_grad_sum_fn(self.pad)
        self._clipped_sum = fused.instrumented_jit(
            lambda p, b, m: clip_fn(p, b, m)
        )

        def cohort_step(params, bx, by, masks, salt_t, idxs, n_shares):
            """Every participant's noised clipped sum + the cohort total in
            one program; noise keys fold in ``(salt_t, idx)`` exactly as the
            per-participant path does, so batching changes no draw."""

            def one(bx_i, by_i, m_i, idx):
                g_sum, loss = clip_fn(params, {"x": bx_i, "y": by_i}, m_i)
                nkey = jax.random.fold_in(
                    jax.random.fold_in(self._key, salt_t), idx
                )
                noised = dp_lib.tree_add_noise(
                    g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                    noise_multiplier=cfg.dp.noise_multiplier,
                    n_shares=n_shares,
                )
                return noised, loss

            stack, losses = jax.vmap(one)(bx, by, masks, idxs)
            return stack, fused.seq_tree_sum(stack, bx.shape[0]), losses

        self._fused_step, self._fused_step_slim = fused.instrumented_jit_pair(
            cohort_step, static_argnums=(6,)
        )

    # --- schedule -------------------------------------------------------------

    def planned_rounds(self) -> int:
        if self.cfg.epsilon_budget is None:
            return self.cfg.rounds
        return min(
            self.cfg.rounds,
            steps_for_epsilon(
                self.rate * self.cfg.participation_rate,
                self.cfg.dp.noise_multiplier,
                self.cfg.epsilon_budget, self.cfg.dp.delta,
                max_steps=self.cfg.rounds + 1,
            ),
        )

    def quorum(self) -> tuple[int, int | None]:
        # Running below the configured reconstruction threshold would
        # silently weaken the operator's security choice.
        if self.cfg.use_secagg:
            return max(2, self.cfg.secagg_threshold or 2), None
        return 2, None

    def round_cost(self, i: int) -> int:
        # expected Poisson draw, not the full batch: at H=1000 a hospital
        # contributes rate * |shard| examples per round in expectation
        return max(1, int(round(self.rate * len(self.participants[i]))))

    def facilitator(self, t: int, active: Sequence[int]) -> int:
        leader = int(self.leaders[t])
        if leader in active:
            return leader
        # shared-seed schedule: everyone deterministically skips to the
        # next online hospital
        return active[t % len(active)]

    # --- numerics ---------------------------------------------------------------

    def contribution(self, params, i, t, rng, n_shares):
        b, m, k = poisson_batch(rng, self.participants[i], self.rate, self.pad)
        g_sum, loss = self._clipped_sum(params, b, jax.numpy.asarray(m))
        nkey = jax.random.fold_in(
            jax.random.fold_in(self._key, _NOISE_SALT + t), i
        )
        noised = dp_lib.tree_add_noise(
            g_sum, nkey, clip_norm=self.cfg.dp.clip_norm,
            noise_multiplier=self.cfg.dp.noise_multiplier, n_shares=n_shares,
        )
        return Contribution(payload=noised, size=k, loss=float(loss))

    def fused_round(self, params, active, t, rng, n_shares, need_payloads,
                    need_reduced=True):
        cb = fused.stack_poisson(
            rng, self.participants, active, self.rate, self.pad
        )
        args = (params, cb.x, cb.y, cb.masks,
                np.int32(_NOISE_SALT + t), np.asarray(active, np.int32),
                n_shares)
        if need_reduced:
            stack, reduced, losses = self._fused_step(*args)
        else:
            (stack, losses), reduced = self._fused_step_slim(*args), None
        contribs = fused.build_contributions(
            active, stack, losses, cb.sizes, need_payloads
        )
        return contribs, reduced

    def aggregate(
        self,
        params,
        contributions: Mapping[int, Contribution],
        services: AggregationServices,
    ) -> RoundOutcome:
        order = sorted(contributions)
        agg_batch = services.sum_sizes([contributions[i].size for i in order])
        if agg_batch == 0:
            return RoundOutcome(params, stepped=False)
        total = services.sum_payloads(
            {i: contributions[i].payload for i in order}
        )
        grad = tree_div(total, agg_batch)
        params = sgd_update(params, grad, self.cfg.lr, self.cfg.weight_decay)
        loss = float(np.mean([contributions[i].loss for i in order]))
        return RoundOutcome(params, stepped=True, loss=loss,
                            aggregate_batch=agg_batch)

    # --- accounting -------------------------------------------------------------

    def account(self) -> None:
        self.acct.step()

    def epsilon(self) -> float:
        return self.acct.epsilon()

    def should_stop(self) -> bool:
        return (
            self.cfg.epsilon_budget is not None
            and self.acct.exceeds(self.cfg.epsilon_budget)
        )
