"""FL — the paper's non-private comparison arm (FedSGD / FedAvg).

``fl_local_steps == 1`` is FedSGD with DeCaPH's sampling/sync cadence (the
paper's FL arm; SL is equivalent for utility); ``> 1`` is FedAvg (McMahan et
al.): each client takes k local SGD steps per round and the server
size-weights the resulting weights.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.arms.base import (
    AggregationServices,
    ArmConfig,
    Contribution,
    Model,
    Participant,
    RoundArm,
    RoundOutcome,
    default_pad,
    poisson_batch,
    sgd_update,
    tree_div,
)
from repro.arms import fused
from repro.arms.registry import register


@register("fl")
class FLArm(RoundArm):
    """Server-based FL without DP (utility upper bound)."""

    requires_dst_online = True    # classic single point of failure
    topology_kind = "star"
    fused_capable = True

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        n_total = sum(len(p) for p in self.participants)
        self.rate = cfg.batch_size / n_total
        self.pad = default_pad(self.rate, self.participants, cfg)
        self.fedavg = cfg.fl_local_steps > 1

        def batch_grad(p, b, m):
            def masked_loss(pp):
                losses = jax.vmap(lambda ex: model.loss_fn(pp, ex))(b)
                return jnp.sum(losses * m)
            return jax.grad(masked_loss)(p)

        self._batch_grad_raw = batch_grad
        self._batch_grad = fused.instrumented_jit(batch_grad)

        def cohort_sgd(params, bx, by, masks):
            """FedSGD: every client's masked-sum gradient + the cohort
            total, one program."""
            stack = jax.vmap(
                lambda bx_i, by_i, m_i: batch_grad(
                    params, {"x": bx_i, "y": by_i}, m_i
                )
            )(bx, by, masks)
            return stack, fused.seq_tree_sum(stack, bx.shape[0])

        def cohort_avg(params, bx, by, masks, counts, weights):
            """FedAvg-family: every client's K local steps (scan) + the
            size-weighted average, one program.  Empty Poisson draws skip
            the step exactly like the loop path's ``continue``."""

            def one(bxs, bys, ms, ks):
                def step(local, inp):
                    bx_i, by_i, m_i, k_i = inp
                    g = self._local_step_grad(
                        local, {"x": bx_i, "y": by_i}, m_i, k_i, params
                    )
                    new = sgd_update(local, g, cfg.lr, cfg.weight_decay)
                    new = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(k_i > 0, a, b), new, local
                    )
                    return new, None

                local, _ = jax.lax.scan(step, params, (bxs, bys, ms, ks))
                return local

            stack = jax.vmap(one)(bx, by, masks, counts)
            return stack, fused.seq_weighted_sum(stack, weights, bx.shape[0])

        self._fused_sgd, self._fused_sgd_slim = \
            fused.instrumented_jit_pair(cohort_sgd)
        self._fused_avg, self._fused_avg_slim = \
            fused.instrumented_jit_pair(cohort_avg)

    def _local_step_grad(self, local, batch, mask, k, global_params):
        """One local step's gradient (FedProx overrides to add its proximal
        term).  ``k`` is the draw's real example count (traced int32)."""
        g = self._batch_grad_raw(local, batch, mask)
        return tree_div(g, jnp.maximum(k, 1))

    def _local_steps(self) -> int:
        return self.cfg.fl_local_steps

    def quorum(self) -> tuple[int, int | None]:
        # server-based FL stalls whenever the hub is offline
        return 1, self.cfg.fl_server

    def facilitator(self, t: int, active: Sequence[int]) -> int:
        return self.cfg.fl_server

    def contribution(self, params, i, t, rng, n_shares):
        part = self.participants[i]
        if not self.fedavg:  # FedSGD: one masked-sum gradient per client
            b, m, k = poisson_batch(rng, part, self.rate, self.pad)
            g = self._batch_grad(params, b, jnp.asarray(m))
            return Contribution(payload=g, size=k)
        # FedAvg: k local steps, upload the resulting weights
        local, consumed = params, 0
        for _ in range(self.cfg.fl_local_steps):
            b, m, k = poisson_batch(rng, part, self.rate, self.pad)
            if k == 0:
                continue
            g = self._batch_grad(local, b, jnp.asarray(m))
            g = tree_div(g, max(k, 1))
            local = sgd_update(local, g, self.cfg.lr, self.cfg.weight_decay)
            consumed += k
        return Contribution(payload=local, size=consumed)

    def fused_round(self, params, active, t, rng, n_shares, need_payloads,
                    need_reduced=True):
        if not self.fedavg:
            cb = fused.stack_poisson(
                rng, self.participants, active, self.rate, self.pad
            )
            if need_reduced:
                stack, reduced = self._fused_sgd(params, cb.x, cb.y, cb.masks)
            else:
                (stack,) = self._fused_sgd_slim(params, cb.x, cb.y, cb.masks)
                reduced = None
            return fused.build_contributions(
                active, stack, None, cb.sizes, need_payloads
            ), reduced
        cb = fused.stack_poisson(
            rng, self.participants, active, self.rate, self.pad,
            steps=self._local_steps(),
        )
        # f32 weights now so the in-jit weighted sum multiplies by exactly
        # the scalars the eager size-weighted average would
        sizes = [float(len(self.participants[i])) for i in active]
        wsum = sum(sizes)
        weights = np.asarray([w / wsum for w in sizes], np.float32)
        args = (params, cb.x, cb.y, cb.masks, cb.counts, weights)
        if need_reduced:
            stack, reduced = self._fused_avg(*args)
        else:
            (stack,), reduced = self._fused_avg_slim(*args), None
        return fused.build_contributions(
            active, stack, None, cb.sizes, need_payloads
        ), reduced

    def aggregate(
        self,
        params,
        contributions: Mapping[int, Contribution],
        services: AggregationServices,
    ) -> RoundOutcome:
        order = sorted(contributions)
        if not order:
            return RoundOutcome(params, stepped=False)
        if self.fedavg:  # size-weighted weight averaging
            if services.fused_reduced is not None:
                # the fused program already holds the weighted average
                return RoundOutcome(services.fused_reduced, stepped=True,
                                    aggregate_batch=self.cfg.batch_size)
            weights = [float(len(self.participants[i])) for i in order]
            wsum = sum(weights)
            params = jax.tree_util.tree_map(
                lambda *xs: sum(w / wsum * x for w, x in zip(weights, xs)),
                *[contributions[i].payload for i in order],
            )
            return RoundOutcome(params, stepped=True,
                                aggregate_batch=self.cfg.batch_size)
        agg = services.sum_sizes([contributions[i].size for i in order])
        if agg == 0:
            return RoundOutcome(params, stepped=False)
        total = services.sum_payloads(
            {i: contributions[i].payload for i in order}
        )
        grad = tree_div(total, agg)
        params = sgd_update(params, grad, self.cfg.lr, self.cfg.weight_decay)
        return RoundOutcome(params, stepped=True, aggregate_batch=agg)
