"""FL — the paper's non-private comparison arm (FedSGD / FedAvg).

``fl_local_steps == 1`` is FedSGD with DeCaPH's sampling/sync cadence (the
paper's FL arm; SL is equivalent for utility); ``> 1`` is FedAvg (McMahan et
al.): each client takes k local SGD steps per round and the server
size-weights the resulting weights.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.arms.base import (
    AggregationServices,
    ArmConfig,
    Contribution,
    Model,
    Participant,
    RoundArm,
    RoundOutcome,
    default_pad,
    poisson_batch,
    sgd_update,
    tree_div,
)
from repro.arms.registry import register


@register("fl")
class FLArm(RoundArm):
    """Server-based FL without DP (utility upper bound)."""

    requires_dst_online = True    # classic single point of failure
    topology_kind = "star"

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        n_total = sum(len(p) for p in self.participants)
        self.rate = cfg.batch_size / n_total
        self.pad = default_pad(self.rate, self.participants, cfg)
        self.fedavg = cfg.fl_local_steps > 1

        def batch_grad(p, b, m):
            def masked_loss(pp):
                losses = jax.vmap(lambda ex: model.loss_fn(pp, ex))(b)
                return jnp.sum(losses * m)
            return jax.grad(masked_loss)(p)

        self._batch_grad = jax.jit(batch_grad)

    def quorum(self) -> tuple[int, int | None]:
        # server-based FL stalls whenever the hub is offline
        return 1, self.cfg.fl_server

    def facilitator(self, t: int, active: Sequence[int]) -> int:
        return self.cfg.fl_server

    def contribution(self, params, i, t, rng, n_shares):
        part = self.participants[i]
        if not self.fedavg:  # FedSGD: one masked-sum gradient per client
            b, m, k = poisson_batch(rng, part, self.rate, self.pad)
            g = self._batch_grad(params, b, jnp.asarray(m))
            return Contribution(payload=g, size=k)
        # FedAvg: k local steps, upload the resulting weights
        local, consumed = params, 0
        for _ in range(self.cfg.fl_local_steps):
            b, m, k = poisson_batch(rng, part, self.rate, self.pad)
            if k == 0:
                continue
            g = self._batch_grad(local, b, jnp.asarray(m))
            g = tree_div(g, max(k, 1))
            local = sgd_update(local, g, self.cfg.lr, self.cfg.weight_decay)
            consumed += k
        return Contribution(payload=local, size=consumed)

    def aggregate(
        self,
        params,
        contributions: Mapping[int, Contribution],
        services: AggregationServices,
    ) -> RoundOutcome:
        order = sorted(contributions)
        if not order:
            return RoundOutcome(params, stepped=False)
        if self.fedavg:  # size-weighted weight averaging
            weights = [float(len(self.participants[i])) for i in order]
            wsum = sum(weights)
            params = jax.tree_util.tree_map(
                lambda *xs: sum(w / wsum * x for w, x in zip(weights, xs)),
                *[contributions[i].payload for i in order],
            )
            return RoundOutcome(params, stepped=True,
                                aggregate_batch=self.cfg.batch_size)
        agg = services.sum_sizes([contributions[i].size for i in order])
        if agg == 0:
            return RoundOutcome(params, stepped=False)
        total = services.sum_payloads(
            {i: contributions[i].payload for i in order}
        )
        grad = tree_div(total, agg)
        params = sgd_update(params, grad, self.cfg.lr, self.cfg.weight_decay)
        return RoundOutcome(params, stepped=True, aggregate_batch=agg)
