"""The Arm/Backend contract: write an arm's numerics once, run it anywhere.

An ``Arm`` declares *what* a federation protocol computes each round — local
updates, aggregation rule, privacy accounting, and what goes on the wire —
and nothing about *when*.  Two backends execute the same arm object:

  * ``LocalRunner`` — idealized lockstep: every hospital infinitely fast and
    always online, communication free (the paper's utility experiments);
  * ``SimRunner``  — the discrete-event engine from ``repro.sim``: simulated
    wall-clock, bytes-on-wire, stragglers, dropouts, SecAgg mask recovery.

The contract (DESIGN.md §5): an arm may never observe simulated time, node
availability, or the engine.  Its numerics must be a deterministic function
of (config seed, round index, participant index) plus the backend-supplied
draw stream, so that the two backends produce the same training trajectory
whenever the simulated conditions are ideal.

Randomness rules that make cross-backend equivalence hold:

  * round arms share one host ``np.random.Generator`` consumed strictly in
    (round, ascending participant index) order — both backends iterate the
    round's active cohort the same way;
  * node arms must hold one independent stream per node (the event backend
    interleaves nodes in simulated-time order, so a shared stream would be
    consumed in a schedule-dependent order);
  * JAX noise keys are derived by pure ``fold_in`` of (salt + round, index)
    and therefore never depend on execution order.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_lib

PyTree = Any

logger = logging.getLogger(__name__)


# -- model / data ------------------------------------------------------------


@dataclasses.dataclass
class Model:
    """Functional model triple shared by every arm."""

    init_fn: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, PyTree], jax.Array]  # (params, one example) -> scalar
    predict_fn: Callable[[PyTree, jax.Array], jax.Array]
    # optional capability: ghost-clipping support (arms/clipping.py).  Set by
    # constructors that know the model is a dense decoder stack with untied
    # embeddings; None = faithful per-example clipping only.
    ghost: Any | None = None


@dataclasses.dataclass
class Participant:
    """One hospital: a private (X, y) shard."""

    x: np.ndarray
    y: np.ndarray

    def __len__(self) -> int:
        return len(self.x)


def _global_stats(parts: Sequence[Participant]) -> tuple[np.ndarray, np.ndarray]:
    """Preparation-phase global mean/std via (conceptually) SecAgg sums."""
    n = sum(len(p) for p in parts)
    s = sum(p.x.sum(axis=0) for p in parts)
    mean = s / n
    sq = sum(((p.x - mean) ** 2).sum(axis=0) for p in parts)
    std = np.sqrt(sq / n) + 1e-8
    return mean.astype(np.float32), std.astype(np.float32)


def normalize_participants(parts: Sequence[Participant]) -> list[Participant]:
    mean, std = _global_stats(parts)
    return [Participant((p.x - mean) / std, p.y) for p in parts]


# -- configuration -----------------------------------------------------------


@dataclasses.dataclass
class ArmConfig:
    """One config for every arm on every backend.

    Supersedes ``FederationConfig`` and ``SimConfig`` (both remain as
    aliases); arm-specific knobs are simply ignored by arms that do not use
    them, which keeps scenario sweeps (same config, many arms) trivial.
    """

    rounds: int = 100
    batch_size: int = 64           # desired aggregate mini-batch size B
    lr: float = 0.1
    weight_decay: float = 0.0
    dp: dp_lib.DPConfig = dataclasses.field(default_factory=dp_lib.DPConfig)
    epsilon_budget: float | None = None   # stop when the accountant exceeds it
    use_secagg: bool = True        # run the real fixed-point SecAgg protocol
    secagg_frac_bits: int = 16
    secagg_threshold: int | None = None  # None -> majority of round's cohort
    fl_local_steps: int = 1        # >1 = FedAvg (weight averaging) for "fl"
    fedprox_mu: float = 0.1        # proximal-term weight for "fedprox"
    leader_strategy: str = "uniform"
    fused_rounds: bool = True      # cohort-batched round step (DESIGN.md §7)
    participation_rate: float = 1.0  # Poisson cohort subsampling q (population
                                     # backend; 1.0 = everyone, every round)
    clipping: str = "auto"         # per-example clipping path: "auto" takes
                                   # ghost when Model.ghost is set, "ghost"
                                   # demands it (validation error otherwise),
                                   # "per-example" forces the faithful path
    seed: int = 0
    eval_every: int = 0            # 0 = never
    max_pad_batch: int | None = None  # static padded per-silo batch (jit shapes)
    # systems knobs (sim backend only)
    bytes_per_param: float = 4.0
    fl_server: int = 0             # star hub for fl/primia
    # gossip-family knobs
    gossip_steps: int | None = None  # local steps per node; None -> rounds
    gossip_every: int = 1            # exchange after every k-th local step


# -- shared numerics helpers -------------------------------------------------


def poisson_batch(
    rng: np.random.Generator,
    part: Participant,
    rate: float,
    pad_to: int,
) -> tuple[dict[str, np.ndarray], np.ndarray, int]:
    """Poisson-sample a silo mini-batch, padded to a static shape + mask.

    The returned arrays have leading dimension ``pad_to`` — unless the
    Poisson draw selected *more* than ``pad_to`` examples, in which case the
    pad grows (next power of two that fits) rather than silently truncating
    the draw.  Truncation would bias the subsampling distribution and void
    the subsampled-RDP privacy analysis, so it must never happen quietly;
    the growth is logged because it retriggers jit tracing for that shape.
    """
    sel = rng.random(len(part)) < rate
    idx = np.nonzero(sel)[0]
    k = len(idx)
    if k > pad_to:
        grown = 1 << int(np.ceil(np.log2(k)))
        logger.warning(
            "poisson_batch: draw of %d examples exceeded the padded batch %d; "
            "growing the pad to %d for this round (jit retrace). Raise "
            "max_pad_batch to avoid this.", k, pad_to, grown,
        )
        pad_to = grown
    xb = np.zeros((pad_to,) + part.x.shape[1:], part.x.dtype)
    yb = np.zeros((pad_to,) + part.y.shape[1:], part.y.dtype)
    xb[:k] = part.x[idx]
    yb[:k] = part.y[idx]
    mask = np.zeros((pad_to,), np.float32)
    mask[:k] = 1.0
    return {"x": xb, "y": yb}, mask, k


def sgd_update(params: PyTree, grads: PyTree, lr: float, wd: float) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, g: p - lr * (g + wd * p), params, grads
    )


def tree_sum(trees: Sequence[PyTree]) -> PyTree:
    """Elementwise sum of a non-empty sequence of pytrees (stable order)."""
    return jax.tree_util.tree_map(lambda *xs: sum(xs[1:], xs[0]), *trees)


def tree_scale(tree: PyTree, s: float) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_div(tree: PyTree, d: float) -> PyTree:
    """Elementwise ``x / d`` (NOT ``x * (1/d)`` — one ulp matters for the
    seed-for-seed guarantee of the legacy shims)."""
    return jax.tree_util.tree_map(lambda x: x / d, tree)


def tree_bytes(tree: PyTree, bytes_per_param: float) -> float:
    """Bytes on the wire for one serialised copy of ``tree``."""
    return bytes_per_param * sum(
        int(np.prod(np.shape(leaf)) or 1)
        for leaf in jax.tree_util.tree_leaves(tree)
    )


def default_pad(rate: float, participants: Sequence[Participant],
                cfg: ArmConfig) -> int:
    """Static padded batch: 4x the largest silo's expected draw (legacy rule)."""
    return cfg.max_pad_batch or max(
        8, int(rate * max(len(p) for p in participants) * 4)
    )


# -- the per-round exchange types --------------------------------------------


@dataclasses.dataclass
class Contribution:
    """What one participant produces in one round.

    ``payload`` is the pytree that goes on the wire (gradient sum, noised
    gradient, or local weights — the arm decides); ``size`` is the number of
    real examples consumed (drives the sim backend's compute time and the
    aggregate batch count); ``loss`` is optional telemetry.
    """

    payload: PyTree
    size: int
    loss: float | None = None


@dataclasses.dataclass
class RoundOutcome:
    """What an arm's ``aggregate`` returns to the backend."""

    params: PyTree
    stepped: bool                 # False -> round void (no model update)
    loss: float = float("nan")
    aggregate_batch: int = 0


class AggregationServices:
    """Backend-provided aggregation primitives (see DESIGN.md §5).

    Secure aggregation is a *backend* service: the idealized backend runs the
    honest-but-curious ``SecAggSession`` over the payload trees, the sim
    backend runs the dropout-robust session over the ciphertexts it actually
    gathered (including Shamir mask recovery).  Arms only ever say "sum
    these" — they never see masks, shares, or ciphertexts.
    """

    # When the backend ran the arm's fused round-step it may hand the
    # already-reduced cohort aggregate back to ``aggregate`` here (the
    # idealized backend with plain sums); ``None`` means "sum it yourself".
    fused_reduced: PyTree | None = None

    def sum_sizes(self, sizes: Sequence[int]) -> int:  # pragma: no cover
        raise NotImplementedError

    def sum_payloads(
        self, payloads: Mapping[int, PyTree]
    ) -> PyTree:  # pragma: no cover
        raise NotImplementedError


# -- arm base classes --------------------------------------------------------


class Arm:
    """Base for all arms.  Subclass ``RoundArm`` or ``NodeArm``, not this."""

    name: str = ""
    mode: str = ""                 # "round" | "node"
    private: bool = False          # has an accountant / nonzero epsilon
    topology_kind: str = "full"    # natural sim topology: full | star | ring

    def __init__(
        self,
        model: Model,
        participants: Sequence[Participant],
        cfg: ArmConfig,
    ) -> None:
        if not participants:
            raise ValueError("need at least one participant")
        self.model = model
        self.participants = list(participants)
        self.cfg = cfg
        self.h = len(self.participants)

    # Privacy interface (shared by both modes).
    def epsilon(self) -> float:
        return 0.0

    def should_stop(self) -> bool:
        """Budget exceeded — the backend stops scheduling further rounds."""
        return False


class RoundArm(Arm):
    """Synchronous-round arm: contribute -> aggregate -> broadcast.

    The backend owns the cohort (who is online / eligible), the transport
    (free vs simulated), and the secure-sum transcript; the arm owns every
    number that ends up in the model.
    """

    mode = "round"
    secure_uploads = False        # payloads go through SecAgg when enabled
    requires_dst_online = False   # star hub must survive the whole round
    void_logs = False             # log a NaN round when nothing aggregates
    empty_break = False           # empty cohort ends the run (vs skipping)
    fused_capable = False         # overrides fused_round (backend capability
                                  # negotiation: fused-only backends refuse
                                  # arms without it)
    distributed_noise = False     # DP noise rides per-participant shares, so
                                  # a lost upload under-noises the sum (the
                                  # backend owes a top-up — DESIGN.md §10)

    def round_cost(self, i: int) -> int:
        """Expected examples participant ``i`` processes in one round (the
        trace phase's compute-time model; actual draws happen at solve)."""
        return min(self.cfg.batch_size, len(self.participants[i]))

    def clipped_grad_sum_fn(self, pad: int):
        """Model-aware clipped-grad-sum seam (DESIGN.md §12).

        Returns ``fn(params, {"x", "y"}, mask) -> (grad_sum, loss)``: the
        ghost path for models declaring the capability, the faithful
        ``dp.per_example_clipped_grad_sum`` otherwise — resolved once at arm
        construction so the choice is visible in ``clipping_path``.
        """
        from repro.arms import clipping as clipping_lib

        self.clipping_path = clipping_lib.resolve(self.model, self.cfg)
        return clipping_lib.clipped_grad_sum_fn(self.model, self.cfg, pad)

    # --- cohort / schedule ---------------------------------------------------

    def planned_rounds(self) -> int:
        """Idealized-backend round cap (e.g. pre-computed epsilon budget)."""
        return self.cfg.rounds

    def quorum(self) -> tuple[int, int | None]:
        """(minimum online nodes, required node index or None) to start."""
        return 1, None

    def participates(self, i: int, t: int) -> bool:
        """Eligibility beyond availability (e.g. local budget exhausted)."""
        return True

    def facilitator(self, t: int, active: Sequence[int]) -> int:
        """Who aggregates round ``t`` given the active cohort."""
        raise NotImplementedError

    # --- numerics ------------------------------------------------------------

    def init_params(self) -> PyTree:
        return self.model.init_fn(jax.random.key(self.cfg.seed))

    def contribution(
        self,
        params: PyTree,
        i: int,
        t: int,
        rng: np.random.Generator,
        n_shares: int,
    ) -> Contribution | None:
        """Participant ``i``'s upload for round ``t`` (None = sits out)."""
        raise NotImplementedError

    def fused_round(
        self,
        params: PyTree,
        active: Sequence[int],
        t: int,
        rng: np.random.Generator,
        n_shares: int,
        need_payloads: bool,
        need_reduced: bool = True,
    ) -> tuple[dict[int, Contribution], PyTree | None] | None:
        """The cohort-batched hot path (DESIGN.md §7): every active
        participant's contribution in ONE jit dispatch with ONE host sync
        for metrics, plus (optionally) the in-jit reduced cohort aggregate.

        Must consume ``rng`` exactly as the ``contribution()`` loop would.
        Return ``None`` to fall back to the per-participant loop (the
        default — arms opt in).  ``need_payloads=False`` means the backend
        will consume the reduced tree and per-participant payloads may be
        withheld (stay on device, never unstacked); ``need_reduced=False``
        means the backend will sum delivered payloads itself (sim
        transport, SecAgg) and the in-jit reduction may be skipped.
        """
        return None

    def aggregate(
        self,
        params: PyTree,
        contributions: Mapping[int, Contribution],
        services: AggregationServices,
    ) -> RoundOutcome:
        raise NotImplementedError

    def account(self) -> None:
        """Advance the accountant after a stepped round (no-op by default)."""


class NodeArm(Arm):
    """Per-node arm: independent models, local steps, optional gossip mixing.

    The backend drives the step loop (lockstep when idealized, event-ordered
    under simulated time) and performs the pairwise model averaging; the arm
    owns the local update and the exchange cadence/peer choice.
    """

    mode = "node"
    topology_kind = "ring"

    def steps_total(self) -> int:
        return self.cfg.gossip_steps or self.cfg.rounds

    def step_cost(self, i: int) -> int:
        """Examples one local step processes (sim compute-time model)."""
        return min(self.cfg.batch_size, len(self.participants[i]))

    def init_node_params(self, i: int) -> PyTree:
        raise NotImplementedError

    def local_step(
        self, i: int, params_i: PyTree, s: int
    ) -> tuple[PyTree, float, int] | None:
        """One local step; (new params, loss, examples) or None = retired."""
        raise NotImplementedError

    def wants_exchange(self, i: int, steps_done: int) -> bool:
        return False

    def select_peer(self, i: int, neighbors: Sequence[int]) -> int | None:
        return None

    def consensus(
        self, per_node_params: list[PyTree]
    ) -> tuple[PyTree, list[PyTree]]:
        """(headline params, per-node params) once every node finished."""
        return per_node_params[0], per_node_params
