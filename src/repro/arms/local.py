"""Silo-only baseline: one independent non-private model per hospital.

Note on randomness: each silo draws batches from its own stream seeded by
(config seed, silo index).  The pre-refactor ``run_local`` consumed a single
shared stream node-by-node, which cannot be reproduced under the event
backend (nodes interleave in simulated-time order) — per-node streams are
the arm-contract-compliant equivalent (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.arms.base import ArmConfig, Model, NodeArm, Participant, sgd_update
from repro.arms.registry import register


@register("local")
class LocalArm(NodeArm):
    """No collaboration: plain mini-batch SGD per silo."""

    topology_kind = "full"  # topology is irrelevant; zero bytes on wire

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        self._rngs = [
            np.random.default_rng([cfg.seed, i]) for i in range(self.h)
        ]
        self._bs = [min(cfg.batch_size, len(p)) for p in self.participants]

        def loss_and_grad(p, b):
            def mean_loss(pp):
                return jnp.mean(jax.vmap(lambda ex: model.loss_fn(pp, ex))(b))
            return jax.value_and_grad(mean_loss)(p)

        self._loss_and_grad = jax.jit(loss_and_grad)

    def steps_total(self) -> int:
        return self.cfg.rounds

    def init_node_params(self, i: int):
        return self.model.init_fn(jax.random.key(self.cfg.seed + i))

    def local_step(self, i, params_i, s):
        part, bs = self.participants[i], self._bs[i]
        idx = self._rngs[i].choice(len(part), size=bs, replace=False)
        b = {"x": jnp.asarray(part.x[idx]), "y": jnp.asarray(part.y[idx])}
        loss, g = self._loss_and_grad(params_i, b)
        params_i = sgd_update(params_i, g, self.cfg.lr, self.cfg.weight_decay)
        return params_i, float(loss), bs
