"""Model-aware clipped-grad-sum seam for the fused round-step (DESIGN.md §12).

Every DP arm needs the same primitive inside its cohort step: the sum of
per-example-clipped gradients over one silo's Poisson batch, plus the
mask-weighted mean loss.  Two implementations exist:

- ``core.dp.per_example_clipped_grad_sum`` — faithful, model-agnostic,
  materialises one gradient per example (microbatched).  Always correct.
- ``core.ghost.ghost_clipped_grad_sum`` — ghost clipping (Bu et al.): exact
  per-example norms from collector custom-VJPs in one batched backward, no
  per-example gradient ever materialised.  Supported only for dense decoder
  stacks (attention mixers + dense FFN, no experts/SSM — those mix examples
  across the batch inside a dispatch, breaking the per-example identity)
  with untied embeddings (the tied-head collector term is an upper bound).

Which one a model gets is a *capability*, not a heuristic: a transformer
``Model`` that can take the ghost path carries a ``GhostCapability`` in
``Model.ghost``; everything else (tabular models, MoE/SSM stacks, tied
embeddings) falls back to the faithful path.  ``ArmConfig.clipping`` selects
among {"auto", "ghost", "per-example"} and is validated loudly in
``arms.run`` — asking for "ghost" on a model without the capability is a
``ValueError`` at validation time, never a silent fallback mid-round.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

CLIPPING_MODES = ("auto", "ghost", "per-example")


@dataclasses.dataclass(frozen=True)
class GhostCapability:
    """Attached to ``Model.ghost`` when the ghost clipping path is exact.

    ``cfg`` is the transformer ModelConfig the ghost forward re-runs;
    ``chunk_size`` bounds residual-activation memory (None = whole batch in
    one chunk).  Constructors attach this only for dense decoder stacks with
    untied embeddings — see ``core.ghost._supported``.
    """

    cfg: Any
    chunk_size: int | None = None


def resolve(model, cfg) -> str:
    """Return the effective clipping path ("ghost" | "per-example").

    Loud: ``clipping="ghost"`` on a model without the capability raises
    instead of silently degrading to the per-example path.
    """
    mode = getattr(cfg, "clipping", "auto")
    if mode not in CLIPPING_MODES:
        raise ValueError(
            f"unknown clipping mode {mode!r}; expected one of {CLIPPING_MODES}"
        )
    cap = getattr(model, "ghost", None)
    if mode == "ghost":
        if cap is None:
            raise ValueError(
                "clipping='ghost' requires a model with a GhostCapability "
                "(dense decoder stack, untied embeddings); this model does "
                "not declare one — use clipping='auto' or 'per-example'"
            )
        return "ghost"
    if mode == "per-example":
        return "per-example"
    return "ghost" if cap is not None else "per-example"


def clipped_grad_sum_fn(model, cfg, pad: int) -> Callable:
    """Build ``fn(params, batch, mask) -> (grad_sum, loss)`` for one silo.

    ``batch`` is the arm-side ``{"x": [B, ...], "y": [B]}`` dict; ``mask``
    is the [B] Poisson-pad row mask.  The ghost branch adapts it to the
    transformer token layout and drops the norms from the return so both
    branches share one signature (and one jaxpr shape in the fused step).
    """
    from repro.core import dp as dp_lib

    path = resolve(model, cfg)
    if path == "per-example":
        micro = min(cfg.dp.microbatch_size, pad)

        def per_example(params, batch, mask):
            return dp_lib.per_example_clipped_grad_sum(
                model.loss_fn, params, batch,
                clip_norm=cfg.dp.clip_norm, microbatch_size=micro, mask=mask,
            )

        return per_example

    from repro.core import ghost as ghost_lib

    cap = model.ghost

    def ghost(params, batch, mask):
        gbatch = {"tokens": batch["x"].astype(jnp.int32),
                  "labels": batch["y"].astype(jnp.int32)}
        grads, loss, _norms = ghost_lib.ghost_clipped_grad_sum(
            cap.cfg, params, gbatch, clip_norm=cfg.dp.clip_norm,
            chunk_size=cap.chunk_size, mask=mask,
        )
        return grads, loss

    return ghost
