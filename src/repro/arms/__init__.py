"""repro.arms — write each federation arm once, run it on any backend.

The unified Arm/Backend API (DESIGN.md §5): an ``Arm`` declares a protocol's
per-round numerics (local update, aggregation, accounting, what goes on the
wire) with no notion of time; the backends execute it either idealized
(``LocalRunner`` — the paper's utility experiments) or under simulated time
(``SimRunner`` — wall-clock, bytes-on-wire, stragglers, dropout recovery).

    import repro.arms as arms
    report = arms.run("decaph", model, silos, arms.ArmConfig(rounds=20))
    timed  = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=nodes, topo=topo)

Registered arms: decaph, fl (FedSGD/FedAvg), fedprox (proximal-term FedAvg),
scaffold (control-variate FedAvg), primia (local-DP FL), local (silo-only),
gossip (async D-PSGD), gossip-dp (local-DP D-PSGD).
"""

from __future__ import annotations

from typing import Sequence

from repro.arms.base import (
    AggregationServices,
    Arm,
    ArmConfig,
    Contribution,
    Model,
    NodeArm,
    Participant,
    RoundArm,
    RoundOutcome,
    normalize_participants,
    poisson_batch,
    sgd_update,
    tree_bytes,
    tree_sum,
)
from repro.arms.registry import get, names, register
from repro.arms.results import RoundLog, RunReport, SimTiming
from repro.arms.runners import LocalRunner, SimRunner, default_topology

# importing the arm modules is what registers them
from repro.arms import decaph as _decaph          # noqa: F401
from repro.arms import fedprox as _fedprox        # noqa: F401
from repro.arms import fl as _fl                  # noqa: F401
from repro.arms import gossip as _gossip          # noqa: F401
from repro.arms import gossip_dp as _gossip_dp    # noqa: F401
from repro.arms import local as _local            # noqa: F401
from repro.arms import primia as _primia          # noqa: F401
from repro.arms import scaffold as _scaffold      # noqa: F401


def run(
    name: str,
    model: Model,
    participants: Sequence[Participant],
    cfg: ArmConfig,
    *,
    backend: str = "ideal",
    nodes=None,
    topo=None,
) -> RunReport:
    """Instantiate arm ``name`` and execute it on the chosen backend.

    ``backend="ideal"`` ignores ``nodes`` (everyone is infinitely fast);
    ``backend="sim"`` requires ``nodes`` (one ``HospitalNode`` per
    participant).  ``topo`` defaults to the arm's natural topology.
    """
    arm = get(name)(model, participants, cfg)
    if backend == "ideal":
        return LocalRunner(topo=topo).run(arm)
    if backend == "sim":
        if nodes is None:
            raise ValueError("backend='sim' needs nodes= (HospitalNode list)")
        if topo is None:
            topo = default_topology(arm.topology_kind, len(nodes),
                                    cfg.fl_server)
        return SimRunner(nodes, topo).run(arm)
    raise ValueError(f"unknown backend {backend!r}; use 'ideal' or 'sim'")


__all__ = [
    "AggregationServices",
    "Arm",
    "ArmConfig",
    "Contribution",
    "LocalRunner",
    "Model",
    "NodeArm",
    "Participant",
    "RoundArm",
    "RoundLog",
    "RoundOutcome",
    "RunReport",
    "SimRunner",
    "SimTiming",
    "default_topology",
    "get",
    "names",
    "normalize_participants",
    "poisson_batch",
    "register",
    "run",
    "sgd_update",
    "tree_bytes",
    "tree_sum",
]
