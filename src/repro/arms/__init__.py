"""repro.arms — write each federation arm once, run it on any backend.

The unified Arm/Backend API (DESIGN.md §5, §8): an ``Arm`` declares a
protocol's per-round numerics (local update, aggregation, accounting, what
goes on the wire) with no notion of time; a registry of *backends*
(``repro.arms.backends``) executes it — idealized (``LocalRunner`` — the
paper's utility experiments), under simulated time (``SimRunner`` —
wall-clock, bytes-on-wire, stragglers, dropout recovery), or SPMD on a
device mesh (``repro.launch.federated.ShardedRunner``).  Arm/backend pairs
are capability-negotiated: a combination the ``BackendInfo`` records rule
out fails loudly at validation time.

    import repro.arms as arms
    report = arms.run("decaph", model, silos, arms.ArmConfig(rounds=20))
    timed  = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=nodes, topo=topo)

Registered arms: decaph, fl (FedSGD/FedAvg), fedprox (proximal-term FedAvg),
scaffold (control-variate FedAvg), primia (local-DP FL), local (silo-only),
gossip (async D-PSGD), gossip-dp (local-DP D-PSGD).
"""

from __future__ import annotations

from typing import Sequence

import repro.obs as obs
from repro.arms.base import (
    AggregationServices,
    Arm,
    ArmConfig,
    Contribution,
    Model,
    NodeArm,
    Participant,
    RoundArm,
    RoundOutcome,
    normalize_participants,
    poisson_batch,
    sgd_update,
    tree_bytes,
    tree_sum,
)
from repro.arms import backends
from repro.arms import clipping
from repro.arms.backends import BackendInfo, RunSetup, register_backend
from repro.arms.clipping import GhostCapability
from repro.arms.registry import get, names, register
from repro.arms.results import RoundLog, RunReport, SimTiming
from repro.arms.runners import LocalRunner, SimRunner, default_topology

# importing the arm modules is what registers them
from repro.arms import decaph as _decaph          # noqa: F401
from repro.arms import fedprox as _fedprox        # noqa: F401
from repro.arms import fl as _fl                  # noqa: F401
from repro.arms import gossip as _gossip          # noqa: F401
from repro.arms import gossip_dp as _gossip_dp    # noqa: F401
from repro.arms import local as _local            # noqa: F401
from repro.arms import primia as _primia          # noqa: F401
from repro.arms import scaffold as _scaffold      # noqa: F401


def run(
    name: str,
    model: Model,
    participants: Sequence[Participant],
    cfg: ArmConfig,
    *,
    backend: str = backends.DEFAULT_BACKEND,
    nodes=None,
    topo=None,
    mesh=None,
    on_round=None,
) -> RunReport:
    """Instantiate arm ``name`` and execute it on the chosen backend.

    ``backend`` is any name from ``backends.backend_registry()``; the pair is
    capability-validated before any compute (an arm/backend/config combination
    the capabilities rule out fails loudly here, not mid-run).  Each backend
    consumes the ``RunSetup`` fields it understands — ``nodes`` (one
    ``HospitalNode`` per participant) for simulated time, ``mesh`` for SPMD —
    and rejects what it requires but did not get.  ``topo`` defaults to the
    arm's natural topology.

    ``on_round(t, params)`` is called after every completed round — the
    checkpoint-handoff seam that feeds the serving tier (DESIGN.md §9).
    """
    arm_cls = get(name)
    backend_cls = backends.get_backend(backend)
    backends.validate_run(arm_cls, backend_cls.info, cfg)
    # Clipping-path negotiation (DESIGN.md §12): the model is in scope here,
    # so an explicit clipping="ghost" against a model without the capability
    # fails before any compute, like every other invalid combination.
    clipping.resolve(model, cfg)
    runner = backend_cls.from_setup(
        backends.RunSetup(nodes=nodes, topo=topo, mesh=mesh,
                          on_round=on_round)
    )
    with obs.span("arms.run", cat="train", arm=name, backend=backend,
                  hospitals=len(participants)):
        return runner.run(arm_cls(model, participants, cfg))


__all__ = [
    "AggregationServices",
    "Arm",
    "ArmConfig",
    "BackendInfo",
    "Contribution",
    "LocalRunner",
    "RunSetup",
    "backends",
    "clipping",
    "register_backend",
    "GhostCapability",
    "Model",
    "NodeArm",
    "Participant",
    "RoundArm",
    "RoundLog",
    "RoundOutcome",
    "RunReport",
    "SimRunner",
    "SimTiming",
    "default_topology",
    "get",
    "names",
    "normalize_participants",
    "poisson_batch",
    "register",
    "run",
    "sgd_update",
    "tree_bytes",
    "tree_sum",
]
