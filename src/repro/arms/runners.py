"""The two host execution backends that consume registered arms.

Both register themselves with the backend registry (``repro.arms.backends``,
DESIGN.md §8); the SPMD ``shard`` backend lives in ``launch/federated.py``
and subclasses ``LocalRunner``'s round loop.

``LocalRunner`` is the idealized lockstep executor (every hospital
infinitely fast and always online, free communication) — it reproduces the
pre-refactor ``repro.core.federation.run_*`` loops seed-for-seed.
``SimRunner`` drives the *same arm object* through the discrete-event engine
(``repro.sim``), adding simulated wall-clock, bytes-on-wire, stragglers,
dropouts and SecAgg mask recovery — reproducing the pre-refactor
``repro.sim.protocols.simulate_*`` loops.

Backend-level services (never implemented inside an arm):
  * secure aggregation — honest-but-curious ``SecAggSession`` sums on the
    idealized backend, ``DropoutRobustSession`` ciphertexts + Shamir mask
    recovery on the sim backend;
  * gossip pairwise averaging — the backend applies the atomic pair average
    when an exchange lands (and models its transfer under simulated time);
  * the transport itself: gathers, broadcasts, and their byte accounting.
"""

from __future__ import annotations

import itertools
from typing import Any, Mapping, Sequence

import jax
import numpy as np

import repro.obs as obs
from repro.arms.backends import BackendInfo, RunSetup, register_backend
from repro.arms.base import (
    AggregationServices,
    Arm,
    Contribution,
    NodeArm,
    RoundArm,
    tree_bytes,
    tree_sum,
)
from repro.arms.results import RoundLog, RunReport, SimTiming
from repro.core import dp as dp_lib
from repro.core.secagg import (
    DropoutRobustSession,
    SecAggConfig,
    secagg_recovery_bytes,
    secure_sum,
    secure_sum_ints,
)
from repro.sim.engine import (
    ComputeDone,
    EventEngine,
    NodeDropout,
    NodeRejoin,
    TransferDone,
)
from repro.sim.nodes import HospitalNode
from repro.sim.topology import Topology

PyTree = Any

_SHARE_BYTES = 16.0  # one Shamir share on the wire (index + 61-bit y)


def default_topology(kind: str, n: int, center: int = 0) -> Topology:
    """The natural topology for an arm's ``topology_kind``."""
    if kind == "star":
        return Topology.star(n, center)
    if kind == "ring":
        return Topology.ring(n)
    return Topology.full(n)


# -- aggregation services ----------------------------------------------------


class _IdealServices(AggregationServices):
    """Free, lossless aggregation; SecAgg runs over the raw payload trees."""

    def __init__(self, cfg, n: int, t: int, secure: bool,
                 fused_reduced: PyTree | None = None,
                 cover: frozenset[int] | None = None) -> None:
        self._cfg, self._n, self._t, self._secure = cfg, n, t, secure
        self.fused_reduced = fused_reduced
        self._cover = cover

    def sum_sizes(self, sizes: Sequence[int]) -> int:
        if self._secure:
            # aggregate mini-batch size ||B^t|| via SecAgg — summed in the
            # field as integers (exact, no float fixed-point round-trip)
            return secure_sum_ints(
                list(sizes), n_participants=self._n,
                seed=self._cfg.seed * 7919 + self._t,
            )
        return int(sum(sizes))

    def sum_payloads(self, payloads: Mapping[int, PyTree]) -> PyTree:
        if (self.fused_reduced is not None
                and set(payloads) == self._cover):
            # the fused round-step already reduced the cohort in-jit, in
            # the same ascending-slot order an eager tree_sum would use
            return self.fused_reduced
        trees = [payloads[i] for i in sorted(payloads)]
        if self._secure:
            if len(trees) != self._n:
                raise ValueError(
                    "idealized SecAgg needs every participant's upload "
                    f"({len(trees)} of {self._n})"
                )
            return secure_sum(
                trees,
                SecAggConfig(self._n, self._cfg.secagg_frac_bits,
                             seed=self._cfg.seed + self._t),
            )
        if any(tr is None for tr in trees):
            raise RuntimeError(
                "fused round withheld per-participant payloads but the "
                "reduced sum does not cover this aggregation — arm and "
                "backend disagree about the cohort"
            )
        return tree_sum(trees)


class _SimServices(AggregationServices):
    """Sums over what actually arrived; SecAgg over gathered ciphertexts."""

    def __init__(self, session, uploads: dict[int, Any] | None,
                 topup: PyTree | None = None) -> None:
        self._session, self._uploads = session, uploads
        self._topup = topup

    def sum_sizes(self, sizes: Sequence[int]) -> int:
        return int(sum(sizes))

    def sum_payloads(self, payloads: Mapping[int, PyTree]) -> PyTree:
        if self._session is not None:
            # Shamir mask recovery for dropped participants happens inside
            # the session; the backend already charged its wire/time cost.
            total = self._session.aggregate(self._uploads)
        else:
            total = tree_sum([payloads[i] for i in sorted(payloads)])
        if self._topup is not None:
            # dropped participants took their noise shares with them: the
            # recovered sum is under-noised relative to the accountant's
            # calibration; the backend owes the difference (DESIGN.md §10)
            total = tree_sum([total, self._topup])
        return total


# -- idealized backend -------------------------------------------------------


@register_backend(BackendInfo(
    name="ideal",
    supports_fused=True,
    supports_secagg=True,
    supports_sim_time=False,
    bit_exact_group="host",
    description="idealized lockstep: every hospital infinitely fast and "
                "always online, communication free",
))
class LocalRunner:
    """Idealized lockstep execution of any registered arm."""

    def __init__(self, topo: Topology | None = None, on_round=None) -> None:
        self.topo = topo  # only node arms (gossip) consult it
        self.on_round = on_round

    @classmethod
    def from_setup(cls, setup: RunSetup) -> "LocalRunner":
        return cls(topo=setup.topo, on_round=setup.on_round)

    def run(self, arm: Arm) -> RunReport:
        if isinstance(arm, RoundArm):
            return self._run_rounds(arm)
        if isinstance(arm, NodeArm):
            return self._run_nodes(arm)
        raise TypeError(f"unknown arm mode {arm.mode!r} for {arm.name!r}")

    def _fused_round(self, arm: RoundArm, params, active, t, rng, *,
                     need_payloads: bool, need_reduced: bool):
        """The per-round fused-program seam: SPMD backends override this to
        run the same call under a mesh execution context."""
        return arm.fused_round(params, active, t, rng, len(active),
                               need_payloads=need_payloads,
                               need_reduced=need_reduced)

    def _run_rounds(self, arm: RoundArm) -> RunReport:
        cfg, h = arm.cfg, arm.h
        params = arm.init_params()
        model_bytes = tree_bytes(params, cfg.bytes_per_param)
        rng = np.random.default_rng(cfg.seed)
        logs: list[RoundLog] = []
        for t in range(arm.planned_rounds()):
          # spans buffer host timestamps only; with recording off this is a
          # shared no-op context (tests pin zero extra dispatches per round)
          with obs.span("round", cat="train", arm=arm.name,
                        backend=self.backend, t=t):
            active = [i for i in range(h) if arm.participates(i, t)]
            if not active:
                break  # nobody left who can contribute
            dst = arm.facilitator(t, active)
            secure = arm.secure_uploads and cfg.use_secagg
            contribs: dict[int, Contribution] | None = None
            reduced = None
            if cfg.fused_rounds:
                # one dispatch for the whole cohort; with SecAgg off the
                # reduced aggregate never leaves the device either
                with obs.span("fused_round", cat="train", t=t,
                              cohort=len(active)):
                    fr = self._fused_round(arm, params, active, t, rng,
                                           need_payloads=secure,
                                           need_reduced=not secure)
                if fr is not None:
                    contribs, reduced = fr
            if contribs is None:
                contribs = {}
                for i in active:  # ascending index: the arm-contract rng order
                    c = arm.contribution(params, i, t, rng, len(active))
                    if c is not None:
                        contribs[i] = c
            if not contribs:
                if arm.empty_break:
                    break
                continue
            services = _IdealServices(
                cfg, h, t, secure=secure,
                fused_reduced=None if secure else reduced,
                cover=frozenset(contribs),
            )
            # SecAgg (when on) runs inside aggregate via the services; the
            # span therefore covers reduce + secure-sum + the model step
            with obs.span("aggregate", cat="train", t=t, secure=secure):
                outcome = arm.aggregate(params, contribs, services)
            if outcome.stepped:
                params = outcome.params
                arm.account()
                obs.counter("rounds_completed", 1)
                obs.ledger_round(arm, round=t, backend=self.backend,
                                 cohort=active, delivered=contribs,
                                 bytes_up=model_bytes)
                logs.append(RoundLog(t, dst, outcome.loss, arm.epsilon(),
                                     outcome.aggregate_batch))
                if self.on_round is not None:
                    self.on_round(t, params)  # checkpoint-handoff seam
                if arm.should_stop():
                    break
            elif arm.void_logs:
                logs.append(RoundLog(t, dst, float("nan"), arm.epsilon(), 0))
        return RunReport(
            params=params, logs=logs, epsilon=arm.epsilon(),
            rounds_completed=len(logs), arm=arm.name, backend=self.backend,
        )

    def _run_nodes(self, arm: NodeArm) -> RunReport:
        cfg, h = arm.cfg, arm.h
        topo = self.topo or default_topology(arm.topology_kind, h,
                                             cfg.fl_server)
        per_node = [arm.init_node_params(i) for i in range(h)]
        steps_done = [0] * h
        retired = [False] * h
        total = arm.steps_total()
        logs: list[RoundLog] = []
        for s in range(total):
            losses, consumed, stepped = [], 0, []
            for i in range(h):
                if retired[i]:
                    continue
                r = arm.local_step(i, per_node[i], steps_done[i])
                if r is None:
                    retired[i] = True
                    continue
                per_node[i], loss, k = r
                steps_done[i] += 1
                losses.append(loss)
                consumed += k
                stepped.append(i)
            if not stepped:
                break  # every node retired
            # exchanges fire in ascending node order — the same order an
            # ideal uniform trace delivers them under the event backend
            for i in stepped:
                if arm.wants_exchange(i, steps_done[i]):
                    j = arm.select_peer(i, topo.neighbors(i))
                    if j is not None:
                        _average_pair(per_node, i, j)
            logs.append(RoundLog(s, -1, float(np.mean(losses)),
                                 arm.epsilon(), consumed))
        params, per_node = arm.consensus(per_node)
        if self.on_round is not None:
            # node arms have no server rounds; publish the consensus model
            # once, stamped with the completed step count
            self.on_round(min(steps_done), params)
        return RunReport(
            params=params, logs=logs, epsilon=arm.epsilon(),
            rounds_completed=min(steps_done), arm=arm.name,
            backend=self.backend, per_node_params=per_node,
        )


def _average_pair(per_node: list[PyTree], i: int, j: int) -> None:
    """Backend service: atomic pairwise model averaging (AD-PSGD style)."""
    avg = jax.tree_util.tree_map(
        lambda a, b: 0.5 * (a + b), per_node[i], per_node[j]
    )
    per_node[i] = avg
    per_node[j] = avg


# -- simulated-time backend --------------------------------------------------

# Every gather/broadcast stamps its events with a unique tag.  Events from a
# voided round can outlive the round (a dropped node's in-flight upload); the
# tag match keeps them from being mistaken for the current round's traffic.
_tag_counter = itertools.count()


@register_backend(BackendInfo(
    name="sim",
    supports_fused=True,
    supports_secagg=True,
    supports_sim_time=True,
    bit_exact_group="host",
    description="discrete-event engine: simulated wall-clock, bytes-on-wire, "
                "stragglers, dropouts, SecAgg mask recovery",
))
class SimRunner:
    """Discrete-event execution of any registered arm (PR-1 engine)."""

    def __init__(self, nodes: Sequence[HospitalNode],
                 topo: Topology | None = None, on_round=None) -> None:
        self.nodes = list(nodes)
        self.topo = topo  # None -> the arm's natural topology, resolved in run
        self.on_round = on_round
        # re-resolve per run: a reused runner must not pin the FIRST arm's
        # natural topology onto a second arm with a different topology_kind
        self._auto_topo = topo is None

    @classmethod
    def from_setup(cls, setup: RunSetup) -> "SimRunner":
        if setup.nodes is None:
            raise ValueError(
                "backend 'sim' needs nodes= (HospitalNode list)"
            )
        return cls(setup.nodes, setup.topo, on_round=setup.on_round)

    def _pop(self, engine: EventEngine):
        """Pop the next event, folding scheduled link churn into the topology
        up to the new simulated time before any link is consulted."""
        ev = engine.pop()
        if ev is not None:
            self.topo.advance_to(engine.now)
        return ev

    def run(self, arm: Arm) -> RunReport:
        if len(self.nodes) != arm.h:
            raise ValueError("one HospitalNode per participant required")
        if self._auto_topo:
            self.topo = default_topology(arm.topology_kind, len(self.nodes),
                                         arm.cfg.fl_server)
        self.topo.advance_to(0.0)  # fold in any t=0 schedule entries
        if isinstance(arm, RoundArm):
            return self._run_rounds(arm)
        if isinstance(arm, NodeArm):
            return self._run_nodes(arm)
        raise TypeError(f"unknown arm mode {arm.mode!r} for {arm.name!r}")

    # --- shared engine plumbing ---------------------------------------------

    def _engine(self) -> EventEngine:
        engine = EventEngine()
        for node in self.nodes:
            for t_off, t_on in node.dropouts:
                engine.schedule_at(t_off, NodeDropout(node.index))
                if t_on is not None:
                    engine.schedule_at(t_on, NodeRejoin(node.index))
        return engine

    def _apply_availability(self, ev) -> bool:
        """Handle dropout/rejoin events; True if ``ev`` was one of them."""
        if isinstance(ev, NodeDropout):
            self.nodes[ev.node].online = False
            return True
        if isinstance(ev, NodeRejoin):
            self.nodes[ev.node].online = True
            return True
        return False

    def _advance_to_quorum(
        self, engine: EventEngine, minimum: int, require: int | None
    ) -> tuple[int, bool]:
        """Fast-forward availability events until >= minimum nodes online
        (and, if given, node ``require`` — e.g. the star hub — is online)."""
        n_drop = 0
        while (
            sum(n.online for n in self.nodes) < minimum
            or (require is not None and not self.nodes[require].online)
        ):
            ev = self._pop(engine)
            if ev is None:
                return n_drop, False  # quorum never reachable again
            if self._apply_availability(ev):
                n_drop += isinstance(ev, NodeDropout)
        return n_drop, True

    def _gather_round(
        self,
        engine: EventEngine,
        dst: int,
        work: dict[int, tuple[Any, float, float]],
    ) -> tuple[dict[int, Any], set[int], float, int]:
        """One synchronous gather: every node computes, then uploads to
        ``dst``.  ``work[i] = (payload, compute_seconds, nbytes)``.  Returns
        ``(delivered, dropped_mid_round, bytes_on_wire, dropout_events)``.
        A node whose NodeDropout fires before its upload lands is excluded
        from ``delivered`` — exactly the case SecAgg recovery must handle."""
        nodes, topo = self.nodes, self.topo
        tag = f"sync-{next(_tag_counter)}"
        pending = set(work)
        delivered: dict[int, Any] = {}
        dropped_mid: set[int] = set()
        inflight: dict[int, int] = {}  # node -> cancel handle of next event
        wire = 0.0
        n_drop = 0
        for i, (payload, compute_s, nbytes) in work.items():
            inflight[i] = engine.schedule(
                compute_s, ComputeDone(i, tag=tag, payload=(payload, nbytes))
            )
        while pending:
            ev = self._pop(engine)
            if ev is None:
                break
            if self._apply_availability(ev):
                if isinstance(ev, NodeDropout):
                    n_drop += 1
                    if ev.node in pending:
                        pending.discard(ev.node)
                        dropped_mid.add(ev.node)
                        # the dropout kills the compute / connection: its
                        # upload must never arrive, so the aggregator never
                        # holds both a "dropped" ciphertext and its
                        # reconstructed pads
                        handle = inflight.pop(ev.node, None)
                        if handle is not None:
                            engine.cancel(handle)
                continue
            if isinstance(ev, ComputeDone) and ev.tag == tag:
                if not nodes[ev.node].online:
                    continue  # dropped during compute; already counted
                payload, nbytes = ev.payload
                if ev.node == dst:
                    delivered[ev.node] = payload
                    pending.discard(ev.node)
                    inflight.pop(ev.node, None)
                elif not topo.has_edge(ev.node, dst):
                    # link churn severed the path before the upload started;
                    # from the aggregator's view the node dropped mid-round
                    pending.discard(ev.node)
                    dropped_mid.add(ev.node)
                    inflight.pop(ev.node, None)
                else:
                    wire += nbytes
                    inflight[ev.node] = engine.schedule(
                        topo.transfer_time(ev.node, dst, nbytes),
                        TransferDone(ev.node, dst, nbytes, tag=tag,
                                     payload=payload),
                    )
            elif isinstance(ev, TransferDone) and ev.tag == tag:
                if ev.src in pending:
                    delivered[ev.src] = ev.payload
                    pending.discard(ev.src)
                    inflight.pop(ev.src, None)
        return delivered, dropped_mid, wire, n_drop

    def _broadcast(
        self, engine: EventEngine, src: int, nbytes: float,
        targets: Sequence[int],
    ) -> tuple[float, int]:
        """Send ``nbytes`` from ``src`` to each online target; barrier on
        arrival."""
        nodes, topo = self.nodes, self.topo
        tag = f"bcast-{next(_tag_counter)}"
        outstanding = 0
        wire = 0.0
        n_drop = 0
        for j in targets:
            if j == src or not nodes[j].online or not topo.has_edge(src, j):
                continue
            wire += nbytes
            outstanding += 1
            engine.schedule(
                topo.transfer_time(src, j, nbytes),
                TransferDone(src, j, nbytes, tag=tag),
            )
        while outstanding:
            ev = self._pop(engine)
            if ev is None:
                break
            if self._apply_availability(ev):
                n_drop += isinstance(ev, NodeDropout)
                continue
            if isinstance(ev, TransferDone) and ev.tag == tag:
                outstanding -= 1
        return wire, n_drop

    # --- round-based arms ----------------------------------------------------

    def _run_rounds(self, arm: RoundArm) -> RunReport:
        cfg, h = arm.cfg, arm.h
        nodes = self.nodes
        params = arm.init_params()
        rng = np.random.default_rng(cfg.seed)
        model_bytes = tree_bytes(params, cfg.bytes_per_param)
        engine = self._engine()
        wire = 0.0
        dropouts = recoveries = lost = completed = topups = 0
        logs: list[RoundLog] = []
        minimum, require = arm.quorum()
        topup_base = jax.random.key(cfg.seed * 31 + dp_lib.TOPUP_SALT)

        # planned_rounds() pre-caps for an epsilon budget exactly like the
        # idealized backend — without it the sim side would overshoot the
        # operator's budget by one round before should_stop() fires
        for t in range(arm.planned_rounds()):
          # same no-op-when-disabled discipline as the ideal runner: the span
          # context brackets every exit path (break/continue) of the round
          with obs.span("round", cat="train", arm=arm.name,
                        backend=self.backend, t=t):
            d, ok = self._advance_to_quorum(engine, minimum, require)
            dropouts += d
            if not ok:
                break
            active = [
                i for i in range(h)
                if nodes[i].online and arm.participates(i, t)
            ]
            if not active:
                if arm.empty_break:
                    break
                lost += 1
                continue
            dst = arm.facilitator(t, active)

            contribs: dict[int, Contribution] | None = None
            if cfg.fused_rounds:
                # one dispatch computes the whole cohort's contributions;
                # the transport below still ships them one by one
                # delivery may be partial, so the backend sums what arrives:
                # skip the in-jit reduction (XLA DCEs it in the slim variant)
                with obs.span("fused_round", cat="train", t=t,
                              cohort=len(active)):
                    fr = arm.fused_round(params, active, t, rng, len(active),
                                         need_payloads=True,
                                         need_reduced=False)
                if fr is not None:
                    contribs, _ = fr
            if contribs is None:
                contribs = {}
                for i in active:  # ascending index: the arm-contract rng order
                    c = arm.contribution(params, i, t, rng, len(active))
                    if c is not None:
                        contribs[i] = c
            if not contribs:
                if arm.empty_break:
                    break
                lost += 1
                continue

            session = None
            slot_of: dict[int, int] = {}
            if arm.secure_uploads and cfg.use_secagg:
                n_active = len(active)
                # quorum guarantees n_active >= any configured threshold
                threshold = cfg.secagg_threshold or (n_active // 2 + 1)
                session = DropoutRobustSession(
                    SecAggConfig(n_active, cfg.secagg_frac_bits,
                                 seed=cfg.seed * 6007 + t),
                    params, threshold=threshold,
                )
                wire += secagg_recovery_bytes(n_active)["setup_bytes"]
                slot_of = {i: s for s, i in enumerate(active)}

            ciphers = None
            if session is not None:
                # one host transfer + one masking pass for the whole cohort
                # (each participant still *ships* its own ciphertext below)
                with obs.span("secagg.encode", cat="secagg", t=t,
                              cohort=len(active)):
                    ciphers = session.upload_all(
                        {slot_of[i]: c.payload for i, c in contribs.items()}
                    )
            work = {}
            for i, c in contribs.items():
                payload = ciphers[slot_of[i]] if ciphers else c.payload
                work[i] = (payload, nodes[i].compute_time(c.size), model_bytes)
            with obs.span("transport.gather", cat="sim", t=t,
                          uploads=len(work)):
                delivered, dropped_mid, w, d = self._gather_round(
                    engine, dst, work
                )
            wire += w
            dropouts += d
            dst_dead = dst in dropped_mid or (
                not nodes[dst].online if arm.requires_dst_online
                else dst not in delivered
            )
            if dst_dead:
                lost += 1
                continue  # facilitator died mid-round; round is void

            uploads = None
            if session is not None:
                uploads = {slot_of[i]: delivered[i] for i in delivered}
                if len(uploads) < session.threshold:
                    lost += 1
                    continue  # below recovery threshold: protocol aborts
                if dropped_mid:
                    # survivors reveal shares of each dropped secret so the
                    # facilitator can reconstruct and cancel its pads
                    with obs.span("secagg.recover", cat="secagg", t=t,
                                  dropped=len(dropped_mid)):
                        recoveries += len(dropped_mid)
                        wire += secagg_recovery_bytes(
                            len(active), len(dropped_mid)
                        )["recovery_bytes"]
                        dropouts += self._gather_shares(
                            engine, dst, delivered)

            topup = None
            if dropped_mid and arm.distributed_noise:
                # every active participant noised its share for a cohort of
                # len(active); the dropped shares never arrived
                with obs.span("noise_topup", cat="dp", t=t,
                              missing=len(dropped_mid)):
                    topup = dp_lib.tree_topup_noise(
                        params, jax.random.fold_in(topup_base, t),
                        clip_norm=cfg.dp.clip_norm,
                        noise_multiplier=cfg.dp.noise_multiplier,
                        missing=len(dropped_mid), n_shares=len(active),
                    )
                obs.counter("noise_topups", 1)
                topups += 1
            dl_contribs = {i: contribs[i] for i in delivered}
            # secure decode (when a session exists) happens inside aggregate
            # via the services object, so this span covers reduce + decode
            with obs.span("aggregate", cat="train", t=t,
                          secure=session is not None):
                outcome = arm.aggregate(
                    params, dl_contribs,
                    _SimServices(session, uploads, topup)
                )
            if not outcome.stepped:
                lost += 1  # e.g. empty Poisson draw across the cohort
                continue
            params = outcome.params
            with obs.span("transport.broadcast", cat="sim", t=t):
                w, d = self._broadcast(
                    engine, dst, model_bytes,
                    [i for i in range(h) if nodes[i].online],
                )
            wire += w
            dropouts += d
            arm.account()
            completed += 1
            obs.counter("rounds_completed", 1)
            obs.ledger_round(arm, round=t, backend=self.backend,
                             cohort=active, delivered=delivered,
                             bytes_up=model_bytes,
                             topup=topup is not None)
            logs.append(RoundLog(t, dst, outcome.loss, arm.epsilon(),
                                 outcome.aggregate_batch))
            if self.on_round is not None:
                self.on_round(t, params)  # checkpoint-handoff seam
            if arm.should_stop():
                break

        return RunReport(
            params=params, logs=logs, epsilon=arm.epsilon(),
            rounds_completed=completed, arm=arm.name, backend=self.backend,
            timing=SimTiming(
                wall_clock=engine.now, bytes_on_wire=wire,
                dropout_events=dropouts, recoveries=recoveries,
                lost_rounds=lost, events=engine.processed,
                noise_topups=topups,
            ),
        )

    def _gather_shares(
        self, engine: EventEngine, dst: int, delivered: Mapping[int, Any]
    ) -> int:
        """Time cost of the Shamir share gather (tiny, latency-bound)."""
        tag = f"shares-{next(_tag_counter)}"
        surv = [i for i in delivered
                if i != dst and self.topo.has_edge(i, dst)]
        for j in surv:
            engine.schedule(
                self.topo.transfer_time(j, dst, _SHARE_BYTES),
                TransferDone(j, dst, _SHARE_BYTES, tag=tag),
            )
        outstanding = len(surv)
        n_drop = 0
        while outstanding:
            ev = self._pop(engine)
            if ev is None:
                break
            if self._apply_availability(ev):
                n_drop += isinstance(ev, NodeDropout)
                continue
            if isinstance(ev, TransferDone) and ev.tag == tag:
                outstanding -= 1
        return n_drop

    # --- per-node arms --------------------------------------------------------

    def _run_nodes(self, arm: NodeArm) -> RunReport:
        cfg, h = arm.cfg, arm.h
        nodes, topo = self.nodes, self.topo
        per_node = [arm.init_node_params(i) for i in range(h)]
        model_bytes = tree_bytes(per_node[0], cfg.bytes_per_param)
        total = arm.steps_total()
        engine = self._engine()
        steps_done = [0] * h
        parked = [False] * h
        retired = [False] * h
        wire = 0.0
        dropouts = exchanges = 0
        last_progress = 0.0

        def unfinished(i: int) -> bool:
            return not retired[i] and steps_done[i] < total

        def start_step(i: int) -> None:
            engine.schedule(
                nodes[i].compute_time(arm.step_cost(i)),
                ComputeDone(i, tag="step"),
            )

        def handler(ev) -> None:
            nonlocal wire, dropouts, exchanges, last_progress
            if isinstance(ev, NodeDropout):
                nodes[ev.node].online = False
                dropouts += 1
                return
            if isinstance(ev, NodeRejoin):
                nodes[ev.node].online = True
                if parked[ev.node] and unfinished(ev.node):
                    parked[ev.node] = False
                    start_step(ev.node)
                return
            if isinstance(ev, ComputeDone) and ev.tag == "step":
                i = ev.node
                if not nodes[i].online:
                    parked[i] = True  # step lost mid-compute; redo on rejoin
                    return
                r = arm.local_step(i, per_node[i], steps_done[i])
                if r is None:
                    retired[i] = True  # e.g. local privacy budget exhausted
                    return
                per_node[i], _loss, _k = r
                steps_done[i] += 1
                last_progress = engine.now
                if arm.wants_exchange(i, steps_done[i]):
                    # skip neighbours currently offline (connection refused);
                    # a neighbour dying mid-transfer is handled at arrival
                    nbrs = [j for j in topo.neighbors(i) if nodes[j].online]
                    j = arm.select_peer(i, nbrs)
                    if j is not None:
                        wire += model_bytes  # outbound leg
                        engine.schedule(
                            topo.transfer_time(i, j, model_bytes),
                            TransferDone(i, j, model_bytes, tag="xchg"),
                        )
                if unfinished(i):
                    start_step(i)  # async: do not wait for the transfer
                return
            if isinstance(ev, TransferDone) and ev.tag == "xchg":
                if nodes[ev.src].online and nodes[ev.dst].online:
                    _average_pair(per_node, ev.src, ev.dst)
                    wire += model_bytes  # return leg only on real exchange
                    exchanges += 1
                    last_progress = engine.now

        for i in range(h):
            if nodes[i].online:
                start_step(i)
            else:
                parked[i] = True
        # run until every node finished/retired and in-flight exchanges land
        while any(unfinished(i) for i in range(h)) or len(engine):
            if not any(unfinished(i) for i in range(h)):
                # only drain transfers that are already in flight
                if engine.pending_kinds() <= {NodeDropout, NodeRejoin}:
                    break  # nothing left that changes the models
            ev = self._pop(engine)
            if ev is None:
                break
            handler(ev)

        params, per_node = arm.consensus(per_node)
        if self.on_round is not None:
            # node arms have no server rounds; publish the consensus model
            # once, stamped with the completed step count
            self.on_round(min(steps_done), params)
        return RunReport(
            params=params, logs=[], epsilon=arm.epsilon(),
            rounds_completed=min(steps_done), arm=arm.name,
            backend=self.backend, per_node_params=per_node,
            timing=SimTiming(
                wall_clock=last_progress, bytes_on_wire=wire,
                dropout_events=dropouts, recoveries=0, lost_rounds=0,
                events=engine.processed,
            ),
        )
