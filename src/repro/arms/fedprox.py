"""FedProx (Li et al., 2020) — FedAvg with a proximal term, as an arm.

Each client takes ``max(2, fl_local_steps)`` local SGD steps on the
regularised objective ``F_i(w) + (mu/2) ||w - w_global||^2``; the proximal
term pulls local iterates back toward the round's global model, which
stabilises FedAvg under the heterogeneous (non-IID) silos the paper's
multi-hospital setting produces.  The server size-weights the resulting
weights exactly like FedAvg.

Registered once (DESIGN.md §5): both backends, the CLI smoke matrix, the
sweep axes in ``repro.scenarios`` and the CI jobs all pick it up from the
registry with no further wiring.
"""

from __future__ import annotations

from typing import Sequence

import jax

from repro.arms.base import (
    ArmConfig,
    Contribution,
    Model,
    Participant,
    poisson_batch,
    sgd_update,
    tree_div,
)
from repro.arms.fl import FLArm
from repro.arms.registry import register


@register("fedprox")
class FedProxArm(FLArm):
    """Proximal-term FedAvg: heterogeneity-robust server-based FL."""

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        # FedProx is only distinct from FedSGD when clients take multiple
        # local steps; always use the weight-averaging (FedAvg) aggregation.
        self.fedavg = True
        self.local_steps = max(2, cfg.fl_local_steps)
        self.mu = cfg.fedprox_mu

    # --- fused hot path (the FLArm cohort program with a proximal term) ---

    def _local_steps(self) -> int:
        return self.local_steps

    def _local_step_grad(self, local, batch, mask, k, global_params):
        g = super()._local_step_grad(local, batch, mask, k, global_params)
        # grad of (mu/2)||w - w_global||^2 at the local iterate
        return jax.tree_util.tree_map(
            lambda gl, wl, wg: gl + self.mu * (wl - wg),
            g, local, global_params,
        )

    def contribution(self, params, i, t, rng, n_shares):
        part = self.participants[i]
        local, consumed = params, 0
        for _ in range(self.local_steps):
            b, m, k = poisson_batch(rng, part, self.rate, self.pad)
            if k == 0:
                continue
            g = tree_div(self._batch_grad(local, b, jax.numpy.asarray(m)),
                         max(k, 1))
            # grad of (mu/2)||w - w_global||^2 at the local iterate
            g = jax.tree_util.tree_map(
                lambda gl, wl, wg: gl + self.mu * (wl - wg), g, local, params
            )
            local = sgd_update(local, g, self.cfg.lr, self.cfg.weight_decay)
            consumed += k
        return Contribution(payload=local, size=consumed)
