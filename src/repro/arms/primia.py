"""PriMIA-style local-DP FL as a registered arm.

Every client runs its own DP-SGD: local Poisson rate ``B_h / |D_h|``, the
FULL noise N(0, (C sigma)^2) added locally (n_shares=1), and a *local*
accountant.  A client stops contributing once another step would overshoot
its own epsilon budget — clients with higher sampling rates (small silos)
drop out first, the forgetting failure mode the paper describes.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np

from repro.arms.base import (
    AggregationServices,
    ArmConfig,
    Contribution,
    Model,
    Participant,
    RoundArm,
    RoundOutcome,
    poisson_batch,
    sgd_update,
    tree_div,
)
from repro.arms import fused
from repro.arms.registry import register
from repro.core import dp as dp_lib
from repro.core.accountant import RDPAccountant, steps_for_epsilon

_NOISE_SALT = 31  # legacy key derivation: fold_in(fold_in(key, 31 + t), i)


@register("primia")
class PriMIAArm(RoundArm):
    """Local-DP FL through a star hub, per-client accountants."""

    private = True
    requires_dst_online = True
    empty_break = True            # every budget exhausted -> run over
    topology_kind = "star"
    fused_capable = True

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        per_client_batch = max(1, cfg.batch_size // self.h)
        self.rates = [
            min(1.0, per_client_batch / max(len(p), 1))
            for p in self.participants
        ]
        self.pads = [
            cfg.max_pad_batch or max(8, int(r * len(p) * 4) or 8)
            for r, p in zip(self.rates, self.participants)
        ]
        self.accts = [
            RDPAccountant(sampling_rate=r,
                          noise_multiplier=cfg.dp.noise_multiplier,
                          delta=cfg.dp.delta)
            for r in self.rates
        ]
        if cfg.epsilon_budget is not None:
            # a client only participates while ANOTHER step stays within its
            # local budget (never overshoots)
            self.max_rounds = [
                steps_for_epsilon(r, cfg.dp.noise_multiplier,
                                  cfg.epsilon_budget, cfg.dp.delta,
                                  max_steps=cfg.rounds + 1)
                for r in self.rates
            ]
        else:
            self.max_rounds = [cfg.rounds] * self.h
        self._key = jax.random.key(cfg.seed)
        # Same clipped-grad-sum seam as decaph (DESIGN.md §12); the pad hint
        # only caps the faithful path's microbatch, so keep the configured
        # microbatch size by passing the largest per-client pad.
        clip_fn = self.clipped_grad_sum_fn(
            max(cfg.dp.microbatch_size, *self.pads)
        )
        self._clipped_sum = fused.instrumented_jit(
            lambda p, b, m: clip_fn(p, b, m)
        )

        def cohort_step(params, bx, by, masks, counts, salt_t, idxs):
            """Every client's locally-noised mean gradient + the cohort
            total in one program.  The ragged per-client Poisson draws ride
            the cohort pad (padded to the round max; masks keep the extra
            rows inert), noise keys fold in ``(salt_t, idx)`` exactly like
            the per-participant path, and each client divides by its own
            real-example count."""

            def one(bx_i, by_i, m_i, k_i, idx):
                g_sum, loss = clip_fn(params, {"x": bx_i, "y": by_i}, m_i)
                nkey = jax.random.fold_in(
                    jax.random.fold_in(self._key, salt_t), idx
                )
                # Local DP: the FULL noise per client (n_shares=1).
                g = dp_lib.tree_add_noise(
                    g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                    noise_multiplier=cfg.dp.noise_multiplier, n_shares=1,
                )
                g = jax.tree_util.tree_map(
                    lambda x: x / jax.numpy.maximum(k_i, 1), g
                )
                return g, loss

            stack, losses = jax.vmap(one)(bx, by, masks, counts, idxs)
            return stack, fused.seq_tree_sum(stack, bx.shape[0]), losses

        self._fused_step, self._fused_step_slim = fused.instrumented_jit_pair(
            cohort_step
        )

    def quorum(self) -> tuple[int, int | None]:
        return 1, self.cfg.fl_server

    def participates(self, i: int, t: int) -> bool:
        return self.accts[i].steps < self.max_rounds[i]

    def facilitator(self, t: int, active: Sequence[int]) -> int:
        return self.cfg.fl_server

    def contribution(self, params, i, t, rng, n_shares):
        b, m, k = poisson_batch(
            rng, self.participants[i], self.rates[i], self.pads[i]
        )
        g_sum, loss = self._clipped_sum(params, b, jax.numpy.asarray(m))
        nkey = jax.random.fold_in(
            jax.random.fold_in(self._key, _NOISE_SALT + t), i
        )
        # Local DP: the FULL noise per client (n_shares=1).
        g = dp_lib.tree_add_noise(
            g_sum, nkey, clip_norm=self.cfg.dp.clip_norm,
            noise_multiplier=self.cfg.dp.noise_multiplier, n_shares=1,
        )
        g = tree_div(g, max(k, 1))
        self.accts[i].step()  # privacy is spent at compute time, not arrival
        return Contribution(payload=g, size=k, loss=float(loss))

    def fused_round(self, params, active, t, rng, n_shares, need_payloads,
                    need_reduced=True):
        # per-client rates *and* pads: the stack draws each client with its
        # own (rate, pad) in loop order, then re-pads to the cohort max
        cb = fused.stack_poisson(
            rng, self.participants, active, self.rates, self.pads
        )
        args = (params, cb.x, cb.y, cb.masks, cb.counts,
                np.int32(_NOISE_SALT + t), np.asarray(active, np.int32))
        if need_reduced:
            stack, reduced, losses = self._fused_step(*args)
        else:
            (stack, losses), reduced = self._fused_step_slim(*args), None
        for i in active:
            self.accts[i].step()  # spent at compute time, like the loop path
        contribs = fused.build_contributions(
            active, stack, losses, cb.sizes, need_payloads
        )
        return contribs, reduced

    def aggregate(
        self,
        params,
        contributions: Mapping[int, Contribution],
        services: AggregationServices,
    ) -> RoundOutcome:
        order = sorted(contributions)
        if not order:
            return RoundOutcome(params, stepped=False)
        total = services.sum_payloads(
            {i: contributions[i].payload for i in order}
        )
        grad = tree_div(total, len(order))
        params = sgd_update(params, grad, self.cfg.lr, self.cfg.weight_decay)
        agg = int(sum(contributions[i].size for i in order))
        return RoundOutcome(params, stepped=True, aggregate_batch=agg)

    def epsilon(self) -> float:
        return max(a.epsilon() for a in self.accts)
