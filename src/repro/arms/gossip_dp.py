"""DP gossip (ROADMAP item): D-PSGD with local clip+noise per node.

Each node runs its own DP-SGD step (Poisson sampling, per-example clipping,
FULL local noise — local DP like PriMIA) between pairwise averagings, with a
per-node RDP accountant; a node retires once another step would overshoot
its epsilon budget.  This lets the utility-privacy trade-off of decentralised
averaging be compared against DeCaPH's distributed-noise design on either
backend — the whole arm is this file, both backends come for free.
"""

from __future__ import annotations

from typing import Sequence

import jax

from repro.arms.base import (
    ArmConfig, Model, NodeArm, Participant, poisson_batch, sgd_update,
    tree_div,
)
from repro.arms.gossip import GossipArm
from repro.arms.registry import register
from repro.core import dp as dp_lib
from repro.core.accountant import RDPAccountant, steps_for_epsilon

_NOISE_SALT = 53  # key derivation: fold_in(fold_in(key, 53 + step), i)


@register("gossip-dp")
class GossipDPArm(GossipArm):
    """Gossip averaging with per-node local-DP updates and accountants."""

    private = True

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        per_node_batch = max(1, cfg.batch_size // self.h)
        self.rates = [
            min(1.0, per_node_batch / max(len(p), 1))
            for p in self.participants
        ]
        self.pads = [
            cfg.max_pad_batch or max(8, int(r * len(p) * 4) or 8)
            for r, p in zip(self.rates, self.participants)
        ]
        self.accts = [
            RDPAccountant(sampling_rate=r,
                          noise_multiplier=cfg.dp.noise_multiplier,
                          delta=cfg.dp.delta)
            for r in self.rates
        ]
        steps = self.steps_total()
        if cfg.epsilon_budget is not None:  # never overshoot the local budget
            self.max_steps = [
                steps_for_epsilon(r, cfg.dp.noise_multiplier,
                                  cfg.epsilon_budget, cfg.dp.delta,
                                  max_steps=steps + 1)
                for r in self.rates
            ]
        else:
            self.max_steps = [steps] * self.h
        self._clipped_sum = jax.jit(
            lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
                model.loss_fn, p, b,
                clip_norm=cfg.dp.clip_norm,
                microbatch_size=cfg.dp.microbatch_size,
                mask=m,
            )
        )

    def step_cost(self, i: int) -> int:
        return max(1, int(round(self.rates[i] * len(self.participants[i]))))

    def local_step(self, i, params_i, s):
        if self.accts[i].steps >= self.max_steps[i]:
            return None  # local budget exhausted: node retires from training
        b, m, k = poisson_batch(
            self._rngs[i], self.participants[i], self.rates[i], self.pads[i]
        )
        g_sum, loss = self._clipped_sum(params_i, b, jax.numpy.asarray(m))
        nkey = jax.random.fold_in(
            jax.random.fold_in(self._key, _NOISE_SALT + s), i
        )
        g = dp_lib.tree_add_noise(
            g_sum, nkey, clip_norm=self.cfg.dp.clip_norm,
            noise_multiplier=self.cfg.dp.noise_multiplier, n_shares=1,
        )
        g = tree_div(g, max(k, 1))
        params_i = sgd_update(params_i, g, self.cfg.lr, self.cfg.weight_decay)
        self.accts[i].step()
        return params_i, float(loss), k

    def epsilon(self) -> float:
        return max(a.epsilon() for a in self.accts)
