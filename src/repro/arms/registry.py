"""Arm registry: every federation arm is written once and registered here.

``register`` is used as a class decorator on ``Arm`` subclasses; ``get``
returns the class so callers instantiate it with their (model, participants,
config).  Every registered execution backend (``repro.arms.backends``)
consumes the same registered class — registering an arm is all it takes to
get it on every backend its capabilities allow, the CLI
(``python -m repro.run``), and the CI smoke matrix.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Callable, TypeVar

if TYPE_CHECKING:  # pragma: no cover
    from repro.arms.base import Arm

_REGISTRY: dict[str, type["Arm"]] = {}

A = TypeVar("A", bound="type[Arm]")


def register(name: str) -> Callable[[A], A]:
    """Class decorator: ``@register("decaph")`` above an ``Arm`` subclass."""

    def deco(cls: A) -> A:
        if name in _REGISTRY:
            raise ValueError(
                f"arm {name!r} already registered ({_REGISTRY[name].__qualname__})"
            )
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> type["Arm"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arm {name!r}; registered arms: {', '.join(names())}"
        ) from None


def names() -> tuple[str, ...]:
    """Registered arm names, sorted for stable CLI/CI enumeration."""
    return tuple(sorted(_REGISTRY))
