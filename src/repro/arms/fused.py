"""The fused cohort round-step: one jit dispatch per round (DESIGN.md §7).

The per-participant round loop costs H separate jit dispatches plus H host
syncs (``float(loss)`` inside each ``contribution()``) per round.  The fused
hot path stacks the cohort's padded Poisson batches on a leading participant
axis and vmaps the arm's per-silo numerics across it inside ONE jit'd
program — noise keys are pure ``fold_in`` functions of ``(round, index)``,
so batching them changes nothing about what each participant draws.  Metrics
come back as one stacked array: a single host sync per round.

Contract (enforced by ``tests/test_fused.py``):

  * an arm's ``fused_round`` must consume the backend's host rng in exactly
    the order the ``contribution()`` loop would (round, ascending
    participant index), so the two paths see the same Poisson draws;
  * the fused payloads must match the per-participant loop's payloads up to
    vmap-vs-loop float association (ulp-level; the loop path is *not*
    bit-identical to the fused path, which is why the legacy seed-for-seed
    shims in ``repro.core.federation`` pin ``fused_rounds=False``);
  * both backends run the *same* fused program, so cross-backend
    equivalence stays bit-exact with fusion enabled by default.

The in-jit cohort reduction (``seq_tree_sum`` / ``seq_weighted_sum``)
accumulates in ascending-slot order — the same order as the eager
``tree_sum`` over per-participant slices — so an idealized backend that
consumes the fused total and a sim backend that sums delivered slices
agree bit-for-bit.

Every jit entry point on the round hot path is created through
``instrumented_jit`` so ``benchmarks/hotpath.py`` can count program
launches: the fused path dispatches O(1) programs per round, the legacy
loop O(H).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import numpy as np

import repro.obs as obs
from repro.arms.base import Contribution, Participant, poisson_batch

# -- jit dispatch accounting -------------------------------------------------
# Hoisted to ``repro.instrument`` so the serving tier (DESIGN.md §9) shares
# the same counter without importing the arms package; re-exported here for
# every arm module, benchmark and test that grew up on ``fused.X``.
from repro.instrument import (  # noqa: F401
    active_executor,
    execution_context,
    instrumented_jit,
    instrumented_jit_pair,
    jit_dispatches,
    reset_jit_dispatches,
)

PyTree = Any


# -- host-side cohort stacking ----------------------------------------------


@dataclasses.dataclass
class CohortBatch:
    """The active cohort's Poisson draws, stacked to one static shape.

    ``x``/``y`` have leading axis ``n_active`` (plus a steps axis when
    ``steps`` was requested); ``masks`` flags the real examples inside each
    pad; ``counts`` is the per-draw real-example count (int32, same leading
    axes); ``sizes`` is the per-participant total — host ints, known before
    the dispatch, which is what lets aggregate-batch math stay off-device.
    """

    x: np.ndarray
    y: np.ndarray
    masks: np.ndarray
    counts: np.ndarray
    sizes: list[int]


def _repad(arr: np.ndarray, pad_to: int) -> np.ndarray:
    if arr.shape[0] == pad_to:
        return arr
    out = np.zeros((pad_to,) + arr.shape[1:], arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def stack_poisson(
    rng: np.random.Generator,
    participants: Sequence[Participant],
    active: Sequence[int],
    rate: float | Sequence[float],
    pad: int | Sequence[int],
    steps: int | None = None,
) -> CohortBatch:
    """Stack each active participant's Poisson draw(s) to one static shape.

    Consumes ``rng`` in exactly the order the per-participant loop would:
    ascending participant index, and (when ``steps`` is given) each
    participant's local steps drawn consecutively.  If any single draw
    outgrew the configured pad (``poisson_batch`` grows rather than
    truncates), the whole cohort is re-padded to the round's max — masks
    keep the extra rows inert.

    ``rate``/``pad`` may be sequences indexed by *absolute* participant
    index (ragged local-DP arms like primia: every client has its own
    sampling rate and pad); each draw then uses its own rate/pad exactly
    like the per-participant loop, and the stack re-pads every row to the
    cohort max.  Extra zero rows contribute exactly nothing to masked
    sums, so padding never changes any number.

    Under an active mesh execution context the cohort pad is rounded up to
    the mesh's data-axis size (again mask-inert) and the stacked batch
    arrays are marked for sharding along the example axis.
    """
    t0 = obs.now()  # host-RNG phase: the one per-round host-side cost
    rate_of = (rate.__getitem__ if not isinstance(rate, (int, float))
               else lambda i: rate)
    pad_of = (pad.__getitem__ if not isinstance(pad, int)
              else lambda i: pad)
    executor = active_executor()
    k_steps = 1 if steps is None else steps
    draws: list[list[tuple[dict, np.ndarray, int]]] = []
    pad_to = max(pad_of(i) for i in active)
    for i in active:
        row = []
        for _ in range(k_steps):
            b, m, k = poisson_batch(rng, participants[i], rate_of(i),
                                    pad_of(i))
            pad_to = max(pad_to, len(m))
            row.append((b, m, k))
        draws.append(row)
    if executor is not None:
        pad_to = executor.round_pad(pad_to)

    def gather(fn):
        return np.stack([
            np.stack([fn(d) for d in row]) for row in draws
        ])

    x = gather(lambda d: _repad(d[0]["x"], pad_to))
    y = gather(lambda d: _repad(d[0]["y"], pad_to))
    masks = gather(lambda d: _repad(d[1], pad_to))
    counts = np.asarray(
        [[d[2] for d in row] for row in draws], np.int32
    )
    sizes = [int(c) for c in counts.sum(axis=1)]
    if steps is None:  # collapse the singleton steps axis
        x, y, masks, counts = x[:, 0], y[:, 0], masks[:, 0], counts[:, 0]
    if executor is not None:
        example_axis = 1 if steps is None else 2
        for arr in (x, y, masks):
            executor.mark(arr, axis=example_axis)
    obs.complete("host_rng.stack_poisson", t0, cat="rng",
                 cohort=len(active), pad=pad_to)
    return CohortBatch(x=x, y=y, masks=masks, counts=counts, sizes=sizes)


# -- in-jit cohort reductions ------------------------------------------------


def seq_tree_sum(stack: PyTree, n: int) -> PyTree:
    """Sum over the leading axis in ascending-slot order (NOT a reduce —
    association must match the eager ``tree_sum`` over slices bit-for-bit)."""
    total = jax.tree_util.tree_map(lambda x: x[0], stack)
    for s in range(1, n):
        total = jax.tree_util.tree_map(
            lambda a, x, s=s: a + x[s], total, stack
        )
    return total


def seq_weighted_sum(stack: PyTree, weights, n: int) -> PyTree:
    """``sum_s w[s] * stack[s]`` in ascending-slot order (same association
    as the eager size-weighted FedAvg average)."""
    total = jax.tree_util.tree_map(lambda x: weights[0] * x[0], stack)
    for s in range(1, n):
        total = jax.tree_util.tree_map(
            lambda a, x, s=s: a + weights[s] * x[s], total, stack
        )
    return total


# -- fused output -> per-participant contributions --------------------------


def build_contributions(
    active: Sequence[int],
    payload_stack: PyTree,
    losses,
    sizes: Sequence[int],
    need_payloads: bool,
) -> dict[int, Contribution]:
    """One host sync for the whole cohort's metrics (and, when the backend
    needs per-participant payloads — SecAgg uploads or sim transport — one
    transfer for the whole payload stack; the slices are numpy views).

    With ``need_payloads=False`` the payloads stay on device inside the
    fused reduced sum and the per-participant ``payload`` is ``None`` — the
    idealized backend serves the aggregate from the reduced tree instead.
    """
    loss_vals = None
    if need_payloads:
        if losses is not None:
            payload_stack, loss_vals = jax.device_get((payload_stack, losses))
        else:
            payload_stack = jax.device_get(payload_stack)
        slices = [
            jax.tree_util.tree_map(lambda a, s=s: a[s], payload_stack)
            for s in range(len(active))
        ]
    else:
        if losses is not None:
            loss_vals = np.asarray(losses)
        slices = [None] * len(active)
    return {
        i: Contribution(
            payload=slices[s],
            size=int(sizes[s]),
            loss=None if loss_vals is None else float(loss_vals[s]),
        )
        for s, i in enumerate(active)
    }
