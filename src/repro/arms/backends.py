"""Backend registry + capability-negotiated Runner protocol (DESIGN.md §8).

Arms became registry-discovered citizens in PR 2; this module does the same
for the *other* side of the contract.  A backend is one module that defines a
class satisfying the ``Runner`` protocol, declares what it can do in a
``BackendInfo`` capability record, and registers itself::

    @register_backend(BackendInfo(name="ideal", ...))
    class LocalRunner:
        @classmethod
        def from_setup(cls, setup: RunSetup) -> "LocalRunner": ...
        def run(self, arm: Arm) -> RunReport: ...

Everything that used to hardcode the ``{"ideal", "sim"}`` pair — the
``repro.run`` CLI, ``ScenarioSpec`` validation, ``SweepGrid`` backend axes,
the CI smoke matrix, the cross-backend equivalence tests — enumerates
``backend_registry()`` instead, so adding a backend is one module, exactly
like adding an arm.

Capability negotiation replaces the old implicit assumptions: a spec (or a
direct ``repro.arms.run`` call) requesting an arm/backend pair the
capabilities rule out fails loudly at validation time with the rule that
rejected it, instead of silently ignoring a knob or crashing mid-run.
``bit_exact_group`` drives the cross-backend equivalence tests: backends in
the same group must produce bit-identical trajectories under ideal
conditions; across groups the tests fall back to a documented tolerance.

This module is stdlib-only at import time (``ScenarioSpec`` validation calls
into it): backend *implementations* live in jax-heavy modules listed in
``_BACKEND_MODULES`` and are imported lazily on first registry access —
the same deferred-import exception ``grid._registered_arms`` already makes
for the arm registry.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import TYPE_CHECKING, Any, Callable, Protocol, Sequence, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover
    from repro.arms.base import Arm, ArmConfig
    from repro.arms.results import RunReport

# The default execution substrate everywhere a caller does not choose one.
DEFAULT_BACKEND = "ideal"

# Importing one of these modules registers its backend(s) — one module per
# backend, exactly like arm modules under ``repro.arms``.
_BACKEND_MODULES = (
    "repro.arms.runners",       # ideal + sim
    "repro.launch.federated",   # shard (SPMD mesh execution)
    "repro.population.backend",  # population (trace-then-solve cross-device)
)


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """What one execution backend can (and cannot) do.

    Attributes:
      name: registry key (``spec.backend`` / ``--backend`` value).
      supports_fused: executes arms' cohort-batched ``fused_round`` programs.
      supports_secagg: runs the SecAgg wire protocol (masked ciphertext
        uploads).  Backends that keep payloads on device (the SPMD fast
        path) refuse secure uploads at validation time instead of silently
        shipping plaintext.
      supports_sim_time: consumes node traces, topologies and link churn —
        i.e. produces a ``SimTiming`` systems story.  Specs that pin traces
        are rejected on backends that would silently ignore them.
      fused_only: refuses arms without a fused hot path (and refuses
        ``fused_rounds=False`` configs): the backend has no per-participant
        loop to fall back to.
      supports_subsampling: honours ``participation_rate`` (Poisson cohort
        subsampling, q < 1).  Backends without it run every hospital every
        round, so a q < 1 config would make the arm's accountant claim an
        amplified ε the execution never delivered — validation refuses the
        pair instead.
      bit_exact_group: backends sharing a non-empty group value promise
        bit-identical training trajectories for the same (arm, config)
        under ideal conditions; equivalence tests pair backends by group.
        Backends in different groups agree only to a documented tolerance
        (partitioned reductions re-associate float math).
      device_requirements: human-readable device needs ("" = none); the
        machine check lives in the backend's optional ``available()``.
    """

    name: str
    supports_fused: bool = True
    supports_secagg: bool = True
    supports_sim_time: bool = False
    fused_only: bool = False
    supports_subsampling: bool = False
    bit_exact_group: str = ""
    device_requirements: str = ""
    description: str = ""


@dataclasses.dataclass
class RunSetup:
    """Backend-agnostic execution context handed to ``Runner.from_setup``.

    Every field is optional; each backend consumes what it understands and
    rejects what it requires but did not get (loudly, at construction).
    """

    nodes: Sequence[Any] | None = None  # HospitalNode list (sim-time backends)
    topo: Any | None = None             # Topology override
    mesh: Any | None = None             # jax Mesh override (SPMD backends)
    # Round-end observer: called as ``on_round(t, params)`` after every
    # COMPLETED round (post-aggregate, post-accounting) on every backend.
    # This is the checkpoint-handoff seam (DESIGN.md §9): wiring a
    # ``serve.handoff.CheckpointPublisher.publish`` here feeds a live
    # serving tier from any arm on any backend.
    on_round: Callable[[int, Any], None] | None = None


@runtime_checkable
class Runner(Protocol):
    """The backend contract: construct from a ``RunSetup``, execute any arm.

    ``info`` is attached by ``register_backend``; ``run`` returns the unified
    ``RunReport``.  An optional classmethod ``available() -> str | None``
    reports why the backend cannot run in this process (e.g. too few XLA
    devices) — ``None`` means ready.
    """

    info: BackendInfo

    @classmethod
    def from_setup(cls, setup: RunSetup) -> "Runner": ...  # pragma: no cover

    def run(self, arm: "Arm") -> "RunReport": ...  # pragma: no cover


_REGISTRY: dict[str, type] = {}


def register_backend(info: BackendInfo) -> Callable[[type], type]:
    """Class decorator: ``@register_backend(BackendInfo(name="shard", ...))``."""

    def deco(cls: type) -> type:
        if info.name in _REGISTRY:
            raise ValueError(
                f"backend {info.name!r} already registered "
                f"({_REGISTRY[info.name].__qualname__})"
            )
        cls.info = info
        cls.backend = info.name  # the RunReport.backend label
        _REGISTRY[info.name] = cls
        return cls

    return deco


def _ensure_loaded() -> None:
    for mod in _BACKEND_MODULES:
        importlib.import_module(mod)


def backend_names() -> tuple[str, ...]:
    """Registered backend names, sorted for stable CLI/CI enumeration."""
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def backend_registry() -> dict[str, BackendInfo]:
    """name -> capability record, for every registered backend."""
    _ensure_loaded()
    return {name: _REGISTRY[name].info for name in sorted(_REGISTRY)}


def get_backend(name: str) -> type:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(backend_names())}"
        ) from None


def availability(name: str) -> str | None:
    """Why backend ``name`` cannot run in this process (None = it can)."""
    cls = get_backend(name)
    check = getattr(cls, "available", None)
    return check() if check is not None else None


def bit_exact_groups() -> dict[str, tuple[str, ...]]:
    """Equivalence classes of backends that promise bit-identical runs."""
    groups: dict[str, list[str]] = {}
    for name, info in backend_registry().items():
        if info.bit_exact_group:
            groups.setdefault(info.bit_exact_group, []).append(name)
    return {g: tuple(sorted(ns)) for g, ns in sorted(groups.items())}


# -- capability negotiation ---------------------------------------------------


def compatibility_error(
    arm_cls: type,
    info: BackendInfo,
    *,
    use_secagg: bool,
    fused_rounds: bool = True,
    participation_rate: float = 1.0,
) -> str | None:
    """The rule that rejects this (arm, backend, config) — or None if OK."""
    arm_name = getattr(arm_cls, "name", arm_cls.__name__)
    if participation_rate < 1.0 and not info.supports_subsampling:
        # Running everyone while the accountant composes at the subsampled
        # rate would understate ε — a silent privacy violation, not a knob.
        return (
            f"participation_rate={participation_rate} requires Poisson "
            f"cohort subsampling but backend {info.name!r} runs every "
            f"hospital every round; its ε accounting would be wrong "
            f"(use a backend with supports_subsampling)"
        )
    if fused_rounds and not info.supports_fused:
        return (
            f"backend {info.name!r} cannot execute fused cohort programs; "
            f"set fused_rounds=False to run it per-participant"
        )
    secure = bool(getattr(arm_cls, "secure_uploads", False)) and use_secagg
    if secure and not info.supports_secagg:
        return (
            f"arm {arm_name!r} uploads SecAgg ciphertexts but backend "
            f"{info.name!r} does not run the SecAgg wire protocol "
            f"(set use_secagg=False to run it there)"
        )
    if info.fused_only:
        if getattr(arm_cls, "mode", "") != "round" or not getattr(
            arm_cls, "fused_capable", False
        ):
            return (
                f"backend {info.name!r} only executes fused-capable round "
                f"arms; arm {arm_name!r} has no fused cohort round-step"
            )
        if not fused_rounds:
            return (
                f"backend {info.name!r} has no per-participant loop to fall "
                f"back to; fused_rounds=False is not executable there"
            )
    return None


def validate_run(arm_cls: type, info: BackendInfo, cfg: "ArmConfig") -> None:
    """Loud pre-flight check used by ``repro.arms.run`` before any compute."""
    err = compatibility_error(
        arm_cls, info, use_secagg=cfg.use_secagg,
        fused_rounds=cfg.fused_rounds,
        participation_rate=getattr(cfg, "participation_rate", 1.0),
    )
    if err is not None:
        raise ValueError(err)


def validate_scenario(
    *,
    arm: str,
    backend: str,
    use_secagg: bool,
    needs_sim_time: bool,
    participation_rate: float = 1.0,
) -> None:
    """Capability-gate a ``ScenarioSpec`` at construction time.

    Unknown backends are always an error (the backend axis *is* the
    registry); an unknown arm is left for the executor to reject so specs
    can be built before optional arm modules load.
    """
    try:
        info = get_backend(backend).info
    except KeyError:
        raise ValueError(
            f"backend {backend!r} not registered; registered backends: "
            f"{', '.join(backend_names())}"
        ) from None
    if needs_sim_time and not info.supports_sim_time:
        raise ValueError(
            f"spec pins node traces / topology / stragglers but backend "
            f"{backend!r} does not execute simulated time (it would "
            f"silently ignore them); use a backend with supports_sim_time"
        )
    import repro.arms as arms_lib  # deferred: the jax-importing path

    try:
        arm_cls = arms_lib.get(arm)
    except KeyError:
        return  # executor fails loudly on unknown arms (with the arm list)
    err = compatibility_error(
        arm_cls, info, use_secagg=use_secagg,
        participation_rate=participation_rate,
    )
    if err is not None:
        raise ValueError(err)
