"""SCAFFOLD (Karimireddy et al., 2020) — control-variate FedAvg, as an arm.

FedAvg drifts under heterogeneous silos: each client's local steps descend
its *local* loss, so the averaged model is pulled toward client optima.
SCAFFOLD corrects every local step with control variates — ``c`` (server)
and ``c_i`` (per client) estimating the global vs local update direction:

    y  <-  y - lr * (g_i(y) - c_i + c)

After K local steps the client uploads the model delta and its control
delta (Option II of the paper):

    dy  = y_K - x
    c_i+ = c_i - c + (x - y_K) / (K * lr)      =>   dc = c_i+ - c_i

and the server applies ``x += mean(dy)``, ``c += (|S|/N) * mean(dc)``.

Registered once (DESIGN.md §5): both backends, the CLI smoke matrix, the
scenario sweep axes and the CI jobs pick it up with zero further wiring —
and it rides the fused cohort round-step (DESIGN.md §7), carrying its
per-client control variates through the one-dispatch program as a stacked
``(H, ...)`` pytree.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.arms.base import (
    AggregationServices,
    ArmConfig,
    Contribution,
    Model,
    Participant,
    RoundArm,
    RoundOutcome,
    default_pad,
    sgd_update,
    tree_div,
)
from repro.arms import fused
from repro.arms.registry import register


@register("scaffold")
class ScaffoldArm(RoundArm):
    """Control-variate FedAvg: heterogeneity-robust server-based FL."""

    requires_dst_online = True    # classic single point of failure
    topology_kind = "star"
    fused_capable = True

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        n_total = sum(len(p) for p in self.participants)
        self.rate = cfg.batch_size / n_total
        self.pad = default_pad(self.rate, self.participants, cfg)
        # SCAFFOLD only differs from FedSGD when clients take several steps
        self.local_steps = max(2, cfg.fl_local_steps)
        template = model.init_fn(jax.random.key(cfg.seed))
        self._c = jax.tree_util.tree_map(jnp.zeros_like, template)
        # per-client variates as one stacked (H, ...) tree: the fused
        # program gathers the active rows, steps them, and scatters the
        # updated rows back — all inside the round's single dispatch
        self._ci = jax.tree_util.tree_map(
            lambda x: jnp.zeros((self.h,) + x.shape, x.dtype), template
        )

        def batch_grad(p, b, m):
            def masked_loss(pp):
                losses = jax.vmap(lambda ex: model.loss_fn(pp, ex))(b)
                return jnp.sum(losses * m)
            return jax.grad(masked_loss)(p)

        def one_client(params, c, ci, bxs, bys, ms, ks):
            """K corrected local steps for one client; empty draws skipped."""

            def step(local, inp):
                bx_i, by_i, m_i, k_i = inp
                g = tree_div(batch_grad(local, {"x": bx_i, "y": by_i}, m_i),
                             jnp.maximum(k_i, 1))
                g = jax.tree_util.tree_map(
                    lambda gl, cs, cl: gl + cs - cl, g, c, ci
                )
                new = sgd_update(local, g, cfg.lr, cfg.weight_decay)
                new = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(k_i > 0, a, b), new, local
                )
                return new, None

            local, _ = jax.lax.scan(step, params, (bxs, bys, ms, ks))
            dy = jax.tree_util.tree_map(jnp.subtract, local, params)
            inv_klr = 1.0 / (self.local_steps * cfg.lr)
            dc = jax.tree_util.tree_map(
                lambda cs, d: -cs - inv_klr * d, c, dy
            )
            return {"dy": dy, "dc": dc}

        self._one_client = fused.instrumented_jit(one_client)

        def cohort_step(params, c, ci_stack, bx, by, masks, counts, idxs):
            ci_rows = jax.tree_util.tree_map(lambda x: x[idxs], ci_stack)
            stack = jax.vmap(
                one_client, in_axes=(None, None, 0, 0, 0, 0, 0)
            )(params, c, ci_rows, bx, by, masks, counts)
            ci_new = jax.tree_util.tree_map(
                lambda st, rows, d: st.at[idxs].set(rows + d),
                ci_stack, ci_rows, stack["dc"],
            )
            return stack, fused.seq_tree_sum(stack, bx.shape[0]), ci_new

        # the per-client variate stack is the one buffer an output can
        # alias: ci_new has ci_stack's exact shape, so donation makes the
        # scatter-update effectively in-place across rounds
        self._fused_step, self._fused_step_slim = fused.instrumented_jit_pair(
            cohort_step, donate_argnums=(2,)
        )

    def quorum(self) -> tuple[int, int | None]:
        return 1, self.cfg.fl_server

    def facilitator(self, t: int, active: Sequence[int]) -> int:
        return self.cfg.fl_server

    # --- numerics ------------------------------------------------------------

    def contribution(self, params, i, t, rng, n_shares):
        cb = fused.stack_poisson(
            rng, self.participants, [i], self.rate, self.pad,
            steps=self.local_steps,
        )
        ci = jax.tree_util.tree_map(lambda x: x[i], self._ci)
        payload = self._one_client(
            params, self._c, ci, cb.x[0], cb.y[0], cb.masks[0], cb.counts[0]
        )
        self._ci = jax.tree_util.tree_map(
            lambda st, cl, d: st.at[i].set(cl + d),
            self._ci, ci, payload["dc"],
        )
        return Contribution(payload=payload, size=cb.sizes[0])

    def fused_round(self, params, active, t, rng, n_shares, need_payloads,
                    need_reduced=True):
        cb = fused.stack_poisson(
            rng, self.participants, active, self.rate, self.pad,
            steps=self.local_steps,
        )
        args = (params, self._c, self._ci, cb.x, cb.y, cb.masks, cb.counts,
                np.asarray(active, np.int32))
        if need_reduced:
            stack, reduced, self._ci = self._fused_step(*args)
        else:
            (stack, self._ci), reduced = self._fused_step_slim(*args), None
        return fused.build_contributions(
            active, stack, None, cb.sizes, need_payloads
        ), reduced

    def aggregate(
        self,
        params,
        contributions: Mapping[int, Contribution],
        services: AggregationServices,
    ) -> RoundOutcome:
        order = sorted(contributions)
        if not order:
            return RoundOutcome(params, stepped=False)
        n = len(order)
        total = services.sum_payloads(
            {i: contributions[i].payload for i in order}
        )
        mean_dy = tree_div(total["dy"], n)
        mean_dc = tree_div(total["dc"], n)
        params = jax.tree_util.tree_map(jnp.add, params, mean_dy)
        self._c = jax.tree_util.tree_map(
            lambda cs, d: cs + (n / self.h) * d, self._c, mean_dc
        )
        agg = int(sum(contributions[i].size for i in order))
        return RoundOutcome(params, stepped=True,
                            aggregate_batch=agg or self.cfg.batch_size)
