"""Asynchronous gossip D-PSGD (Lian et al. 2018 style), non-private.

No global rounds: each node alternates local SGD steps with pairwise model
averaging over its topology neighbours (round-robin).  Under the sim
backend communication overlaps compute — exactly the straggler tolerance
the synchronous arms lack; under the idealized backend the same numerics
run in lockstep (all nodes step, then all exchanges fire in node order,
matching the event order of an ideal uniform trace).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.arms.base import ArmConfig, Model, NodeArm, Participant, sgd_update
from repro.arms.registry import register


@register("gossip")
class GossipArm(NodeArm):
    """Async D-PSGD: local SGD + neighbour averaging, no rounds."""

    topology_kind = "ring"

    def __init__(self, model: Model, participants: Sequence[Participant],
                 cfg: ArmConfig) -> None:
        super().__init__(model, participants, cfg)
        self._key = jax.random.key(cfg.seed)
        # per-node streams (legacy simulate_gossip seeding, kept bit-for-bit)
        self._rngs = [
            np.random.default_rng(cfg.seed * 100_003 + i)
            for i in range(self.h)
        ]
        self._bs = [min(cfg.batch_size, len(p)) for p in self.participants]
        self._cursor = [0] * self.h

        def loss_and_grad(p, b):
            def mean_loss(pp):
                return jnp.mean(jax.vmap(lambda ex: model.loss_fn(pp, ex))(b))
            return jax.value_and_grad(mean_loss)(p)

        self._loss_and_grad = jax.jit(loss_and_grad)

    def init_node_params(self, i: int):
        return self.model.init_fn(jax.random.fold_in(self._key, i))

    def local_step(self, i, params_i, s):
        part, bs = self.participants[i], self._bs[i]
        idx = self._rngs[i].choice(len(part), size=bs, replace=False)
        b = {"x": jnp.asarray(part.x[idx]), "y": jnp.asarray(part.y[idx])}
        loss, g = self._loss_and_grad(params_i, b)
        params_i = sgd_update(params_i, g, self.cfg.lr, self.cfg.weight_decay)
        return params_i, float(loss), bs

    def wants_exchange(self, i: int, steps_done: int) -> bool:
        return steps_done % self.cfg.gossip_every == 0

    def select_peer(self, i: int, neighbors: Sequence[int]) -> int | None:
        if not neighbors:
            return None  # every neighbour offline: connection refused
        j = neighbors[self._cursor[i] % len(neighbors)]
        self._cursor[i] += 1
        return j

    def consensus(self, per_node_params):
        avg = jax.tree_util.tree_map(
            lambda *xs: sum(xs[1:], xs[0]) / self.h, *per_node_params
        )
        return avg, per_node_params
