"""The single result type every arm/backend combination returns.

Pre-refactor the repo had two: ``federation.RunResult`` (idealized runs) and
``sim.protocols.ArmReport`` (simulated-time runs), which forced every consumer
to branch on where a result came from.  ``RunReport`` unifies them: training
outputs (params, logs, epsilon) are always present; the systems story
(wall-clock, bytes-on-wire, dropout bookkeeping) lives in an optional
``SimTiming`` section that only the sim backend fills in.

Both legacy names remain as aliases (``RunResult = ArmReport = RunReport``)
and the legacy attribute spellings (``per_client_params``, ``wall_clock``,
``bytes_on_wire``, ...) are provided as properties so pre-refactor callers and
benchmarks keep working unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

PyTree = Any


@dataclasses.dataclass
class RoundLog:
    """One communication round (or, for node arms, one lockstep of steps)."""

    round: int
    leader: int
    loss: float
    epsilon: float
    aggregate_batch: int


@dataclasses.dataclass
class SimTiming:
    """Systems metrics only the discrete-event backend can produce."""

    wall_clock: float = 0.0       # simulated seconds
    bytes_on_wire: float = 0.0
    dropout_events: int = 0       # NodeDropout events that fired
    recoveries: int = 0           # SecAgg Shamir recoveries performed
    lost_rounds: int = 0          # rounds voided (dead facilitator, empty batch)
    events: int = 0               # engine events processed
    noise_topups: int = 0         # rounds whose DP noise was topped up after
                                  # losing distributed noise shares mid-round


@dataclasses.dataclass
class RunReport:
    """What any (arm, backend) run returns.

    ``timing`` is ``None`` for the idealized backend — everything is free and
    instantaneous there, so systems metrics would be meaningless zeros.
    """

    params: PyTree
    logs: list[RoundLog]
    epsilon: float
    rounds_completed: int
    arm: str = ""
    backend: str = ""
    per_node_params: list[PyTree] | None = None
    timing: SimTiming | None = None

    # -- legacy RunResult spelling -------------------------------------------

    @property
    def per_client_params(self) -> list[PyTree] | None:
        return self.per_node_params

    # -- legacy ArmReport spellings ------------------------------------------

    @property
    def wall_clock(self) -> float:
        return self.timing.wall_clock if self.timing else 0.0

    @property
    def bytes_on_wire(self) -> float:
        return self.timing.bytes_on_wire if self.timing else 0.0

    @property
    def dropout_events(self) -> int:
        return self.timing.dropout_events if self.timing else 0

    @property
    def recoveries(self) -> int:
        return self.timing.recoveries if self.timing else 0

    @property
    def lost_rounds(self) -> int:
        return self.timing.lost_rounds if self.timing else 0

    @property
    def events(self) -> int:
        return self.timing.events if self.timing else 0

    @property
    def noise_topups(self) -> int:
        return self.timing.noise_topups if self.timing else 0

    def mean_loss(self) -> float:
        """Mean of the logged (finite) round losses; NaN when none exist."""
        vals = [l.loss for l in self.logs if math.isfinite(l.loss)]
        return sum(vals) / len(vals) if vals else float("nan")
