"""Discrete-event engine for the multi-hospital simulator.

A priority-queue simulated clock: events are scheduled at absolute simulated
times, popped in time order (FIFO within a timestamp), and dispatched to a
handler.  The engine knows nothing about federated learning — protocols
(``repro.sim.protocols``) schedule the typed events below and advance their
own state in the handlers.  Simulated time is completely decoupled from wall
time, so a 5-hospital day-long training run replays in milliseconds.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Iterator

# -- typed events -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ComputeDone:
    """A node finished local computation (one batch / one local step)."""

    node: int
    tag: str = ""
    payload: Any = None


@dataclasses.dataclass(frozen=True)
class TransferDone:
    """Bytes finished traversing the src -> dst link."""

    src: int
    dst: int
    nbytes: float
    tag: str = ""
    payload: Any = None


@dataclasses.dataclass(frozen=True)
class NodeDropout:
    """A hospital goes offline (crash / network partition / maintenance)."""

    node: int


@dataclasses.dataclass(frozen=True)
class NodeRejoin:
    """A previously-offline hospital comes back."""

    node: int


Event = ComputeDone | TransferDone | NodeDropout | NodeRejoin


# -- engine -----------------------------------------------------------------


class EventEngine:
    """Priority-queue simulated clock with cancellation.

    ``schedule`` returns an opaque handle usable with ``cancel`` (e.g. void a
    node's pending upload when its dropout fires first).  ``now`` only moves
    forward, and only when an event is popped.
    """

    def __init__(self) -> None:
        self.now: float = 0.0
        self.processed: int = 0
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._cancelled: set[int] = set()

    def __len__(self) -> int:
        return len(self._heap) - len(self._cancelled)

    def schedule(self, delay: float, event: Event) -> int:
        """Enqueue ``event`` at ``now + delay``; returns a cancel handle."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        return self.schedule_at(self.now + delay, event)

    def schedule_at(self, time: float, event: Event) -> int:
        if time < self.now:
            raise ValueError(f"cannot schedule in the past ({time} < {self.now})")
        handle = next(self._seq)
        heapq.heappush(self._heap, (time, handle, event))
        return handle

    def cancel(self, handle: int) -> None:
        self._cancelled.add(handle)

    def pop(self) -> Event | None:
        """Next live event in time order; advances ``now``.  None when empty."""
        while self._heap:
            time, handle, event = heapq.heappop(self._heap)
            if handle in self._cancelled:
                self._cancelled.discard(handle)
                continue
            self.now = time
            self.processed += 1
            return event
        return None

    def pending_kinds(self) -> set[type]:
        """Types of events still queued (ignoring cancelled ones)."""
        return {
            type(e) for _, h, e in self._heap if h not in self._cancelled
        }

    def peek_time(self) -> float | None:
        """Timestamp of the next live event without popping it."""
        while self._heap and self._heap[0][1] in self._cancelled:
            _, handle, _ = heapq.heappop(self._heap)
            self._cancelled.discard(handle)
        return self._heap[0][0] if self._heap else None

    def run(
        self,
        handler: Callable[[Event], None],
        *,
        until: float | None = None,
        max_events: int | None = None,
    ) -> int:
        """Dispatch events to ``handler`` until empty / ``until`` / cap."""
        n = 0
        while True:
            if max_events is not None and n >= max_events:
                return n
            t = self.peek_time()
            if t is None or (until is not None and t > until):
                if until is not None and t is not None:
                    self.now = until
                return n
            handler(self.pop())
            n += 1

    def drain(self) -> Iterator[Event]:
        """Iterate remaining events in time order (testing convenience)."""
        while (ev := self.pop()) is not None:
            yield ev
