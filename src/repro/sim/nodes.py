"""Per-hospital compute/availability model for the simulator.

A ``HospitalNode`` is the systems-side twin of a ``federation.Participant``:
where the participant holds the private shard, the node holds the hardware
story — training throughput (examples/second), fixed per-round overhead
(data loading, clipping setup, attestation...), and an availability trace of
``(t_off, t_on)`` windows that the protocol adapters turn into
``NodeDropout`` / ``NodeRejoin`` events.

Traces are plain dicts so scenario files stay JSON-serialisable:

    {"throughput": 250.0, "overhead": 0.05, "dropouts": [[120.0, 300.0]]}
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence


@dataclasses.dataclass
class HospitalNode:
    """Compute/availability model for one hospital."""

    index: int
    throughput: float          # training examples processed per sim-second
    overhead: float = 0.0      # fixed seconds per local round/step
    # (t_off, t_on) windows; t_on = None means the node never comes back
    dropouts: tuple[tuple[float, float | None], ...] = ()
    online: bool = True        # mutable runtime state, driven by the engine

    def __post_init__(self) -> None:
        if self.throughput <= 0:
            raise ValueError(f"node {self.index}: throughput must be > 0")
        if self.overhead < 0:
            raise ValueError(f"node {self.index}: negative overhead")
        for t_off, t_on in self.dropouts:
            if t_on is not None and t_on <= t_off:
                raise ValueError(
                    f"node {self.index}: rejoin {t_on} <= dropout {t_off}"
                )

    def compute_time(self, n_examples: int) -> float:
        """Simulated seconds to process one local batch of ``n_examples``."""
        return self.overhead + n_examples / self.throughput


def node_from_trace(index: int, trace: Mapping) -> HospitalNode:
    dropouts = tuple(
        (float(w[0]), None if w[1] is None else float(w[1]))
        for w in trace.get("dropouts", ())
    )
    return HospitalNode(
        index=index,
        throughput=float(trace["throughput"]),
        overhead=float(trace.get("overhead", 0.0)),
        dropouts=dropouts,
    )


def nodes_from_trace(traces: Sequence[Mapping]) -> list[HospitalNode]:
    """Build the cohort from a list of per-hospital trace dicts."""
    return [node_from_trace(i, t) for i, t in enumerate(traces)]


def heterogeneous_trace(
    n: int = 5,
    *,
    fastest: float = 500.0,
    slowdown: float = 0.55,
    overhead: float = 0.02,
) -> list[dict]:
    """A default heterogeneous cohort: geometric throughput spread.

    Hospital 0 is a research centre with ``fastest`` examples/sec; each
    subsequent hospital is ``slowdown`` times slower (node n-1 is the
    community-hospital straggler).  No dropouts — callers inject those.
    """
    return [
        {"throughput": fastest * slowdown**i, "overhead": overhead}
        for i in range(n)
    ]
