"""Protocol adapters: the paper's arms under simulated time + async gossip.

Each ``simulate_*`` runner drives *real* training numerics (the same DP
mechanics, SecAgg field arithmetic and SGD updates as
``repro.core.federation``) through the discrete-event engine, so the report
carries simulated wall-clock and bytes-on-wire **and** genuine
utility/epsilon — including the effect of injected dropouts on what actually
gets aggregated.

Arms:
  * ``decaph`` — synchronous rounds, rotating leader, dropout-robust SecAgg:
    a hospital dropping mid-round triggers real Shamir mask recovery
    (``repro.core.secagg.DropoutRobustSession``), and the round's aggregate
    equals the plain sum of the survivors' noised gradients.
  * ``fl``     — FedSGD through a star hub (the server-based baseline).
  * ``primia`` — local-DP FL through the star hub; per-client accountants,
    budget-exhausted clients stop computing (distinct from availability
    dropouts).
  * ``local``  — silo-only training; zero bytes on wire; wall-clock is the
    slowest hospital's compute, stretched by its offline windows.
  * ``gossip`` — asynchronous D-PSGD (Lian et al. 2018 style): no global
    rounds; each node alternates local SGD steps with pairwise model
    averaging over its topology neighbours, communication overlapping
    compute.  Non-private (like the ``fl`` arm) — it is the systems
    baseline decentralised ML usually gets compared against.

Known simplifications (recorded in DESIGN.md): the per-round facilitator is
assumed reliable while facilitating (a leader dropping mid-round voids the
round, it is not re-elected mid-round); noise shares are sized for the
round-start active set, so a mid-round dropout leaves the round marginally
under-noised (conservative accounting would scale shares up).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_lib
from repro.core.accountant import RDPAccountant
from repro.core.federation import (
    Model,
    Participant,
    _poisson_batch,
    _sgd_update,
)
from repro.core.leader import leader_schedule
from repro.core.secagg import (
    DropoutRobustSession,
    SecAggConfig,
    secagg_recovery_bytes,
)
from repro.sim.engine import (
    ComputeDone,
    EventEngine,
    NodeDropout,
    NodeRejoin,
    TransferDone,
)
from repro.sim.nodes import HospitalNode, nodes_from_trace
from repro.sim.topology import Topology

PyTree = Any

_SHARE_BYTES = 16.0  # one Shamir share on the wire (index + 61-bit y)


@dataclasses.dataclass
class SimConfig:
    """Training + systems knobs for one simulated run."""

    rounds: int = 20
    batch_size: int = 64
    lr: float = 0.1
    weight_decay: float = 0.0
    dp: dp_lib.DPConfig = dataclasses.field(default_factory=dp_lib.DPConfig)
    use_secagg: bool = True
    secagg_frac_bits: int = 16
    secagg_threshold: int | None = None  # None -> majority of round's cohort
    leader_strategy: str = "uniform"
    seed: int = 0
    bytes_per_param: float = 4.0
    max_pad_batch: int | None = None
    # gossip arm
    gossip_steps: int | None = None  # local steps per node; None -> rounds
    gossip_every: int = 1            # exchange after every k-th local step
    fl_server: int = 0               # star hub for fl/primia
    epsilon_budget: float | None = None


@dataclasses.dataclass
class ArmReport:
    """What ``benchmarks/sim_report.py`` tabulates per arm."""

    arm: str
    wall_clock: float          # simulated seconds
    bytes_on_wire: float
    rounds_completed: int
    epsilon: float
    params: PyTree
    per_node_params: list[PyTree] | None = None
    dropout_events: int = 0    # NodeDropout events that fired
    recoveries: int = 0        # SecAgg Shamir recoveries performed
    lost_rounds: int = 0       # rounds voided (leader dropped / empty batch)
    events: int = 0            # engine events processed


# -- shared machinery -------------------------------------------------------


def _tree_bytes(tree: PyTree, bytes_per_param: float) -> float:
    return bytes_per_param * sum(
        int(np.prod(np.shape(leaf)) or 1)
        for leaf in jax.tree_util.tree_leaves(tree)
    )


def _schedule_availability(engine: EventEngine, nodes: Sequence[HospitalNode]) -> None:
    for node in nodes:
        for t_off, t_on in node.dropouts:
            engine.schedule_at(t_off, NodeDropout(node.index))
            if t_on is not None:
                engine.schedule_at(t_on, NodeRejoin(node.index))


def _apply_availability(nodes: Sequence[HospitalNode], ev) -> bool:
    """Handle dropout/rejoin events; True if ``ev`` was one of them."""
    if isinstance(ev, NodeDropout):
        nodes[ev.node].online = False
        return True
    if isinstance(ev, NodeRejoin):
        nodes[ev.node].online = True
        return True
    return False


# Every gather/broadcast stamps its events with a unique tag.  Events from a
# voided round can outlive the round (a dropped node's in-flight upload); the
# tag match keeps them from being mistaken for the current round's traffic.
_tag_counter = itertools.count()


def _gather_round(
    engine: EventEngine,
    nodes: Sequence[HospitalNode],
    topo: Topology,
    dst: int,
    work: dict[int, tuple[Any, float, float]],
) -> tuple[dict[int, Any], set[int], float, int]:
    """One synchronous gather: every node computes, then uploads to ``dst``.

    ``work[i] = (payload, compute_seconds, nbytes)``.  Returns
    ``(delivered, dropped_mid_round, bytes_on_wire, dropout_events)``.
    A node whose NodeDropout fires before its upload lands is excluded from
    ``delivered`` — exactly the case SecAgg recovery must handle.
    """
    tag = f"sync-{next(_tag_counter)}"
    pending = set(work)
    delivered: dict[int, Any] = {}
    dropped_mid: set[int] = set()
    inflight: dict[int, int] = {}  # node -> cancel handle of its next event
    wire = 0.0
    n_drop_events = 0
    for i, (payload, compute_s, nbytes) in work.items():
        inflight[i] = engine.schedule(
            compute_s, ComputeDone(i, tag=tag, payload=(payload, nbytes))
        )
    while pending:
        ev = engine.pop()
        if ev is None:
            break
        if _apply_availability(nodes, ev):
            if isinstance(ev, NodeDropout):
                n_drop_events += 1
                if ev.node in pending:
                    pending.discard(ev.node)
                    dropped_mid.add(ev.node)
                    # the dropout kills the compute / connection: its upload
                    # must never arrive, so the leader never holds both a
                    # "dropped" ciphertext and its reconstructed pads
                    handle = inflight.pop(ev.node, None)
                    if handle is not None:
                        engine.cancel(handle)
            continue
        if isinstance(ev, ComputeDone) and ev.tag == tag:
            if not nodes[ev.node].online:
                continue  # dropped during compute; already counted
            payload, nbytes = ev.payload
            if ev.node == dst:
                delivered[ev.node] = payload
                pending.discard(ev.node)
                inflight.pop(ev.node, None)
            else:
                wire += nbytes
                inflight[ev.node] = engine.schedule(
                    topo.transfer_time(ev.node, dst, nbytes),
                    TransferDone(ev.node, dst, nbytes, tag=tag, payload=payload),
                )
        elif isinstance(ev, TransferDone) and ev.tag == tag:
            if ev.src in pending:
                delivered[ev.src] = ev.payload
                pending.discard(ev.src)
                inflight.pop(ev.src, None)
    return delivered, dropped_mid, wire, n_drop_events


def _broadcast(
    engine: EventEngine,
    nodes: Sequence[HospitalNode],
    topo: Topology,
    src: int,
    nbytes: float,
    targets: Sequence[int],
) -> tuple[float, int]:
    """Send ``nbytes`` from ``src`` to each online target; barrier on arrival."""
    tag = f"bcast-{next(_tag_counter)}"
    outstanding = 0
    wire = 0.0
    n_drop_events = 0
    for j in targets:
        if j == src or not nodes[j].online:
            continue
        wire += nbytes
        outstanding += 1
        engine.schedule(
            topo.transfer_time(src, j, nbytes),
            TransferDone(src, j, nbytes, tag=tag),
        )
    while outstanding:
        ev = engine.pop()
        if ev is None:
            break
        if _apply_availability(nodes, ev):
            n_drop_events += isinstance(ev, NodeDropout)
            continue
        if isinstance(ev, TransferDone) and ev.tag == tag:
            outstanding -= 1
    return wire, n_drop_events


def _advance_to_quorum(
    engine: EventEngine,
    nodes: Sequence[HospitalNode],
    minimum: int,
    require: int | None = None,
) -> tuple[int, int]:
    """Fast-forward through availability events until >= minimum online
    (and, if given, node ``require`` — e.g. the star hub — is online)."""
    n_drop_events = 0
    while (
        sum(n.online for n in nodes) < minimum
        or (require is not None and not nodes[require].online)
    ):
        ev = engine.pop()
        if ev is None:
            return n_drop_events, 0
        if _apply_availability(nodes, ev):
            n_drop_events += isinstance(ev, NodeDropout)
    return n_drop_events, 1


# -- decaph -----------------------------------------------------------------


def simulate_decaph(
    model: Model,
    participants: Sequence[Participant],
    nodes: Sequence[HospitalNode],
    topo: Topology,
    cfg: SimConfig,
) -> ArmReport:
    """DeCaPH rounds under simulated time with dropout-robust SecAgg."""
    h = len(participants)
    if len(nodes) != h:
        raise ValueError("one HospitalNode per participant required")
    n_total = sum(len(p) for p in participants)
    rate = cfg.batch_size / n_total
    pad = cfg.max_pad_batch or max(
        8, int(rate * max(len(p) for p in participants) * 4)
    )
    leaders = leader_schedule(
        h, cfg.rounds, seed=cfg.seed, strategy=cfg.leader_strategy
    )
    acct = RDPAccountant(
        sampling_rate=rate,
        noise_multiplier=cfg.dp.noise_multiplier,
        delta=cfg.dp.delta,
    )
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)
    model_bytes = _tree_bytes(params, cfg.bytes_per_param)

    clipped_sum = jax.jit(
        lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
            model.loss_fn, p, b,
            clip_norm=cfg.dp.clip_norm,
            microbatch_size=min(cfg.dp.microbatch_size, pad),
            mask=m,
        )
    )

    engine = EventEngine()
    _schedule_availability(engine, nodes)
    wire = 0.0
    dropouts = recoveries = lost = completed = 0

    # a round needs at least the configured reconstruction threshold online;
    # running below it would silently weaken the operator's security choice
    quorum = max(2, cfg.secagg_threshold or 2) if cfg.use_secagg else 2
    for t in range(cfg.rounds):
        d, ok = _advance_to_quorum(engine, nodes, quorum)
        dropouts += d
        if not ok:
            break  # quorum never reachable again
        active = [i for i in range(h) if nodes[i].online]
        leader = int(leaders[t])
        if leader not in active:
            # shared-seed schedule: everyone deterministically skips to the
            # next online hospital
            leader = active[t % len(active)]

        # local compute: Poisson batch, clip, per-participant noise share
        shares: dict[int, PyTree] = {}
        sizes: dict[int, int] = {}
        for i in active:
            b, m, k = _poisson_batch(rng, participants[i], rate, pad)
            g_sum, _ = clipped_sum(params, b, jnp.asarray(m))
            nkey = jax.random.fold_in(jax.random.fold_in(key, 17 + t), i)
            shares[i] = dp_lib.tree_add_noise(
                g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier,
                n_shares=len(active),
            )
            sizes[i] = k

        session = None
        if cfg.use_secagg:
            n_active = len(active)
            # quorum above guarantees n_active >= any configured threshold
            threshold = cfg.secagg_threshold or (n_active // 2 + 1)
            session = DropoutRobustSession(
                SecAggConfig(n_active, cfg.secagg_frac_bits,
                             seed=cfg.seed * 6007 + t),
                params, threshold=threshold,
            )
            wire += secagg_recovery_bytes(n_active)["setup_bytes"]

        work = {}
        for slot, i in enumerate(active):
            payload = (
                session.upload(slot, shares[i]) if session else shares[i]
            )
            work[i] = (
                (slot, payload, sizes[i]),
                nodes[i].compute_time(sizes[i]),
                model_bytes,
            )
        delivered, dropped_mid, w, d = _gather_round(
            engine, nodes, topo, leader, work
        )
        wire += w
        dropouts += d
        if leader in dropped_mid or leader not in delivered:
            lost += 1
            continue  # facilitator died mid-round; round is void
        agg_batch = sum(k for (_, _, k) in delivered.values())
        if agg_batch == 0:
            lost += 1  # empty Poisson draw; matches federation (no step)
            continue
        if session is not None:
            uploads = {slot: up for (slot, up, _) in delivered.values()}
            if len(uploads) < session.threshold:
                lost += 1
                continue  # below recovery threshold: protocol aborts round
            if dropped_mid:
                # survivors reveal shares of each dropped secret to the leader
                recoveries += len(dropped_mid)
                share_bytes = (
                    secagg_recovery_bytes(len(active), len(dropped_mid))
                    ["recovery_bytes"]
                )
                wire += share_bytes
                # time cost of the share gather (tiny messages, latency-bound)
                stag = f"shares-{next(_tag_counter)}"
                surv = [i for i in delivered if i != leader]
                for j in surv:
                    engine.schedule(
                        topo.transfer_time(j, leader, _SHARE_BYTES),
                        TransferDone(j, leader, _SHARE_BYTES, tag=stag),
                    )
                outstanding = len(surv)
                while outstanding:
                    ev = engine.pop()
                    if ev is None:
                        break
                    if _apply_availability(nodes, ev):
                        dropouts += isinstance(ev, NodeDropout)
                        continue
                    if isinstance(ev, TransferDone) and ev.tag == stag:
                        outstanding -= 1
            total = session.aggregate(uploads)
        else:
            trees = [v for (_, v, _) in delivered.values()]
            total = jax.tree_util.tree_map(
                lambda *xs: sum(xs[1:], xs[0]), *trees
            )
        grad = jax.tree_util.tree_map(lambda x: x / agg_batch, total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        w, d = _broadcast(
            engine, nodes, topo, leader, model_bytes,
            [i for i in range(h) if nodes[i].online],
        )
        wire += w
        dropouts += d
        acct.step()
        completed += 1
        if cfg.epsilon_budget is not None and acct.exceeds(cfg.epsilon_budget):
            break

    return ArmReport(
        arm="decaph", wall_clock=engine.now, bytes_on_wire=wire,
        rounds_completed=completed, epsilon=acct.epsilon(), params=params,
        dropout_events=dropouts, recoveries=recoveries, lost_rounds=lost,
        events=engine.processed,
    )


# -- fl / primia (star hub) -------------------------------------------------


def simulate_fl(
    model: Model,
    participants: Sequence[Participant],
    nodes: Sequence[HospitalNode],
    topo: Topology,
    cfg: SimConfig,
) -> ArmReport:
    """FedSGD through a star hub under simulated time (non-private)."""
    h = len(participants)
    n_total = sum(len(p) for p in participants)
    rate = cfg.batch_size / n_total
    pad = cfg.max_pad_batch or max(
        8, int(rate * max(len(p) for p in participants) * 4)
    )
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)
    model_bytes = _tree_bytes(params, cfg.bytes_per_param)
    server = cfg.fl_server

    def batch_grad(p, b, m):
        def masked_loss(pp):
            losses = jax.vmap(lambda ex: model.loss_fn(pp, ex))(b)
            return jnp.sum(losses * m)
        return jax.grad(masked_loss)(p)

    batch_grad = jax.jit(batch_grad)

    engine = EventEngine()
    _schedule_availability(engine, nodes)
    wire = 0.0
    dropouts = lost = completed = 0
    for t in range(cfg.rounds):
        # server-based FL stalls whenever the hub is offline
        d, ok = _advance_to_quorum(engine, nodes, 1, require=server)
        dropouts += d
        if not ok:
            break
        active = [i for i in range(h) if nodes[i].online]
        work = {}
        for i in active:
            b, m, k = _poisson_batch(rng, participants[i], rate, pad)
            g = batch_grad(params, b, jnp.asarray(m))
            work[i] = ((g, k), nodes[i].compute_time(k), model_bytes)
        delivered, dropped_mid, w, d = _gather_round(
            engine, nodes, topo, server, work
        )
        wire += w
        dropouts += d
        if server in dropped_mid or not nodes[server].online:
            lost += 1
            continue  # hub died mid-round; no aggregation happened
        agg = sum(k for (_, k) in delivered.values())
        if not delivered or agg == 0:
            lost += 1
            continue
        total = jax.tree_util.tree_map(
            lambda *xs: sum(xs[1:], xs[0]),
            *[g for (g, _) in delivered.values()],
        )
        grad = jax.tree_util.tree_map(lambda x: x / agg, total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        w, d = _broadcast(
            engine, nodes, topo, server, model_bytes,
            [i for i in range(h) if nodes[i].online],
        )
        wire += w
        dropouts += d
        completed += 1
    return ArmReport(
        arm="fl", wall_clock=engine.now, bytes_on_wire=wire,
        rounds_completed=completed, epsilon=0.0, params=params,
        dropout_events=dropouts, lost_rounds=lost, events=engine.processed,
    )


def simulate_primia(
    model: Model,
    participants: Sequence[Participant],
    nodes: Sequence[HospitalNode],
    topo: Topology,
    cfg: SimConfig,
) -> ArmReport:
    """Local-DP FL (PriMIA) through the star hub under simulated time."""
    h = len(participants)
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)
    model_bytes = _tree_bytes(params, cfg.bytes_per_param)
    server = cfg.fl_server

    per_client_batch = max(1, cfg.batch_size // h)
    rates = [min(1.0, per_client_batch / max(len(p), 1)) for p in participants]
    pads = [cfg.max_pad_batch or max(8, int(r * len(p) * 4) or 8)
            for r, p in zip(rates, participants)]
    accts = [
        RDPAccountant(sampling_rate=r, noise_multiplier=cfg.dp.noise_multiplier,
                      delta=cfg.dp.delta)
        for r in rates
    ]
    if cfg.epsilon_budget is not None:
        from repro.core.accountant import steps_for_epsilon

        max_rounds = [
            steps_for_epsilon(r, cfg.dp.noise_multiplier, cfg.epsilon_budget,
                              cfg.dp.delta, max_steps=cfg.rounds + 1)
            for r in rates
        ]
    else:
        max_rounds = [cfg.rounds] * h

    clipped_sum = jax.jit(
        lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
            model.loss_fn, p, b,
            clip_norm=cfg.dp.clip_norm,
            microbatch_size=cfg.dp.microbatch_size,
            mask=m,
        )
    )

    engine = EventEngine()
    _schedule_availability(engine, nodes)
    wire = 0.0
    dropouts = lost = completed = 0
    for t in range(cfg.rounds):
        # server-based FL stalls whenever the hub is offline
        d, ok = _advance_to_quorum(engine, nodes, 1, require=server)
        dropouts += d
        if not ok:
            break
        active = [
            i for i in range(h)
            if nodes[i].online and accts[i].steps < max_rounds[i]
        ]
        if not active:
            break  # every client's local budget exhausted
        work = {}
        for i in active:
            b, m, k = _poisson_batch(rng, participants[i], rates[i], pads[i])
            g_sum, _ = clipped_sum(params, b, jnp.asarray(m))
            nkey = jax.random.fold_in(jax.random.fold_in(key, 31 + t), i)
            g = dp_lib.tree_add_noise(
                g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=1,
            )
            g = jax.tree_util.tree_map(lambda x: x / max(k, 1), g)
            work[i] = (g, nodes[i].compute_time(k), model_bytes)
            accts[i].step()
        delivered, dropped_mid, w, d = _gather_round(
            engine, nodes, topo, server, work
        )
        wire += w
        dropouts += d
        if server in dropped_mid or not nodes[server].online:
            lost += 1
            continue  # hub died mid-round; no aggregation happened
        if not delivered:
            lost += 1
            continue
        total = jax.tree_util.tree_map(
            lambda *xs: sum(xs[1:], xs[0]), *delivered.values()
        )
        grad = jax.tree_util.tree_map(lambda x: x / len(delivered), total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        w, d = _broadcast(
            engine, nodes, topo, server, model_bytes,
            [i for i in range(h) if nodes[i].online],
        )
        wire += w
        dropouts += d
        completed += 1
    eps = max(a.epsilon() for a in accts)
    return ArmReport(
        arm="primia", wall_clock=engine.now, bytes_on_wire=wire,
        rounds_completed=completed, epsilon=eps, params=params,
        dropout_events=dropouts, lost_rounds=lost, events=engine.processed,
    )


# -- local ------------------------------------------------------------------


def simulate_local(
    model: Model,
    participants: Sequence[Participant],
    nodes: Sequence[HospitalNode],
    topo: Topology,
    cfg: SimConfig,
) -> ArmReport:
    """Silo-only training: zero communication; offline windows stall a silo.

    A round interrupted by a dropout is redone after rejoin (the checkpoint
    story is out of scope), so a flaky hospital's wall-clock stretches by
    its offline time plus the wasted partial rounds.
    """
    h = len(participants)
    engine = EventEngine()
    _schedule_availability(engine, nodes)

    rng = np.random.default_rng(cfg.seed)
    per_node_params: list[PyTree] = [
        model.init_fn(jax.random.key(cfg.seed + i)) for i in range(h)
    ]
    batch_sizes: list[int] = [
        min(cfg.batch_size, len(part)) for part in participants
    ]

    @jax.jit
    def batch_grad(p, b):
        def mean_loss(pp):
            return jnp.mean(jax.vmap(lambda ex: model.loss_fn(pp, ex))(b))
        return jax.grad(mean_loss)(p)

    remaining = [cfg.rounds] * h
    parked = [False] * h

    def start_round(i: int) -> None:
        engine.schedule(
            nodes[i].compute_time(batch_sizes[i]), ComputeDone(i, tag="local")
        )

    def handler(ev) -> None:
        if isinstance(ev, NodeDropout):
            nodes[ev.node].online = False
            return
        if isinstance(ev, NodeRejoin):
            nodes[ev.node].online = True
            if parked[ev.node] and remaining[ev.node] > 0:
                parked[ev.node] = False
                start_round(ev.node)
            return
        if isinstance(ev, ComputeDone) and ev.tag == "local":
            i = ev.node
            if not nodes[i].online:
                parked[i] = True  # round lost; redo after rejoin
                return
            part, bs = participants[i], batch_sizes[i]
            idx = rng.choice(len(part), size=bs, replace=False)
            b = {"x": jnp.asarray(part.x[idx]), "y": jnp.asarray(part.y[idx])}
            g = batch_grad(per_node_params[i], b)
            per_node_params[i] = _sgd_update(
                per_node_params[i], g, cfg.lr, cfg.weight_decay
            )
            remaining[i] -= 1
            if remaining[i] > 0:
                start_round(i)

    finish_times = [0.0] * h
    for i in range(h):
        if nodes[i].online:
            start_round(i)
        else:
            parked[i] = True
    while any(r > 0 for r in remaining):
        ev = engine.pop()
        if ev is None:
            break
        handler(ev)
        if isinstance(ev, ComputeDone):
            finish_times[ev.node] = engine.now
    return ArmReport(
        arm="local", wall_clock=max(finish_times) if finish_times else 0.0,
        bytes_on_wire=0.0, rounds_completed=cfg.rounds - max(remaining),
        epsilon=0.0,
        params=per_node_params[0], per_node_params=per_node_params,
        events=engine.processed,
    )


# -- async gossip (D-PSGD) --------------------------------------------------


def simulate_gossip(
    model: Model,
    participants: Sequence[Participant],
    nodes: Sequence[HospitalNode],
    topo: Topology,
    cfg: SimConfig,
) -> ArmReport:
    """Asynchronous gossip D-PSGD: local SGD + pairwise averaging, no rounds.

    Each node loops: one local SGD step on its own shard, then (every
    ``gossip_every`` steps) ships its model to one topology neighbour,
    round-robin.  On arrival, sender and receiver atomically set both their
    models to the average (the AD-PSGD idealisation; we charge the wire for
    both directions).  Communication overlaps compute — the node starts its
    next local step without waiting for the transfer — which is exactly the
    straggler-tolerance the synchronous arms lack.
    """
    h = len(participants)
    key = jax.random.key(cfg.seed)
    per_node_params = [
        model.init_fn(jax.random.fold_in(key, i)) for i in range(h)
    ]
    model_bytes = _tree_bytes(per_node_params[0], cfg.bytes_per_param)
    total_steps = cfg.gossip_steps or cfg.rounds
    rngs = [np.random.default_rng(cfg.seed * 100_003 + i) for i in range(h)]
    batch_sizes = [min(cfg.batch_size, len(p)) for p in participants]

    @jax.jit
    def batch_grad(p, b):
        def mean_loss(pp):
            return jnp.mean(jax.vmap(lambda ex: model.loss_fn(pp, ex))(b))
        return jax.grad(mean_loss)(p)

    engine = EventEngine()
    _schedule_availability(engine, nodes)
    wire = 0.0
    steps_done = [0] * h
    parked = [False] * h
    neighbor_cursor = [0] * h
    dropouts = exchanges = 0

    def start_step(i: int) -> None:
        engine.schedule(
            nodes[i].compute_time(batch_sizes[i]), ComputeDone(i, tag="gossip")
        )

    def average_pair(i: int, j: int) -> None:
        avg = jax.tree_util.tree_map(
            lambda a, b: 0.5 * (a + b), per_node_params[i], per_node_params[j]
        )
        per_node_params[i] = avg
        per_node_params[j] = avg

    def handler(ev) -> None:
        nonlocal wire, dropouts, exchanges
        if isinstance(ev, NodeDropout):
            nodes[ev.node].online = False
            dropouts += 1
            return
        if isinstance(ev, NodeRejoin):
            nodes[ev.node].online = True
            if parked[ev.node] and steps_done[ev.node] < total_steps:
                parked[ev.node] = False
                start_step(ev.node)
            return
        if isinstance(ev, ComputeDone) and ev.tag == "gossip":
            i = ev.node
            if not nodes[i].online:
                parked[i] = True  # step lost mid-compute; resume on rejoin
                return
            part, bs = participants[i], batch_sizes[i]
            idx = rngs[i].choice(len(part), size=bs, replace=False)
            b = {"x": jnp.asarray(part.x[idx]), "y": jnp.asarray(part.y[idx])}
            g = batch_grad(per_node_params[i], b)
            per_node_params[i] = _sgd_update(
                per_node_params[i], g, cfg.lr, cfg.weight_decay
            )
            steps_done[i] += 1
            if steps_done[i] % cfg.gossip_every == 0:
                # skip neighbours currently offline (connection refused);
                # a neighbour dying mid-transfer is handled at arrival
                nbrs = [j for j in topo.neighbors(i) if nodes[j].online]
                if nbrs:
                    j = nbrs[neighbor_cursor[i] % len(nbrs)]
                    neighbor_cursor[i] += 1
                    wire += model_bytes  # outbound leg
                    engine.schedule(
                        topo.transfer_time(i, j, model_bytes),
                        TransferDone(i, j, model_bytes, tag="xchg"),
                    )
            if steps_done[i] < total_steps:
                start_step(i)  # async: do not wait for the transfer
            return
        if isinstance(ev, TransferDone) and ev.tag == "xchg":
            if nodes[ev.src].online and nodes[ev.dst].online:
                average_pair(ev.src, ev.dst)
                wire += model_bytes  # return leg only if the exchange happens
                exchanges += 1

    for i in range(h):
        if nodes[i].online:
            start_step(i)
        else:
            parked[i] = True
    # run until every node finished its steps and in-flight exchanges land
    while any(s < total_steps for s in steps_done) or len(engine):
        if all(s >= total_steps for s in steps_done):
            # only drain transfers/availability that are already in flight
            if engine.pending_kinds() <= {NodeDropout, NodeRejoin}:
                break  # nothing left that changes the models
        ev = engine.pop()
        if ev is None:
            break
        handler(ev)

    consensus = jax.tree_util.tree_map(
        lambda *xs: sum(xs[1:], xs[0]) / h, *per_node_params
    )
    return ArmReport(
        arm="gossip", wall_clock=engine.now, bytes_on_wire=wire,
        rounds_completed=min(steps_done), epsilon=0.0, params=consensus,
        per_node_params=per_node_params, dropout_events=dropouts,
        recoveries=0, lost_rounds=0, events=engine.processed,
    )


SIM_RUNNERS: dict[str, Callable[..., ArmReport]] = {
    "decaph": simulate_decaph,
    "fl": simulate_fl,
    "primia": simulate_primia,
    "local": simulate_local,
    "gossip": simulate_gossip,
}


def scenario_from_trace(
    trace: dict,
) -> tuple[list[HospitalNode], Topology]:
    """Build (nodes, topology) from one JSON-serialisable scenario dict:

    {"nodes": [{"throughput": ..., "overhead": ..., "dropouts": [...]}, ...],
     "topology": {"kind": "full", "default": {...}, ...}}

    ``topology.n`` defaults to ``len(nodes)``.
    """
    nodes = nodes_from_trace(trace["nodes"])
    topo_spec = dict(trace.get("topology") or {"kind": "full"})
    topo_spec.setdefault("n", len(nodes))
    return nodes, Topology.from_trace(topo_spec)
