"""Deprecated per-arm simulator entry points (now thin Arm/Backend shims).

Pre-refactor this module re-implemented every arm's training numerics a
second time for simulated execution (~850 lines).  Since the Arm/Backend
redesign the numerics live once in ``repro.arms`` and the discrete-event
execution lives in ``repro.arms.SimRunner``; each ``simulate_*`` below just
binds a registered arm to that backend.  New code should use::

    import repro.arms as arms
    report = arms.run("decaph", model, silos, cfg, backend="sim",
                      nodes=nodes, topo=topo)

``SimConfig`` is an alias of :class:`repro.arms.ArmConfig` and ``ArmReport``
of :class:`repro.arms.RunReport` (unified result type; the systems metrics
live in its ``timing`` section and remain readable under their historical
names — ``wall_clock``, ``bytes_on_wire``, ``recoveries``, ...).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Sequence

from repro.arms import ArmConfig, RunReport, SimRunner, get
from repro.arms.base import Model, Participant  # noqa: F401  (legacy re-export)
from repro.sim.nodes import HospitalNode, nodes_from_trace
from repro.sim.topology import Topology

__all__ = [
    "ArmReport",
    "SIM_RUNNERS",
    "SimConfig",
    "scenario_from_trace",
    "simulate_decaph",
    "simulate_fl",
    "simulate_gossip",
    "simulate_gossip_dp",
    "simulate_local",
    "simulate_primia",
]

# Legacy aliases — historical names for the unified types.
ArmReport = RunReport


@dataclasses.dataclass
class SimConfig(ArmConfig):
    """Legacy name for :class:`repro.arms.ArmConfig`.

    Only difference: the historical default of 20 rounds (ArmConfig keeps
    FederationConfig's 100), so pre-refactor ``SimConfig()`` callers do not
    silently get a 5x longer simulation.
    """

    rounds: int = 20


def _simulate(arm_name: str):
    def shim(
        model: Model,
        participants: Sequence[Participant],
        nodes: Sequence[HospitalNode],
        topo: Topology,
        cfg: ArmConfig,
    ) -> RunReport:
        warnings.warn(
            f"repro.sim.protocols.simulate_{arm_name.replace('-', '_')} is "
            f"deprecated; use repro.arms.run({arm_name!r}, ..., "
            "backend='sim', nodes=..., topo=...)",
            DeprecationWarning,
            stacklevel=2,
        )
        return SimRunner(nodes, topo).run(get(arm_name)(model, participants, cfg))

    shim.__name__ = f"simulate_{arm_name.replace('-', '_')}"
    shim.__qualname__ = shim.__name__
    return shim


simulate_decaph = _simulate("decaph")
simulate_fl = _simulate("fl")
simulate_primia = _simulate("primia")
simulate_local = _simulate("local")
simulate_gossip = _simulate("gossip")
simulate_gossip_dp = _simulate("gossip-dp")

SIM_RUNNERS: dict[str, Callable[..., RunReport]] = {
    "decaph": simulate_decaph,
    "fl": simulate_fl,
    "primia": simulate_primia,
    "local": simulate_local,
    "gossip": simulate_gossip,
    "gossip-dp": simulate_gossip_dp,
}


def scenario_from_trace(
    trace: dict,
) -> tuple[list[HospitalNode], Topology]:
    """Build (nodes, topology) from one JSON-serialisable scenario dict:

    {"nodes": [{"throughput": ..., "overhead": ..., "dropouts": [...]}, ...],
     "topology": {"kind": "full", "default": {...}, ...}}

    ``topology.n`` defaults to ``len(nodes)``.
    """
    nodes = nodes_from_trace(trace["nodes"])
    topo_spec = dict(trace.get("topology") or {"kind": "full"})
    topo_spec.setdefault("n", len(nodes))
    return nodes, Topology.from_trace(topo_spec)
