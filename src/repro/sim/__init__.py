"""repro.sim — discrete-event multi-hospital simulator.

Answers the systems questions the idealized ``repro.core.federation``
runtimes cannot: simulated wall-clock under heterogeneous compute,
bytes-on-wire per protocol, straggler sensitivity, and dropout recovery —
while running the real training numerics, so utility/epsilon come out of the
same run.  See DESIGN.md ("Discrete-event simulator") for the event model.
"""

from repro.sim.engine import (
    ComputeDone,
    EventEngine,
    NodeDropout,
    NodeRejoin,
    TransferDone,
)
from repro.sim.nodes import (
    HospitalNode,
    heterogeneous_trace,
    node_from_trace,
    nodes_from_trace,
)
from repro.sim.protocols import (
    ArmReport,
    SIM_RUNNERS,
    SimConfig,
    scenario_from_trace,
    simulate_decaph,
    simulate_fl,
    simulate_gossip,
    simulate_local,
    simulate_primia,
)
from repro.sim.topology import Link, Topology

__all__ = [
    "ArmReport",
    "ComputeDone",
    "EventEngine",
    "HospitalNode",
    "Link",
    "NodeDropout",
    "NodeRejoin",
    "SIM_RUNNERS",
    "SimConfig",
    "Topology",
    "TransferDone",
    "heterogeneous_trace",
    "node_from_trace",
    "nodes_from_trace",
    "scenario_from_trace",
    "simulate_decaph",
    "simulate_fl",
    "simulate_gossip",
    "simulate_local",
    "simulate_primia",
]
