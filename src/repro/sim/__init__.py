"""repro.sim — discrete-event multi-hospital simulator.

Answers the systems questions the idealized runtime cannot: simulated
wall-clock under heterogeneous compute, bytes-on-wire per protocol,
straggler sensitivity, and dropout recovery — while running the real
training numerics, so utility/epsilon come out of the same run.  See
DESIGN.md §4 for the event model and §5 for the Arm/Backend contract.

Since the Arm/Backend redesign the per-arm numerics live in ``repro.arms``
and the event-driven execution in ``repro.arms.SimRunner``; this package
keeps the engine (events, clock), the systems models (nodes, topology), and
deprecated ``simulate_*`` shims for pre-refactor callers.

Implementation note: the protocol names are loaded lazily (PEP 562) because
``repro.arms`` — which ``protocols`` imports — itself imports the engine
from this package; eager loading would be a circular import.
"""

from repro.sim.engine import (
    ComputeDone,
    EventEngine,
    NodeDropout,
    NodeRejoin,
    TransferDone,
)
from repro.sim.nodes import (
    HospitalNode,
    heterogeneous_trace,
    node_from_trace,
    nodes_from_trace,
)
from repro.sim.topology import Link, LinkChange, LinkSchedule, Topology

_PROTOCOL_NAMES = (
    "ArmReport",
    "SIM_RUNNERS",
    "SimConfig",
    "scenario_from_trace",
    "simulate_decaph",
    "simulate_fl",
    "simulate_gossip",
    "simulate_gossip_dp",
    "simulate_local",
    "simulate_primia",
)


def __getattr__(name: str):
    if name in _PROTOCOL_NAMES:
        from repro.sim import protocols

        return getattr(protocols, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ComputeDone",
    "EventEngine",
    "HospitalNode",
    "Link",
    "LinkChange",
    "LinkSchedule",
    "NodeDropout",
    "NodeRejoin",
    "Topology",
    "TransferDone",
    "heterogeneous_trace",
    "node_from_trace",
    "nodes_from_trace",
    *_PROTOCOL_NAMES,
]
