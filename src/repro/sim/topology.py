"""Network topology: pairwise link bandwidth/latency between hospitals.

Links are directed internally (stored per ordered pair) but all builders
create symmetric graphs.  ``transfer_time`` is the latency + serialisation
model ``lat + nbytes / bw`` — intentionally simple; contention-free links
match the cross-silo setting (hospitals talk over independent WAN paths,
not a shared fabric).

Builders cover the paper-relevant shapes:

  * ``full``      — every pair connected (DeCaPH's rotating leader can be
                    anyone, so the mesh must be complete);
  * ``star``      — all traffic through a hub (classic server-based FL);
  * ``ring``      — minimal gossip graph;
  * ``k_regular`` — circulant k-regular gossip graph (each node talks to
                    its k nearest ring neighbours), the standard D-PSGD
                    communication graph;
  * ``small_world`` — Watts-Strogatz rewiring of the circulant graph:
                    keeps ~k edges per node but adds long-range shortcuts,
                    so the hop diameter drops from O(n/k) to O(log n) —
                    the realistic sparse overlay for 1000-node federations.

Topologies may carry a ``LinkSchedule`` — timestamped link changes (degrade,
remove, restore) that model WAN churn.  The schedule is applied lazily:
``advance_to(t)`` folds in every change with time <= t, and the sim backend
calls it whenever the simulated clock moves before consulting a link.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class Link:
    bandwidth: float  # bytes per simulated second
    latency: float = 0.0  # seconds

    def __post_init__(self) -> None:
        if self.bandwidth <= 0:
            raise ValueError("bandwidth must be > 0")
        if self.latency < 0:
            raise ValueError("latency must be >= 0")


_DEFAULT_LINK = Link(bandwidth=12.5e6, latency=0.02)  # ~100 Mbit/s WAN


@dataclasses.dataclass(frozen=True)
class LinkChange:
    """One scheduled link event: at ``time``, edge i<->j becomes ``link``
    (both directions), or is removed entirely when ``link`` is None."""

    time: float
    i: int
    j: int
    link: Link | None

    def __post_init__(self) -> None:
        if self.time < 0:
            raise ValueError("change time must be >= 0")
        if self.i == self.j:
            raise ValueError(f"self-edge ({self.i}, {self.j})")


class LinkSchedule:
    """Time-ordered link churn: bandwidth/latency changes and edge removals.

    JSON form (one entry per change; ``down`` removes the edge, an entry
    with a bandwidth re-adds or re-rates it; ``latency`` defaults to 0.0,
    matching the ``links`` override convention of ``Topology.from_trace``)::

        [{"t": 2.0, "link": "0-4", "bandwidth": 1.25e5, "latency": 0.4},
         {"t": 3.5, "link": "0-4", "down": true},
         {"t": 9.0, "link": "0-4", "bandwidth": 1.25e6, "latency": 0.08}]
    """

    def __init__(self, changes: Iterable[LinkChange]):
        self.changes: tuple[LinkChange, ...] = tuple(
            sorted(changes, key=lambda c: c.time)
        )

    def __len__(self) -> int:
        return len(self.changes)

    @classmethod
    def from_trace(cls, entries: Sequence[Mapping]) -> "LinkSchedule":
        changes = []
        for e in entries:
            i, j = (int(x) for x in str(e["link"]).split("-"))
            if e.get("down"):
                link = None
            else:
                link = Link(float(e["bandwidth"]), float(e.get("latency", 0.0)))
            changes.append(LinkChange(float(e["t"]), i, j, link))
        return cls(changes)

    def to_trace(self) -> list[dict]:
        out = []
        for c in self.changes:
            entry: dict = {"t": c.time, "link": f"{c.i}-{c.j}"}
            if c.link is None:
                entry["down"] = True
            else:
                entry["bandwidth"] = c.link.bandwidth
                entry["latency"] = c.link.latency
            out.append(entry)
        return out


def _validate_schedule(schedule: LinkSchedule, n: int) -> None:
    for c in schedule.changes:
        if not (0 <= c.i < n and 0 <= c.j < n):
            raise ValueError(
                f"schedule change on edge ({c.i}, {c.j}) for n={n}"
            )


class Topology:
    """Pairwise links over ``n`` hospitals (optionally time-varying)."""

    def __init__(
        self,
        n: int,
        links: Mapping[tuple[int, int], Link],
        *,
        name: str = "custom",
        schedule: LinkSchedule | None = None,
    ):
        if n < 1:
            raise ValueError("need at least one node")
        self.n = n
        self.name = name
        self._links: dict[tuple[int, int], Link] = {}
        for (i, j), link in links.items():
            if not (0 <= i < n and 0 <= j < n) or i == j:
                raise ValueError(f"bad edge ({i}, {j}) for n={n}")
            self._links[(i, j)] = link
        self.schedule = schedule
        self._applied = 0  # index of the next unapplied schedule change
        if schedule is not None:
            _validate_schedule(schedule, n)

    def advance_to(self, t: float) -> int:
        """Apply every scheduled change with time <= ``t``; returns how many
        fired.  Idempotent and monotonic — the sim clock never rewinds."""
        if self.schedule is None:
            return 0
        fired = 0
        while (
            self._applied < len(self.schedule.changes)
            and self.schedule.changes[self._applied].time <= t
        ):
            c = self.schedule.changes[self._applied]
            if c.link is None:
                self._links.pop((c.i, c.j), None)
                self._links.pop((c.j, c.i), None)
            else:
                self._links[(c.i, c.j)] = c.link
                self._links[(c.j, c.i)] = c.link
            self._applied += 1
            fired += 1
        return fired

    def has_edge(self, i: int, j: int) -> bool:
        return (i, j) in self._links

    def neighbors(self, i: int) -> list[int]:
        return sorted(j for (a, j) in self._links if a == i)

    def link(self, i: int, j: int) -> Link:
        try:
            return self._links[(i, j)]
        except KeyError:
            raise ValueError(
                f"no {self.name} link {i} -> {j}; route through a neighbour"
            ) from None

    def transfer_time(self, i: int, j: int, nbytes: float) -> float:
        """Seconds to move ``nbytes`` over the direct i -> j link."""
        link = self.link(i, j)
        return link.latency + nbytes / link.bandwidth

    def degree(self, i: int) -> int:
        return len(self.neighbors(i))

    # -- builders -----------------------------------------------------------

    @classmethod
    def _symmetric(
        cls, n: int, edges: Iterable[tuple[int, int]], link: Link, name: str
    ) -> "Topology":
        links: dict[tuple[int, int], Link] = {}
        for i, j in edges:
            links[(i, j)] = link
            links[(j, i)] = link
        return cls(n, links, name=name)

    @classmethod
    def full(cls, n: int, link: Link = _DEFAULT_LINK) -> "Topology":
        return cls._symmetric(
            n, ((i, j) for i in range(n) for j in range(i + 1, n)), link,
            "full",
        )

    @classmethod
    def star(cls, n: int, center: int = 0, link: Link = _DEFAULT_LINK) -> "Topology":
        return cls._symmetric(
            n, ((center, j) for j in range(n) if j != center), link, "star"
        )

    @classmethod
    def ring(cls, n: int, link: Link = _DEFAULT_LINK) -> "Topology":
        if n < 3:
            return cls.full(n, link)
        return cls._symmetric(
            n, ((i, (i + 1) % n) for i in range(n)), link, "ring"
        )

    @classmethod
    def k_regular(cls, n: int, k: int, link: Link = _DEFAULT_LINK) -> "Topology":
        """Circulant graph: node i connects to i±1 .. i±(k//2) (mod n);
        odd k on even n adds the antipodal edge i <-> i + n/2."""
        if not 2 <= k < n:
            raise ValueError(f"need 2 <= k < n, got k={k}, n={n}")
        if k % 2 == 1 and n % 2 == 1:
            raise ValueError("odd degree needs an even number of nodes")
        edges = set()
        for i in range(n):
            for step in range(1, k // 2 + 1):
                edges.add(tuple(sorted((i, (i + step) % n))))
            if k % 2 == 1:
                edges.add(tuple(sorted((i, (i + n // 2) % n))))
        return cls._symmetric(n, edges, link, f"{k}-regular")

    @classmethod
    def small_world(cls, n: int, k: int, p: float, seed: int = 0,
                    link: Link = _DEFAULT_LINK) -> "Topology":
        """Watts-Strogatz: start from the circulant k-regular ring lattice,
        rewire each edge's far endpoint with probability ``p`` to a uniform
        non-neighbour.  Deterministic in ``seed`` (stdlib ``random``), so
        ``from_trace`` round-trips byte-identically."""
        if not 2 <= k < n:
            raise ValueError(f"need 2 <= k < n, got k={k}, n={n}")
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"rewire probability must be in [0, 1], got {p}")
        rng = random.Random(f"{seed}:smallworld-rewire")
        edges: set[tuple[int, int]] = set()
        for i in range(n):
            for step in range(1, k // 2 + 1):
                edges.add(tuple(sorted((i, (i + step) % n))))
            if k % 2 == 1 and n % 2 == 0:
                edges.add(tuple(sorted((i, (i + n // 2) % n))))
        # rewire in sorted-edge order: iteration order (hence the rewired
        # graph) is a pure function of (n, k, p, seed)
        for i, j in sorted(edges):
            if rng.random() >= p:
                continue
            adjacent = {a for a, b in edges if b == i} | \
                       {b for a, b in edges if a == i}
            candidates = [v for v in range(n)
                          if v != i and v not in adjacent]
            if not candidates:
                continue
            edges.discard((i, j))
            edges.add(tuple(sorted((i, rng.choice(candidates)))))
        return cls._symmetric(n, edges, link, "small-world")

    @classmethod
    def from_trace(cls, trace: Mapping) -> "Topology":
        """Build from a JSON-serialisable dict.

        {"n": 5, "kind": "full" | "star" | "ring" | "k_regular" | "small_world",
         "k": 2, "center": 0, "p": 0.1, "seed": 0,
         "default": {"bandwidth": 12.5e6, "latency": 0.02},
         "links": {"0-1": {"bandwidth": 1e6, "latency": 0.1}, ...},
         "schedule": [{"t": 2.0, "link": "0-1", "down": true}, ...]}

        ``links`` entries override the builder's default on both directions;
        ``schedule`` entries are ``LinkSchedule`` churn events (optional).
        """
        n = int(trace["n"])
        default = trace.get("default")
        link = (
            Link(float(default["bandwidth"]), float(default.get("latency", 0.0)))
            if default
            else _DEFAULT_LINK
        )
        kind = trace.get("kind", "full")
        if kind == "full":
            topo = cls.full(n, link)
        elif kind == "star":
            topo = cls.star(n, int(trace.get("center", 0)), link)
        elif kind == "ring":
            topo = cls.ring(n, link)
        elif kind == "k_regular":
            topo = cls.k_regular(n, int(trace["k"]), link)
        elif kind == "small_world":
            topo = cls.small_world(
                n, int(trace["k"]), float(trace.get("p", 0.1)),
                int(trace.get("seed", 0)), link,
            )
        else:
            raise ValueError(f"unknown topology kind {kind!r}")
        for key, spec in (trace.get("links") or {}).items():
            i, j = (int(x) for x in key.split("-"))
            override = Link(
                float(spec["bandwidth"]), float(spec.get("latency", 0.0))
            )
            if not topo.has_edge(i, j):
                raise ValueError(f"override for absent edge {key!r}")
            topo._links[(i, j)] = override
            topo._links[(j, i)] = override
        sched = trace.get("schedule")
        if sched:
            schedule = LinkSchedule.from_trace(sched)
            _validate_schedule(schedule, n)
            topo.schedule = schedule
        return topo
