"""Network topology: pairwise link bandwidth/latency between hospitals.

Links are directed internally (stored per ordered pair) but all builders
create symmetric graphs.  ``transfer_time`` is the latency + serialisation
model ``lat + nbytes / bw`` — intentionally simple; contention-free links
match the cross-silo setting (hospitals talk over independent WAN paths,
not a shared fabric).

Builders cover the paper-relevant shapes:

  * ``full``      — every pair connected (DeCaPH's rotating leader can be
                    anyone, so the mesh must be complete);
  * ``star``      — all traffic through a hub (classic server-based FL);
  * ``ring``      — minimal gossip graph;
  * ``k_regular`` — circulant k-regular gossip graph (each node talks to
                    its k nearest ring neighbours), the standard D-PSGD
                    communication graph.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping


@dataclasses.dataclass(frozen=True)
class Link:
    bandwidth: float  # bytes per simulated second
    latency: float = 0.0  # seconds

    def __post_init__(self) -> None:
        if self.bandwidth <= 0:
            raise ValueError("bandwidth must be > 0")
        if self.latency < 0:
            raise ValueError("latency must be >= 0")


_DEFAULT_LINK = Link(bandwidth=12.5e6, latency=0.02)  # ~100 Mbit/s WAN


class Topology:
    """Pairwise links over ``n`` hospitals."""

    def __init__(
        self,
        n: int,
        links: Mapping[tuple[int, int], Link],
        *,
        name: str = "custom",
    ):
        if n < 1:
            raise ValueError("need at least one node")
        self.n = n
        self.name = name
        self._links: dict[tuple[int, int], Link] = {}
        for (i, j), link in links.items():
            if not (0 <= i < n and 0 <= j < n) or i == j:
                raise ValueError(f"bad edge ({i}, {j}) for n={n}")
            self._links[(i, j)] = link

    def has_edge(self, i: int, j: int) -> bool:
        return (i, j) in self._links

    def neighbors(self, i: int) -> list[int]:
        return sorted(j for (a, j) in self._links if a == i)

    def link(self, i: int, j: int) -> Link:
        try:
            return self._links[(i, j)]
        except KeyError:
            raise ValueError(
                f"no {self.name} link {i} -> {j}; route through a neighbour"
            ) from None

    def transfer_time(self, i: int, j: int, nbytes: float) -> float:
        """Seconds to move ``nbytes`` over the direct i -> j link."""
        link = self.link(i, j)
        return link.latency + nbytes / link.bandwidth

    def degree(self, i: int) -> int:
        return len(self.neighbors(i))

    # -- builders -----------------------------------------------------------

    @classmethod
    def _symmetric(
        cls, n: int, edges: Iterable[tuple[int, int]], link: Link, name: str
    ) -> "Topology":
        links: dict[tuple[int, int], Link] = {}
        for i, j in edges:
            links[(i, j)] = link
            links[(j, i)] = link
        return cls(n, links, name=name)

    @classmethod
    def full(cls, n: int, link: Link = _DEFAULT_LINK) -> "Topology":
        return cls._symmetric(
            n, ((i, j) for i in range(n) for j in range(i + 1, n)), link,
            "full",
        )

    @classmethod
    def star(cls, n: int, center: int = 0, link: Link = _DEFAULT_LINK) -> "Topology":
        return cls._symmetric(
            n, ((center, j) for j in range(n) if j != center), link, "star"
        )

    @classmethod
    def ring(cls, n: int, link: Link = _DEFAULT_LINK) -> "Topology":
        if n < 3:
            return cls.full(n, link)
        return cls._symmetric(
            n, ((i, (i + 1) % n) for i in range(n)), link, "ring"
        )

    @classmethod
    def k_regular(cls, n: int, k: int, link: Link = _DEFAULT_LINK) -> "Topology":
        """Circulant graph: node i connects to i±1 .. i±(k//2) (mod n);
        odd k on even n adds the antipodal edge i <-> i + n/2."""
        if not 2 <= k < n:
            raise ValueError(f"need 2 <= k < n, got k={k}, n={n}")
        if k % 2 == 1 and n % 2 == 1:
            raise ValueError("odd degree needs an even number of nodes")
        edges = set()
        for i in range(n):
            for step in range(1, k // 2 + 1):
                edges.add(tuple(sorted((i, (i + step) % n))))
            if k % 2 == 1:
                edges.add(tuple(sorted((i, (i + n // 2) % n))))
        return cls._symmetric(n, edges, link, f"{k}-regular")

    @classmethod
    def from_trace(cls, trace: Mapping) -> "Topology":
        """Build from a JSON-serialisable dict.

        {"n": 5, "kind": "full" | "star" | "ring" | "k_regular",
         "k": 2, "center": 0,
         "default": {"bandwidth": 12.5e6, "latency": 0.02},
         "links": {"0-1": {"bandwidth": 1e6, "latency": 0.1}, ...}}

        ``links`` entries override the builder's default on both directions.
        """
        n = int(trace["n"])
        default = trace.get("default")
        link = (
            Link(float(default["bandwidth"]), float(default.get("latency", 0.0)))
            if default
            else _DEFAULT_LINK
        )
        kind = trace.get("kind", "full")
        if kind == "full":
            topo = cls.full(n, link)
        elif kind == "star":
            topo = cls.star(n, int(trace.get("center", 0)), link)
        elif kind == "ring":
            topo = cls.ring(n, link)
        elif kind == "k_regular":
            topo = cls.k_regular(n, int(trace["k"]), link)
        else:
            raise ValueError(f"unknown topology kind {kind!r}")
        for key, spec in (trace.get("links") or {}).items():
            i, j = (int(x) for x in key.split("-"))
            override = Link(
                float(spec["bandwidth"]), float(spec.get("latency", 0.0))
            )
            if not topo.has_edge(i, j):
                raise ValueError(f"override for absent edge {key!r}")
            topo._links[(i, j)] = override
            topo._links[(j, i)] = override
        return topo
