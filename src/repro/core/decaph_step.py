"""SPMD DeCaPH training step — the pod-scale fast path.

One jit'd program runs the whole DeCaPH round on the production mesh: the
per-example clip happens on each data shard (a data shard == one participant's
slice), the partitioner's reduce-scatter over ``("pod","data")`` *is* the
SecAgg dataflow (masks cancel algebraically; see DESIGN.md §3), and the noise
is one aggregate draw N(0,(C sigma)^2) — identically distributed to the sum of
the paper's per-participant shares.  Equivalence with the host-level
federation runtime is tested in ``tests/test_equivalence.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import dp as dp_lib
from repro.optim import Optimizer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DeCaPHStepConfig:
    dp: dp_lib.DPConfig
    mode: str = "per_example"   # per_example | none (FL arm) | group
    global_batch: int = 256      # ||B^t|| used for the 1/||B^t|| mean
    accum_dtype: Any = jnp.float32


def make_train_step(
    batched_loss_fn: Callable[[PyTree, PyTree], jax.Array],
    per_example_loss_fn: Callable[[PyTree, PyTree], jax.Array],
    optimizer: Optimizer,
    cfg: DeCaPHStepConfig,
):
    """Build ``train_step(params, opt_state, batch, rng) -> (params', opt', metrics)``.

    Args:
      batched_loss_fn: (params, batch) -> scalar mean loss (mode="none"/"group").
      per_example_loss_fn: (params, one-example batch) -> scalar (mode="per_example").
      optimizer: repro.optim Optimizer.
      cfg: step config (clip norm etc. inside cfg.dp).

    The returned function is pure and jit/pjit-able; batch leading axis is the
    (global) example axis — shard it over ("pod","data") and the partitioner
    emits the DeCaPH communication schedule.
    """

    def train_step(params, opt_state, batch, rng):
        if cfg.mode == "per_example":
            g_sum, mean_loss = dp_lib.per_example_clipped_grad_sum(
                per_example_loss_fn, params, batch,
                clip_norm=cfg.dp.clip_norm,
                microbatch_size=cfg.dp.microbatch_size,
                accum_dtype=cfg.accum_dtype,
            )
            # Aggregate noise draw (== sum of H participant shares).
            g_sum = dp_lib.tree_add_noise(
                g_sum, rng, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=1,
            )
            grads = jax.tree_util.tree_map(
                lambda x: x / float(cfg.global_batch), g_sum
            )
        elif cfg.mode == "group":
            # Group-level clipping (beyond-paper cheap mode): clip the shard
            # mean, noise scaled accordingly. Weaker per-record guarantee;
            # documented in EXPERIMENTS.md, not used for paper claims.
            loss, grads = jax.value_and_grad(batched_loss_fn)(params, batch)
            norm = dp_lib.global_l2_norm(grads)
            grads = jax.tree_util.tree_map(
                lambda x: x * dp_lib.clip_factor(norm, cfg.dp.clip_norm), grads
            )
            grads = dp_lib.tree_add_noise(
                grads, rng, clip_norm=cfg.dp.clip_norm / cfg.global_batch,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=1,
            )
            mean_loss = loss
        elif cfg.mode == "none":
            mean_loss, grads = jax.value_and_grad(batched_loss_fn)(params, batch)
        else:
            raise ValueError(f"unknown mode {cfg.mode!r}")

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {
            "loss": mean_loss,
            "grad_norm": dp_lib.global_l2_norm(grads),
        }
        return new_params, new_opt, metrics

    return train_step
