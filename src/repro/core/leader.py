"""Rotating-leader selection (paper Step 1 / Decentralisation section).

The leader only *facilitates* (aggregates + redistributes); under the paper's
honest-but-curious model a shared-seed pseudo-random schedule is sufficient —
every participant derives the same schedule locally, so no coordination
messages are needed beyond the initial seed agreement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def leader_schedule(
    n_participants: int,
    n_rounds: int,
    *,
    seed: int = 0,
    strategy: str = "uniform",
) -> np.ndarray:
    """Leader index per communication round.

    strategies:
      uniform     — paper default: i.i.d. uniform over participants each round.
      round_robin — deterministic rotation (fairest load; beyond-paper option).
      balanced    — random permutations chained (uniform marginals, exact
                    long-run fairness; beyond-paper option).
    """
    if n_participants <= 0 or n_rounds < 0:
        raise ValueError("need n_participants > 0, n_rounds >= 0")
    if strategy == "uniform":
        key = jax.random.key(seed)
        return np.asarray(
            jax.random.randint(key, (n_rounds,), 0, n_participants)
        )
    if strategy == "round_robin":
        return np.arange(n_rounds) % n_participants
    if strategy == "balanced":
        rng = np.random.default_rng(seed)
        out = []
        while len(out) < n_rounds:
            out.extend(rng.permutation(n_participants).tolist())
        return np.asarray(out[:n_rounds])
    raise ValueError(f"unknown strategy {strategy!r}")


def leader_load(schedule: np.ndarray, n_participants: int) -> np.ndarray:
    """Rounds facilitated per participant (fairness diagnostics)."""
    return np.bincount(schedule, minlength=n_participants)
