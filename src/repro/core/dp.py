"""DP gradient mechanics for DeCaPH (paper Algorithm 2 + Step 5 aggregation).

Implements, as pure-JAX composable pieces:

  * per-example gradient computation with L2 clipping (``vmap(grad)`` under a
    ``lax.scan`` over microbatches so memory stays bounded at
    ``microbatch_size x |params|``),
  * ghost clipping for dense stacks (per-example norms without materialising
    per-example weight gradients; the sequence case uses the Pallas
    ``ghost_norm`` kernel),
  * distributed noise shares: every participant adds N(0, (C sigma)^2 / H) so
    the SecAgg **sum** carries the paper's N(0, (C sigma)^2),
  * the full DeCaPH gradient aggregation (clip -> share-noise -> sum -> mean).

All functions are jit/shard_map friendly; nothing allocates per-example copies
of the full parameter pytree beyond one microbatch.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Privacy hyperparameters for one DeCaPH training run.

    Attributes:
      clip_norm: per-example L2 clipping norm C.
      noise_multiplier: sigma; the aggregate noise is N(0, (C sigma)^2).
      sample_rate: Poisson rate p = B / sum_h |D_h| agreed at preparation.
      delta: DP delta (for accounting).
      microbatch_size: examples per vmap'd microbatch in the scan.
      dtype: accumulation dtype for clipped sums and noise.
    """

    clip_norm: float = 1.0
    noise_multiplier: float = 1.0
    sample_rate: float = 0.01
    delta: float = 1e-5
    microbatch_size: int = 16
    dtype: Any = jnp.float32


def global_l2_norm(tree: PyTree) -> jax.Array:
    """L2 norm over every leaf of a pytree (fp32 accumulate)."""
    leaves = jax.tree_util.tree_leaves(tree)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def clip_factor(norm: jax.Array, clip_norm: float) -> jax.Array:
    """min(1, C / norm) — the paper's line 3 scale (Algorithm 1 line 6)."""
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))


def _tree_scale(tree: PyTree, s: jax.Array) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s.astype(x.dtype), tree)


def _tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def _tree_zeros_like(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, dtype), tree)


def per_example_clipped_grad_sum(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    params: PyTree,
    batch: PyTree,
    *,
    clip_norm: float,
    microbatch_size: int = 16,
    mask: jax.Array | None = None,
    accum_dtype=jnp.float32,
    constrain_grads: Callable[[PyTree], PyTree] | None = None,
) -> tuple[PyTree, jax.Array]:
    """Sum of per-example L2-clipped gradients (paper Algorithm 2, lines 1-3).

    Args:
      loss_fn: maps (params, example_batch_of_1) -> scalar loss for ONE example
        (called under vmap; the leading axis of ``batch`` is the example axis).
      params: parameter pytree.
      batch: pytree of arrays with leading example axis of size B_local.
      clip_norm: C.
      microbatch_size: vmap width inside the scan (memory knob).
      mask: optional (B_local,) 0/1 mask for Poisson-sampled batches padded to a
        static shape — masked-out examples contribute nothing.
      accum_dtype: dtype of the clipped-sum accumulator.

    Returns:
      (sum of clipped per-example grads, mean unclipped loss over real examples)
    """
    batch_size = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if mask is None:
        mask = jnp.ones((batch_size,), jnp.float32)
    m = microbatch_size
    if batch_size % m != 0:
        # pad batch and mask to a multiple of the microbatch size
        pad = m - batch_size % m
        batch = jax.tree_util.tree_map(
            lambda x: jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]),
            batch,
        )
        mask = jnp.concatenate([mask, jnp.zeros((pad,), mask.dtype)])
        batch_size += pad
    n_micro = batch_size // m

    grad_fn = jax.grad(loss_fn, argnums=0, has_aux=False)

    def one_example(ex, w):
        g = grad_fn(params, ex)
        norm = global_l2_norm(g)
        scale = clip_factor(norm, clip_norm) * w
        g = _tree_scale(g, scale)
        return g, loss_fn(params, ex) * w

    def micro_step(carry, micro):
        acc, loss_acc = carry
        mb, mw = micro
        g, losses = jax.vmap(one_example)(mb, mw)
        g_sum = jax.tree_util.tree_map(
            lambda x: jnp.sum(x.astype(accum_dtype), axis=0), g
        )
        if constrain_grads is not None:
            # Force the accumulator onto the param sharding (FSDP+TP): the
            # partitioner then reduce-scatters per microbatch — DeCaPH's
            # secure sum — instead of materialising replicated grads.
            g_sum = constrain_grads(g_sum)
        return (_tree_add(acc, g_sum), loss_acc + jnp.sum(losses)), None

    reshaped = jax.tree_util.tree_map(
        lambda x: x.reshape((n_micro, m) + x.shape[1:]), batch
    )
    mask_r = mask.reshape((n_micro, m))
    init = (_tree_zeros_like(params, accum_dtype), jnp.zeros((), accum_dtype))
    (g_sum, loss_sum), _ = jax.lax.scan(micro_step, init, (reshaped, mask_r))
    n_real = jnp.maximum(jnp.sum(mask), 1.0)
    return g_sum, loss_sum / n_real


def noise_share(
    key: jax.Array,
    template: PyTree,
    *,
    clip_norm: float,
    noise_multiplier: float,
    n_shares: int = 1,
    dtype=jnp.float32,
) -> PyTree:
    """One participant's Gaussian noise share (Algorithm 2 line 4).

    Each of ``n_shares`` participants draws N(0, (C sigma)^2 / H); the SecAgg
    sum then carries exactly N(0, (C sigma)^2) — the paper's distributed-DP
    trick. With ``n_shares=1`` this is the full single-draw noise used by the
    SPMD fast path (identically distributed aggregate).
    """
    std = clip_norm * noise_multiplier / jnp.sqrt(float(n_shares))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = jax.random.split(key, len(leaves))
    noised = [
        jax.random.normal(k, x.shape, dtype) * std for k, x in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, noised)


def tree_add_noise(tree: PyTree, key: jax.Array, *, clip_norm: float,
                   noise_multiplier: float, n_shares: int = 1) -> PyTree:
    """tree + one noise share (convenience)."""
    nz = noise_share(
        key, tree, clip_norm=clip_norm, noise_multiplier=noise_multiplier,
        n_shares=n_shares,
    )
    return _tree_add(tree, nz)


# Base-key salt for dropout noise top-ups: a key stream of its own, so a
# top-up draw can never collide with any participant's fold_in-derived
# noise-share keys (arms fold small salts like 17 + t).
TOPUP_SALT = 1_000_003


def tree_topup_noise(
    template: PyTree,
    key: jax.Array,
    *,
    clip_norm: float,
    noise_multiplier: float,
    missing: int,
    n_shares: int,
    dtype=jnp.float32,
) -> PyTree:
    """Conservative noise top-up when ``missing`` of ``n_shares`` noise
    shares were lost mid-round.

    Each participant's share carries N(0, (C sigma)^2 / n); losing
    ``missing`` of them leaves the delivered sum with variance
    (C sigma)^2 * (n - missing) / n — silently *under*-noised relative to
    the calibration the accountant assumed.  Adding an independent
    N(0, (C sigma)^2 * missing / n) draw restores exactly the full-cohort
    variance (Gaussian variances add), so the mechanism's privacy claim
    survives dropouts at the cost of slightly more noise than a
    re-calibrated fresh round would need — the conservative direction.
    """
    if not 0 < missing <= n_shares:
        raise ValueError(
            f"need 0 < missing <= n_shares (got {missing}/{n_shares})"
        )
    std = clip_norm * noise_multiplier * jnp.sqrt(missing / float(n_shares))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = jax.random.split(key, len(leaves))
    noise = [
        jax.random.normal(k, x.shape, dtype) * std
        for k, x in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, noise)


def dp_aggregate_gradients(
    clipped_sums: list[PyTree],
    noise_keys: list[jax.Array],
    total_batch: jax.Array,
    *,
    cfg: DPConfig,
) -> PyTree:
    """Paper Step 5: SecAgg-sum of participants' noised clipped sums, / ||B^t||.

    Host-level reference path (the federation runtime); each participant's
    share is noised independently so the sum carries N(0, (C sigma)^2).
    """
    n = len(clipped_sums)
    total = None
    for share, key in zip(clipped_sums, noise_keys):
        noised = tree_add_noise(
            share, key, clip_norm=cfg.clip_norm,
            noise_multiplier=cfg.noise_multiplier, n_shares=n,
        )
        total = noised if total is None else _tree_add(total, noised)
    inv = 1.0 / jnp.maximum(total_batch.astype(jnp.float32), 1.0)
    return _tree_scale(total, inv)


# ---------------------------------------------------------------------------
# Ghost clipping: per-example grad norms without per-example grads.
# ---------------------------------------------------------------------------

def ghost_norms_2d(a: jax.Array, g: jax.Array) -> jax.Array:
    """Per-example sq-norm of the weight grad of a dense layer, 2D inputs.

    For y = a @ W (a: [B, d_in], cotangent g: [B, d_out]) the per-example
    weight gradient is outer(a_i, g_i) with Frobenius norm^2 =
    |a_i|^2 * |g_i|^2 — O(B(d_in+d_out)) instead of O(B d_in d_out).
    """
    return jnp.sum(a.astype(jnp.float32) ** 2, -1) * jnp.sum(
        g.astype(jnp.float32) ** 2, -1
    )


def ghost_norms_seq_ref(a: jax.Array, g: jax.Array) -> jax.Array:
    """Per-example sq-norm for sequence inputs (pure-jnp oracle).

    y = a @ W with a: [B, S, d_in], g: [B, S, d_out]; per-example grad is
    A_i^T G_i with ||A^T G||_F^2 = sum_{s,t} (a_s . a_t)(g_s . g_t).
    The Pallas kernel in ``repro.kernels.ghost_norm`` computes this blocked;
    this reference is used when the kernel path is disabled.
    """
    aa = jnp.einsum("bsd,btd->bst", a.astype(jnp.float32), a.astype(jnp.float32))
    gg = jnp.einsum("bsd,btd->bst", g.astype(jnp.float32), g.astype(jnp.float32))
    return jnp.sum(aa * gg, axis=(1, 2))


def ghost_clipped_grads_dense_stack(
    forward_caches: list[tuple[jax.Array, jax.Array]],
    per_example_norm_sq_extra: jax.Array | None,
    clip_norm: float,
) -> tuple[jax.Array, jax.Array]:
    """Clip factors from accumulated per-layer ghost norms.

    Args:
      forward_caches: list of (a_l, g_l) per dense layer (2D case).
      per_example_norm_sq_extra: optional [B] extra norm^2 (e.g. biases).

    Returns:
      (per-example clip factors [B], per-example total norms [B]).
    """
    total = None
    for a, g in forward_caches:
        n = ghost_norms_2d(a, g) if a.ndim == 2 else ghost_norms_seq_ref(a, g)
        total = n if total is None else total + n
    if per_example_norm_sq_extra is not None:
        total = total + per_example_norm_sq_extra
    norms = jnp.sqrt(jnp.maximum(total, 0.0))
    return clip_factor(norms, clip_norm), norms
