"""Renyi-DP accountant for the Sampled Gaussian Mechanism (SGM).

Pure-Python/NumPy replacement for the Opacus/TF-privacy accountant the paper
relies on (Mironov, Talwar, Zhang, "Renyi Differential Privacy of the Sampled
Gaussian Mechanism", 2019).  DeCaPH trains with DP-SGD semantics on the
*aggregate* dataset: Poisson subsampling at global rate ``p``, noise multiplier
``sigma`` applied to the clipped gradient sum, composed over ``T`` rounds.

The accountant computes RDP orders ``eps(alpha)`` of one SGM step:

    A(alpha) = E_{z~mu0} [ ((1-p) + p * exp((2z-1)/(2 sigma^2)))^alpha ]

using the stable closed forms from Mironov et al. (integer alpha: binomial
expansion; fractional alpha: the two-term integral split at z=1/2 evaluated
with log-erfc), then composes linearly over steps and converts to
(epsilon, delta)-DP.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

DEFAULT_ORDERS: tuple[float, ...] = tuple(
    [1.0 + x / 10.0 for x in range(1, 100)] + list(range(11, 64)) + [128, 256, 512]
)


def _log_comb(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def _log_add(a: float, b: float) -> float:
    """log(exp(a) + exp(b)), stable."""
    if a == -math.inf:
        return b
    if b == -math.inf:
        return a
    hi, lo = (a, b) if a > b else (b, a)
    return hi + math.log1p(math.exp(lo - hi))


def _log_sub(a: float, b: float) -> float:
    """log(exp(a) - exp(b)) for a >= b, stable."""
    if b == -math.inf:
        return a
    if a == b:
        return -math.inf
    if a < b:
        raise ValueError("log_sub requires a >= b")
    return a + math.log1p(-math.exp(b - a))


def _log_erfc(x: float) -> float:
    """log(erfc(x)), stable for large positive x (asymptotic expansion)."""
    try:
        val = math.erfc(x)
    except OverflowError:  # pragma: no cover
        val = 0.0
    if val > 1e-300:
        return math.log(val)
    # Asymptotic series erfc(x) ~ exp(-x^2)/(x sqrt(pi)) * (1 - 1/(2x^2) + ...)
    return (
        -(x**2)
        - math.log(x)
        - 0.5 * math.log(math.pi)
        + math.log1p(-0.5 / (x**2) + 0.75 / (x**4))
    )


def _compute_log_a_int(p: float, sigma: float, alpha: int) -> float:
    """log(A(alpha)) for integer alpha >= 1 (binomial expansion)."""
    log_a = -math.inf
    for k in range(alpha + 1):
        term = (
            _log_comb(alpha, k)
            + k * math.log(p)
            + (alpha - k) * math.log1p(-p)
            + (k * k - k) / (2.0 * sigma**2)
        )
        log_a = _log_add(log_a, term)
    return log_a


def _signed_log_binom_frac(alpha: float, i: int) -> tuple[int, float]:
    """(sign, log|binom(alpha, i)|) for real non-integer alpha > 1.

    binom(alpha, i) = alpha (alpha-1) ... (alpha-i+1) / i!; the sign alternates
    once i exceeds alpha.
    """
    if i == 0:
        return 1, 0.0
    sign, log_num = 1, 0.0
    for j in range(i):
        v = alpha - j
        if v < 0:
            sign = -sign
            v = -v
        log_num += math.log(v)
    return sign, log_num - math.lgamma(i + 1)


def _compute_log_a_frac(p: float, sigma: float, alpha: float) -> float:
    """log(A(alpha)) for fractional alpha (Mironov et al. Sec. 3.3).

    Splits the SGM integral at z0 = sigma^2 log(1/p - 1) + 1/2 and evaluates
    each half with the binomial series + log-erfc; the series terms alternate
    in sign once i > alpha, so signs are tracked explicitly.
    """
    log_a0, log_a1 = -math.inf, -math.inf
    i = 0
    z0 = sigma**2 * math.log(1.0 / p - 1.0) + 0.5
    while True:  # terms decay superexponentially; break on convergence
        sign, log_coef = _signed_log_binom_frac(alpha, i)
        j = alpha - i
        log_t0 = log_coef + i * math.log(p) + j * math.log1p(-p)
        log_t1 = log_coef + j * math.log(p) + i * math.log1p(-p)
        log_e0 = math.log(0.5) + _log_erfc((i - z0) / (math.sqrt(2.0) * sigma))
        log_e1 = math.log(0.5) + _log_erfc((z0 - j) / (math.sqrt(2.0) * sigma))
        log_s0 = log_t0 + (i * i - i) / (2.0 * sigma**2) + log_e0
        log_s1 = log_t1 + (j * j - j) / (2.0 * sigma**2) + log_e1
        if sign > 0:
            log_a0 = _log_add(log_a0, log_s0)
            log_a1 = _log_add(log_a1, log_s1)
        else:
            log_a0 = _log_sub(log_a0, log_s0)
            log_a1 = _log_sub(log_a1, log_s1)
        i += 1
        if max(log_s0, log_s1) < -30.0:
            break
        if i > 2048:  # safety bound; series has long converged in practice
            break
    return _log_add(log_a0, log_a1)


def compute_rdp_sgm(
    p: float, sigma: float, steps: int, orders: Sequence[float] = DEFAULT_ORDERS
) -> np.ndarray:
    """RDP of ``steps`` compositions of the sampled Gaussian mechanism.

    Args:
      p: Poisson subsampling rate (aggregate over all participants in DeCaPH).
      sigma: noise multiplier (noise stddev = sigma * clip_norm on the SUM).
      steps: number of composed steps (communication rounds).
      orders: RDP orders alpha > 1.

    Returns:
      array of RDP epsilons, one per order.
    """
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"sampling rate must be in [0,1], got {p}")
    if sigma < 0:
        raise ValueError("sigma must be >= 0")
    rdp = np.zeros(len(orders), dtype=np.float64)
    for idx, alpha in enumerate(orders):
        if alpha <= 1.0:
            raise ValueError("RDP orders must be > 1")
        if sigma == 0.0 or p == 1.0 and sigma == 0.0:
            rdp[idx] = math.inf
            continue
        if p == 0.0:
            rdp[idx] = 0.0
            continue
        if sigma == 0.0:
            rdp[idx] = math.inf
            continue
        if p == 1.0:
            # Plain Gaussian mechanism.
            eps_alpha = alpha / (2.0 * sigma**2)
        else:
            if float(alpha).is_integer():
                log_a = _compute_log_a_int(p, sigma, int(alpha))
            else:
                log_a = _compute_log_a_frac(p, sigma, alpha)
            eps_alpha = log_a / (alpha - 1.0)
        rdp[idx] = eps_alpha * steps
    return rdp


def rdp_to_eps_delta(
    rdp: np.ndarray, orders: Sequence[float], delta: float
) -> tuple[float, float]:
    """Convert RDP curve to (epsilon, delta)-DP; returns (eps, best_order).

    Uses the classic Mironov conversion the paper cites:
        eps = rdp(alpha) + log(1/delta) / (alpha - 1),
    minimised over orders.
    """
    if not 0.0 < delta < 1.0:
        raise ValueError("delta must be in (0,1)")
    orders = np.asarray(orders, dtype=np.float64)
    eps = rdp + math.log(1.0 / delta) / (orders - 1.0)
    i = int(np.nanargmin(eps))
    return float(eps[i]), float(orders[i])


def compute_epsilon(
    p: float,
    sigma: float,
    steps: int,
    delta: float,
    orders: Sequence[float] = DEFAULT_ORDERS,
) -> float:
    """End-to-end epsilon for DeCaPH training (aggregate-dataset DP-SGD)."""
    if p == 0.0 or steps == 0:
        return 0.0  # mechanism never touches data
    rdp = compute_rdp_sgm(p, sigma, steps, orders)
    eps, _ = rdp_to_eps_delta(rdp, orders, delta)
    return eps


def steps_for_epsilon(
    p: float, sigma: float, target_eps: float, delta: float,
    orders: Sequence[float] = DEFAULT_ORDERS, max_steps: int = 1_000_000,
) -> int:
    """Largest number of steps with epsilon <= target (binary search)."""
    lo, hi = 0, 1
    while hi < max_steps and compute_epsilon(p, sigma, hi, delta, orders) <= target_eps:
        lo, hi = hi, hi * 2
    hi = min(hi, max_steps)
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if compute_epsilon(p, sigma, mid, delta, orders) <= target_eps:
            lo = mid
        else:
            hi = mid
    return lo


def sigma_for_epsilon(
    p: float, steps: int, target_eps: float, delta: float,
    orders: Sequence[float] = DEFAULT_ORDERS,
    lo: float = 1e-2, hi: float = 1e3, tol: float = 1e-4,
) -> float:
    """Smallest noise multiplier achieving the target epsilon (bisection)."""
    if compute_epsilon(p, hi, steps, delta, orders) > target_eps:
        raise ValueError("target epsilon unreachable within sigma bound")
    while hi - lo > tol * max(1.0, lo):
        mid = 0.5 * (lo + hi)
        if compute_epsilon(p, mid, steps, delta, orders) <= target_eps:
            hi = mid
        else:
            lo = mid
    return hi


@dataclass
class RDPAccountant:
    """Stateful accountant tracking composition across DeCaPH rounds."""

    sampling_rate: float
    noise_multiplier: float
    delta: float
    orders: tuple[float, ...] = DEFAULT_ORDERS
    steps: int = 0
    _rdp: np.ndarray = field(default=None, repr=False)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        self._per_step = compute_rdp_sgm(
            self.sampling_rate, self.noise_multiplier, 1, self.orders
        )
        self._rdp = np.zeros_like(self._per_step)

    def step(self, n: int = 1) -> None:
        self.steps += n
        self._rdp = self._rdp + n * self._per_step

    def epsilon(self) -> float:
        if self.steps == 0:
            return 0.0
        eps, _ = rdp_to_eps_delta(self._rdp, self.orders, self.delta)
        return eps

    def exceeds(self, budget: float) -> bool:
        return self.epsilon() > budget
