"""Ghost clipping for transformer stacks — the beyond-paper DP fast path.

The paper's Algorithm 2 needs per-example gradient L2 norms.  The faithful
implementation (``dp.per_example_clipped_grad_sum``) materialises one
gradient per example, which at pod scale forces microbatch size 1 and
re-gathers every FSDP weight shard once per example — the dominant
collective cost in the train_4k dry-runs (EXPERIMENTS.md §Perf).

This module computes the *exact* per-example norms inside ONE batched
backward pass using a collector threaded through every parameterised op:

  * each op forwards ``coll`` (a per-example [B] accumulator) unchanged;
  * its custom-vjp backward ADDS its per-example grad-norm^2 contribution to
    the collector's cotangent — for a dense layer that contribution is the
    ghost identity  ||A_i^T G_i||_F^2 = sum_{s,t}(a_s.a_t)(g_s.g_t)
    (the Pallas ``ghost_norm`` kernel on TPU), for RMSNorm scales and
    embeddings the cheap exact forms below;
  * one ``jax.vjp`` with cotangents (1.0, ones(B)) therefore yields the
    summed gradients AND all per-example norms — no per-example gradient is
    ever materialised, so the whole global batch runs in ONE forward/backward
    (weight all-gathers amortise over the batch again).

A second backward over the clip-weighted loss produces the clipped-sum
gradient.  Supported family: dense decoder stacks (GQA attention + gated/
plain FFN + RMSNorm/non-param LN + tied or untied head + standard/M-RoPE)
— i.e. smollm / olmo / gemma / nemotron / qwen2-vl.  MoE and SSM mixers keep
the faithful per-example path (their dispatch mixes examples, see DESIGN.md).

Equivalence with vmap(grad) norms and with transformer.forward loss is
enforced by tests/test_ghost_transformer.py.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import pname
from repro.models.transformer import _apply_norm  # loss parity w/ main stack

PyTree = Any


# ---------------------------------------------------------------------------
# Collector ops
# ---------------------------------------------------------------------------

def _ghost_norm_pairs(a: jax.Array, g: jax.Array) -> jax.Array:
    """Per-example ||A^T G||_F^2; dispatches 2D/3D; kernel on TPU."""
    if a.ndim == 2:
        from repro.core.dp import ghost_norms_2d

        return ghost_norms_2d(a, g)
    from repro.kernels.ghost_norm.ops import ghost_norm

    return ghost_norm(a, g)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def dp_dense(a: jax.Array, w: jax.Array, coll: jax.Array,
             with_norms: bool = True):
    """y = a @ w with the collector threaded through."""
    return a @ w, coll


def _dp_dense_fwd(a, w, coll, with_norms):
    return (a @ w, coll), (a, w)


def _dp_dense_bwd(with_norms, res, cot):
    a, w = res
    ybar, collbar = cot
    abar = ybar @ w.T
    if a.ndim == 3:
        wbar = jnp.einsum("bsi,bso->io", a, ybar)
    else:
        wbar = jnp.einsum("bi,bo->io", a, ybar)
    if with_norms:
        # NOTE: no call-site upcast — the blocked ghost-norm converts tiles
        # internally; converting the whole residual here materialises a
        # second f32 copy of every saved activation (observed as a
        # [L, B, S, D] f32 buffer in the nemotron dry-run, §Perf iter 1c).
        collbar = collbar + _ghost_norm_pairs(a, ybar).astype(collbar.dtype)
    return abar.astype(a.dtype), wbar.astype(w.dtype), collbar


dp_dense.defvjp(_dp_dense_fwd, _dp_dense_bwd)


def _rmsnorm_raw(scale, x, eps=1e-6):
    # Variance in f32 (fused reduce); xhat stays in the input dtype so the
    # layer scan never materialises an f32 copy of the residual stream
    # (§Perf iter 1d: XLA saved convert(x) ACROSS the scan otherwise).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    xhat = x * inv
    return xhat * scale.astype(x.dtype), xhat


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def dp_rmsnorm(scale: jax.Array, x: jax.Array, coll: jax.Array,
               with_norms: bool = True):
    y, _ = _rmsnorm_raw(scale, x)
    return y, coll


def _dp_rmsnorm_fwd(scale, x, coll, with_norms):
    y, _ = _rmsnorm_raw(scale, x)
    return (y, coll), (scale, x)


def _dp_rmsnorm_bwd(with_norms, res, cot):
    scale, x = res
    ybar, collbar = cot

    def fn(s, xx):
        return _rmsnorm_raw(s, xx)[0]

    _, inner = jax.vjp(fn, scale, x)
    sbar, xbar = inner(ybar)
    if with_norms:
        _, xhat = _rmsnorm_raw(scale, x)
        # per-example scale grad: sum over sequence of ybar * xhat
        axes = tuple(range(1, x.ndim - 1))
        prod = ybar.astype(jnp.float32) * xhat.astype(jnp.float32)
        g_scale = jnp.sum(prod, axis=axes) if x.ndim == 3 else prod
        collbar = collbar + jnp.sum(jnp.square(g_scale), axis=-1).astype(collbar.dtype)
    return sbar.astype(scale.dtype), xbar.astype(x.dtype), collbar


dp_rmsnorm.defvjp(_dp_rmsnorm_fwd, _dp_rmsnorm_bwd)


@jax.custom_vjp
def dp_embed(emb: jax.Array, tokens: jax.Array, coll: jax.Array):
    """y = emb[tokens] with exact per-example grad norms in the backward."""
    return emb[tokens], coll


def _dp_embed_fwd(emb, tokens, coll):
    # dtype/shape carried via an empty slice (residuals must be JAX types)
    return (emb[tokens], coll), (emb[:0], emb.shape[0], tokens)


def _per_example_embed_norm(tokens_b: jax.Array, g_b: jax.Array) -> jax.Array:
    """||scatter-add_{s: tok_s=r} g_s||^2 summed over rows r, one example.

    Rows repeat when a token repeats, so group equal tokens (sort +
    segment-sum) — O(S log S + S D), no [V, D] buffer.
    """
    s = tokens_b.shape[0]
    order = jnp.argsort(tokens_b)
    tok_sorted = tokens_b[order]
    g_sorted = g_b[order].astype(jnp.float32)
    new_seg = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (tok_sorted[1:] != tok_sorted[:-1]).astype(jnp.int32)]
    )
    seg_ids = jnp.cumsum(new_seg) - 1
    sums = jax.ops.segment_sum(g_sorted, seg_ids, num_segments=s)
    return jnp.sum(jnp.square(sums))


def _dp_embed_bwd(res, cot):
    emb_proto, vocab, tokens = res
    ybar, collbar = cot
    embbar = jnp.zeros((vocab,) + emb_proto.shape[1:], jnp.float32).at[
        tokens
    ].add(ybar.astype(jnp.float32))
    norms = jax.vmap(_per_example_embed_norm)(tokens, ybar)
    return (embbar.astype(emb_proto.dtype), None,
            collbar + norms.astype(collbar.dtype))


dp_embed.defvjp(_dp_embed_fwd, _dp_embed_bwd)


# ---------------------------------------------------------------------------
# Ghost forward for dense decoder stacks (loss-identical to transformer.py)
# ---------------------------------------------------------------------------

def _supported(cfg) -> bool:
    if cfg.is_encoder_decoder or cfg.n_experts:
        return False
    return all(
        spec.mixer == "attn" and spec.ffn == "dense" and not spec.cross_attn
        for _, pattern in cfg.stack for spec in pattern
    )


def _norm_g(cfg, p, x, coll, with_norms):
    if cfg.norm == "rmsnorm":
        return dp_rmsnorm(p[pname("scale", "embed")], x, coll, with_norms)
    return _apply_norm(cfg, p, x), coll  # non-parametric: nothing to collect


def _attn_g(cfg, p, x, positions, mrope_positions, window, coll, with_norms):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, coll = dp_dense(x, p[pname("wq", "embed", "qheads")], coll, with_norms)
    k, coll = dp_dense(x, p[pname("wk", "embed", "kv_heads")], coll, with_norms)
    v, coll = dp_dense(x, p[pname("wv", "embed", "kv_heads")], coll, with_norms)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.rope_type == "mrope" and mrope_positions is not None:
        from repro.models.layers import apply_mrope

        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_type != "none":
        from repro.models.layers import apply_rope

        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models.layers import shard as _shard

    q = _shard(q, "attn_batch", None, "heads", None)
    k = _shard(k, "attn_batch", None, None, None)
    v = _shard(v, "attn_batch", None, None, None)
    if getattr(cfg, "use_flash", False):
        out = attn_lib._sdpa_blocked(q, k, v, causal=True, window=window)
    else:
        mask = attn_lib._causal_mask(s, s, 0, window)
        out = attn_lib._sdpa(q, k, v, mask)
    out = out.reshape(b, s, h * hd)
    y, coll = dp_dense(out, p[pname("wo", "qheads", "embed")], coll, with_norms)
    return y, coll


def _ffn_g(cfg, p, x, coll, with_norms):
    up, coll = dp_dense(x, p[pname("w_up", "embed", "mlp")], coll, with_norms)
    if cfg.ffn_kind in ("swiglu", "geglu"):
        gate, coll = dp_dense(x, p[pname("w_gate", "embed", "mlp")], coll,
                              with_norms)
        act = jax.nn.silu if cfg.ffn_kind == "swiglu" else jax.nn.gelu
        h = act(gate) * up
    else:
        from repro.models.layers import act_fn

        h = act_fn(cfg.ffn_kind)(up)
    y, coll = dp_dense(h, p[pname("w_down", "mlp", "embed")], coll, with_norms)
    return y, coll


def forward_ghost(cfg, params, batch, coll, *, with_norms: bool = True):
    """Loss-identical ghost forward -> (per-example mean-CE [B], coll)."""
    assert _supported(cfg), f"{cfg.name}: ghost path supports dense stacks"
    tokens = batch["tokens"]
    emb = params[pname("embed", "vocab", "embed")]
    x, coll = dp_embed(emb, tokens, coll)
    x = x.astype(cfg.cdtype)
    if cfg.arch_type == "vlm" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(cfg.cdtype)
        x = jnp.concatenate([ve, x], axis=1)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    mrope_positions = batch.get("mrope_positions")
    if cfg.rope_type == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

    window = cfg.sliding_window
    from repro.models.layers import shard

    def layer_body(p, x, coll):
        h, coll = _norm_g(cfg, p["norm1"], x, coll, with_norms)
        h, coll = _attn_g(cfg, p["mixer"], h, positions, mrope_positions,
                          window, coll, with_norms)
        x = x + h
        h, coll = _norm_g(cfg, p["norm2"], x, coll, with_norms)
        h, coll = _ffn_g(cfg, p["ffn"], h, coll, with_norms)
        x = shard(x + h, "batch", "seq", None)
        return x, coll

    for gi, (repeat, pattern) in enumerate(cfg.stack):
        stacked = params[f"group{gi}"]
        if cfg.scan_layers and repeat > 1:
            # The collector is just a scan carry: scan's transpose
            # accumulates each layer's custom-vjp contribution into coll-bar.
            def scan_body(carry, lp):
                xx, cc = carry
                body = layer_body
                if cfg.remat:
                    body = jax.checkpoint(layer_body, static_argnums=())
                xx, cc = body(lp["e0"], xx, cc)
                return (xx, cc), None

            (x, coll), _ = jax.lax.scan(scan_body, (x, coll), stacked)
        else:
            for r in range(repeat):
                lp = jax.tree_util.tree_map(lambda t: t[r], stacked)
                if cfg.remat:
                    x, coll = jax.checkpoint(
                        lambda xx, cc, pp=lp["e0"]: layer_body(pp, xx, cc)
                    )(x, coll)
                else:
                    x, coll = layer_body(lp["e0"], x, coll)
    x, coll = _norm_g(cfg, params["final_norm"], x, coll, with_norms)
    if cfg.tie_embeddings:
        # tied head: a dense against emb^T; its ghost contribution combines
        # with the embedding-gather contribution on the SAME parameter.
        # Exactness requires the cross term; we treat the head and gather
        # contributions as independent (upper bound crossed by <= 2ab term).
        # For the untied archs (nemotron) this is exact.
        logits, coll = dp_dense(
            x, params[pname("embed", "vocab", "embed")].T.astype(cfg.cdtype),
            coll, with_norms,
        )
    else:
        logits, coll = dp_dense(
            x, params[pname("head", "embed", "vocab")].astype(cfg.cdtype),
            coll, with_norms,
        )
    labels = batch["labels"]
    if cfg.arch_type == "vlm" and "vision_embeds" in batch:
        logits = logits[:, -labels.shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels_c[..., None], axis=-1
    )[..., 0]
    per_ex = jnp.sum((logz - gold) * mask, axis=-1) / jnp.maximum(
        jnp.sum(mask, axis=-1), 1.0
    )
    return per_ex, coll


def _chunked(batch: PyTree, n_chunks: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda t: t.reshape((n_chunks, t.shape[0] // n_chunks) + t.shape[1:]),
        batch,
    )


def ghost_clipped_grad_sum(cfg, params, batch, *, clip_norm: float,
                           chunk_size: int | None = None,
                           constrain_grads=None, mask=None):
    """Exact clipped-sum gradients in 2 batched passes (no per-example grads).

    ``chunk_size`` bounds residual-activation memory: the batch is processed
    in ``B/chunk_size`` scanned chunks (weight gathers scale with the chunk
    count, not the example count — the §Perf win over the faithful path).

    ``mask`` ([B] of {0,1}) drops padding rows: their clip factors are zeroed
    (so they contribute nothing to the grad sum) and the returned loss is the
    mask-weighted mean — the same semantics as
    ``dp.per_example_clipped_grad_sum``, which fused round-steps rely on.

    Returns (grad_sum pytree, mask-weighted mean loss, per-example norms).
    """
    b = batch["tokens"].shape[0]
    chunk = min(chunk_size or b, b)
    if b % chunk != 0:  # odd pads fall back to one full-batch chunk
        chunk = b
    n_chunks = b // chunk
    if mask is None:
        mask = jnp.ones((b,), jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)

    def norms_of_chunk(bchunk, mchunk):
        # Masking the loss here zeroes pad rows' per-example cotangents, so
        # their collector contribution vanishes and their norm comes out 0
        # (pure seed); real rows see cotangent 1.0, identical to unmasked.
        def f(p, coll):
            per_ex, coll_out = forward_ghost(cfg, p, bchunk, coll,
                                             with_norms=True)
            return jnp.sum(per_ex * mchunk), coll_out

        coll0 = jnp.zeros((chunk,), jnp.float32)
        (loss_sum, _), vjp_fn = jax.vjp(f, params, coll0)
        _, collbar = vjp_fn((jnp.asarray(1.0), jnp.ones((chunk,), jnp.float32)))
        norms = jnp.sqrt(jnp.maximum(collbar - 1.0, 0.0))  # seed rides along
        return norms, loss_sum

    def grads_of_chunk(bchunk, factors):
        def weighted(p):
            per_ex, _ = forward_ghost(
                cfg, p, bchunk, jnp.zeros((chunk,), jnp.float32),
                with_norms=False,
            )
            return jnp.sum(per_ex * factors)

        return jax.grad(weighted)(params)

    if n_chunks == 1:
        norms, loss_sum = norms_of_chunk(batch, mask)
        factors = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        grads = grads_of_chunk(batch, factors * mask)
        return grads, loss_sum / denom, norms

    chunks = _chunked(batch, n_chunks)
    mask_chunks = mask.reshape(n_chunks, chunk)

    def scan_norms(carry, args):
        bchunk, mchunk = args
        norms, loss_sum = norms_of_chunk(bchunk, mchunk)
        return carry + loss_sum, norms

    loss_total, norms_all = jax.lax.scan(
        scan_norms, jnp.zeros(()), (chunks, mask_chunks)
    )
    norms = norms_all.reshape(-1)
    factors_all = jnp.minimum(
        1.0, clip_norm / jnp.maximum(norms_all, 1e-12)
    ) * mask_chunks

    def scan_grads(acc, args):
        bchunk, factors = args
        g = grads_of_chunk(bchunk, factors)
        g = jax.tree_util.tree_map(
            lambda a_, g_: a_ + g_.astype(jnp.float32), acc, g
        )
        if constrain_grads is not None:
            g = constrain_grads(g)
        return g, None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    grads, _ = jax.lax.scan(scan_grads, zeros, (chunks, factors_all))
    return grads, loss_total / denom, norms
