"""Deprecated host-level entry points for the paper's federation arms.

Since the Arm/Backend redesign every arm's training numerics live in exactly
one place — ``repro.arms`` — and run on either the idealized backend
(``repro.arms.LocalRunner``) or the discrete-event simulator
(``repro.arms.SimRunner``).  The ``run_*`` functions below are thin
deprecation shims over the idealized backend kept for pre-refactor callers;
they reproduce the historical results seed-for-seed.  New code should use::

    import repro.arms as arms
    report = arms.run("decaph", model, silos, arms.ArmConfig(...))

``FederationConfig`` is an alias of :class:`repro.arms.ArmConfig` and
``RunResult`` of :class:`repro.arms.RunReport` (the unified result type with
an optional timing section only the sim backend fills in).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.arms import LocalRunner, RunReport, get
from repro.arms.base import (
    ArmConfig,
    Model,
    Participant,
    _global_stats,
    normalize_participants,
    poisson_batch as _new_poisson_batch,
    sgd_update,
)
from repro.arms.results import RoundLog

__all__ = [
    "FederationConfig",
    "Model",
    "Participant",
    "RoundLog",
    "RunResult",
    "RUNNERS",
    "normalize_participants",
    "run_decaph",
    "run_fl",
    "run_local",
    "run_pate",
    "run_primia",
]

# Legacy aliases — same objects, historical names.
FederationConfig = ArmConfig
RunResult = RunReport
_sgd_update = sgd_update
_poisson_batch = _new_poisson_batch


def _deprecated(old: str, arm: str) -> None:
    warnings.warn(
        f"repro.core.federation.{old} is deprecated; use "
        f"repro.arms.run({arm!r}, ...) (idealized backend) or "
        f"repro.arms.SimRunner for simulated time",
        DeprecationWarning,
        stacklevel=3,
    )


def _run_ideal(arm_name: str, model: Model,
               participants: Sequence[Participant],
               cfg: ArmConfig) -> RunReport:
    # The shims promise the PRE-refactor trajectories seed-for-seed.  The
    # fused cohort step (DESIGN.md §7) reproduces the same draws but vmaps
    # the per-participant float math, which re-associates at the ulp level —
    # so the historical per-participant loop is pinned here.
    cfg = dataclasses.replace(cfg, fused_rounds=False)
    return LocalRunner().run(get(arm_name)(model, participants, cfg))


def run_decaph(model, participants, cfg, *, eval_fn=None) -> RunResult:
    """The DeCaPH protocol, Steps 1-7 of the paper (idealized backend)."""
    _deprecated("run_decaph", "decaph")
    return _run_ideal("decaph", model, participants, cfg)


def run_fl(model, participants, cfg) -> RunResult:
    """FL without DP: FedSGD, or FedAvg when ``cfg.fl_local_steps > 1``."""
    _deprecated("run_fl", "fl")
    return _run_ideal("fl", model, participants, cfg)


def run_primia(model, participants, cfg) -> RunResult:
    """PriMIA-style local-DP FL with per-client accountants."""
    _deprecated("run_primia", "primia")
    return _run_ideal("primia", model, participants, cfg)


def run_local(model, participants, cfg) -> RunResult:
    """Silo-only baselines: one independent non-private model per silo."""
    _deprecated("run_local", "local")
    return _run_ideal("local", model, participants, cfg)


def run_pate(
    model: Model,
    participants: Sequence[Participant],
    cfg: ArmConfig,
    *,
    public_x: np.ndarray,
    n_classes: int = 2,
    gnmax_sigma: float = 2.0,
) -> RunResult:
    """PATE/GNMax baseline (paper Supplementary, "Existing frameworks").

    Each hospital trains a local teacher (the ``local`` arm); a student is
    trained on public data labelled by the noisy argmax of teacher votes.
    The paper argues this class of frameworks needs (a) a public dataset and
    (b) MANY teachers to get good labels at reasonable ε — with 3-8
    hospitals the vote margin is tiny, so utility collapses; this runner
    exists to make that argument measurable (benchmarks/pate_ablation.py).

    ε accounting: each query is a Gaussian mechanism with per-teacher
    sensitivity 1 → RDP(α) = α/(2 σ²) per query, composed over queries
    (data-independent bound; the tighter data-dependent PATE analysis only
    helps with large teacher ensembles).

    Not a registered arm and not deprecated: it is a one-shot pipeline over
    the ``local`` arm, not a per-round protocol, so it has no meaningful
    sim-backend story and this remains its canonical entry point.
    """
    from repro.core.accountant import DEFAULT_ORDERS, rdp_to_eps_delta

    # 1) local teachers (silo-only training via the registered arm)
    teachers = _run_ideal("local", model, participants, cfg).per_node_params

    # 2) noisy-vote labelling of the public pool
    rng = np.random.default_rng(cfg.seed)
    votes = np.zeros((len(public_x), n_classes), np.float64)
    for t in teachers:
        pred = np.asarray(model.predict_fn(t, jnp.asarray(public_x)))
        if pred.ndim == 1:  # binary score -> two-column votes
            cls = (pred > 0.5).astype(int)
        else:
            cls = pred.argmax(-1)
        votes[np.arange(len(public_x)), cls] += 1.0
    noisy = votes + rng.normal(0, gnmax_sigma, votes.shape)
    labels = noisy.argmax(-1).astype(np.float32 if n_classes == 2 else np.int32)

    # 3) privacy: Q Gaussian queries composed in RDP
    orders = np.asarray(DEFAULT_ORDERS)
    rdp = len(public_x) * orders / (2.0 * gnmax_sigma**2)
    eps, _ = rdp_to_eps_delta(rdp, orders, cfg.dp.delta)

    # 4) student trained on the noisy labels (plain SGD; labels are public)
    student = Participant(public_x.astype(np.float32), labels)
    res = _run_ideal("local", model, [student], cfg)
    return RunResult(
        params=res.per_node_params[0], logs=[], epsilon=float(eps),
        rounds_completed=cfg.rounds, arm="pate", backend="ideal",
    )


RUNNERS = {
    "decaph": run_decaph,
    "fl": run_fl,
    "primia": run_primia,
    "local": run_local,
    "pate": run_pate,
}
