"""Host-level federated runtimes: DeCaPH and the paper's comparison arms.

This module simulates H participants (hospitals) as the paper deploys them —
each holding a private shard, communicating once per round — so the paper's
experiments (Figs. 2-5) can be reproduced end to end.  The SPMD fast path for
pod-scale models lives in ``repro.core.decaph_step``; both paths share the DP
mechanics in ``repro.core.dp`` and are equivalence-tested.

These runtimes are *idealized*: every hospital is infinitely fast, always
online, and communication is free.  For simulated wall-clock, bytes-on-wire,
stragglers and dropout (including SecAgg mask recovery), drive the same arms
through the discrete-event simulator in ``repro.sim``.

Arms implemented (Study design):
  * ``decaph``  — the paper's framework: shared Poisson rate, per-example clip,
    per-participant noise shares, SecAgg sum, rotating leader.
  * ``fl``      — FedSGD with the same sampling/sync cadence, no clip/noise
    (the paper's non-private upper bound; SL is equivalent for utility).
  * ``primia``  — local-DP FL: every client runs its own DP-SGD with full
    local noise and a *local* accountant; clients drop out when their local
    budget is exhausted (the forgetting failure mode the paper describes).
  * ``local``   — silo-only training, no collaboration.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_lib
from repro.core.accountant import RDPAccountant
from repro.core.leader import leader_schedule
from repro.core.secagg import SecAggConfig, secure_sum

PyTree = Any


@dataclasses.dataclass
class Model:
    """Functional model triple used by the federation runtimes."""

    init_fn: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, PyTree], jax.Array]  # (params, one example) -> scalar
    predict_fn: Callable[[PyTree, jax.Array], jax.Array]


@dataclasses.dataclass
class Participant:
    """One hospital: a private (X, y) shard."""

    x: np.ndarray
    y: np.ndarray

    def __len__(self) -> int:
        return len(self.x)


@dataclasses.dataclass
class FederationConfig:
    rounds: int = 100
    batch_size: int = 64           # desired aggregate mini-batch size B
    lr: float = 0.1
    weight_decay: float = 0.0
    dp: dp_lib.DPConfig = dataclasses.field(default_factory=dp_lib.DPConfig)
    epsilon_budget: float | None = None   # stop when the accountant exceeds it
    use_secagg: bool = True        # run the real fixed-point SecAgg protocol
    secagg_frac_bits: int = 16
    fl_local_steps: int = 1        # >1 = FedAvg (weight averaging) for run_fl
    leader_strategy: str = "uniform"
    seed: int = 0
    eval_every: int = 0            # 0 = never
    max_pad_batch: int | None = None  # static padded per-silo batch (jit shapes)


@dataclasses.dataclass
class RoundLog:
    round: int
    leader: int
    loss: float
    epsilon: float
    aggregate_batch: int


@dataclasses.dataclass
class RunResult:
    params: PyTree
    logs: list[RoundLog]
    epsilon: float
    rounds_completed: int
    per_client_params: list[PyTree] | None = None


def _global_stats(parts: Sequence[Participant]) -> tuple[np.ndarray, np.ndarray]:
    """Preparation-phase global mean/std via (conceptually) SecAgg sums."""
    n = sum(len(p) for p in parts)
    s = sum(p.x.sum(axis=0) for p in parts)
    mean = s / n
    sq = sum(((p.x - mean) ** 2).sum(axis=0) for p in parts)
    std = np.sqrt(sq / n) + 1e-8
    return mean.astype(np.float32), std.astype(np.float32)


def normalize_participants(parts: Sequence[Participant]) -> list[Participant]:
    mean, std = _global_stats(parts)
    return [Participant((p.x - mean) / std, p.y) for p in parts]


def _poisson_batch(
    rng: np.random.Generator, part: Participant, rate: float, pad_to: int
) -> tuple[dict[str, np.ndarray], np.ndarray, int]:
    """Poisson-sample a silo mini-batch, padded to a static shape + mask."""
    sel = rng.random(len(part)) < rate
    idx = np.nonzero(sel)[0]
    k = len(idx)
    if k > pad_to:
        idx = idx[:pad_to]
        k = pad_to
    xb = np.zeros((pad_to,) + part.x.shape[1:], part.x.dtype)
    yb = np.zeros((pad_to,) + part.y.shape[1:], part.y.dtype)
    xb[:k] = part.x[idx]
    yb[:k] = part.y[idx]
    mask = np.zeros((pad_to,), np.float32)
    mask[:k] = 1.0
    return {"x": xb, "y": yb}, mask, k


def _sgd_update(params: PyTree, grads: PyTree, lr: float, wd: float) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, g: p - lr * (g + wd * p), params, grads
    )


def run_decaph(
    model: Model,
    participants: Sequence[Participant],
    cfg: FederationConfig,
    *,
    eval_fn: Callable[[PyTree], float] | None = None,
) -> RunResult:
    """The DeCaPH protocol, Steps 1-7 of the paper."""
    h = len(participants)
    n_total = sum(len(p) for p in participants)
    rate = cfg.batch_size / n_total
    pad = cfg.max_pad_batch or max(8, int(rate * max(len(p) for p in participants) * 4))
    leaders = leader_schedule(
        h, cfg.rounds, seed=cfg.seed, strategy=cfg.leader_strategy
    )
    acct = RDPAccountant(
        sampling_rate=rate,
        noise_multiplier=cfg.dp.noise_multiplier,
        delta=cfg.dp.delta,
    )
    n_rounds = cfg.rounds
    if cfg.epsilon_budget is not None:
        from repro.core.accountant import steps_for_epsilon

        n_rounds = min(
            cfg.rounds,
            steps_for_epsilon(rate, cfg.dp.noise_multiplier,
                              cfg.epsilon_budget, cfg.dp.delta,
                              max_steps=cfg.rounds + 1),
        )

    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)

    clipped_sum = jax.jit(
        lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
            model.loss_fn, p, b,
            clip_norm=cfg.dp.clip_norm,
            microbatch_size=min(cfg.dp.microbatch_size, pad),
            mask=m,
        )
    )

    logs: list[RoundLog] = []
    for t in range(n_rounds):
        # Step 1: leader selection (bookkeeping under honest-but-curious).
        leader = int(leaders[t])
        # Step 2: each silo Poisson-samples with the shared global rate.
        batches, masks, sizes = [], [], []
        for part in participants:
            b, m, k = _poisson_batch(rng, part, rate, pad)
            batches.append(b)
            masks.append(m)
            sizes.append(k)
        # Aggregate mini-batch size ||B^t|| via SecAgg (cost modelled; exact).
        if cfg.use_secagg:
            agg_size = secure_sum(
                [jnp.asarray([float(s)]) for s in sizes],
                SecAggConfig(h, frac_bits=0, seed=cfg.seed * 7919 + t),
            )[0]
            agg_batch = int(round(float(agg_size)))
        else:
            agg_batch = int(sum(sizes))
        if agg_batch == 0:
            logs.append(RoundLog(t, leader, float("nan"), acct.epsilon(), 0))
            continue
        # Step 3: local clip + per-participant noise shares.
        shares, losses = [], []
        for i, (b, m) in enumerate(zip(batches, masks)):
            g_sum, loss = clipped_sum(params, b, jnp.asarray(m))
            nkey = jax.random.fold_in(jax.random.fold_in(key, 17 + t), i)
            g_noised = dp_lib.tree_add_noise(
                g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=h,
            )
            shares.append(g_noised)
            losses.append(float(loss))
        # Steps 4-5: SecAgg the noised sums; leader computes the update.
        if cfg.use_secagg:
            total = secure_sum(
                shares, SecAggConfig(h, cfg.secagg_frac_bits, seed=cfg.seed + t)
            )
        else:
            total = jax.tree_util.tree_map(
                lambda *xs: sum(xs[1:], xs[0]), *shares
            )
        grad = jax.tree_util.tree_map(lambda x: x / agg_batch, total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        # Step 6-7: everyone syncs with the leader; accountant advances.
        acct.step()
        logs.append(
            RoundLog(t, leader, float(np.mean(losses)), acct.epsilon(), agg_batch)
        )
        if cfg.epsilon_budget is not None and acct.exceeds(cfg.epsilon_budget):
            break
    return RunResult(params, logs, acct.epsilon(), len(logs))


def run_fl(
    model: Model,
    participants: Sequence[Participant],
    cfg: FederationConfig,
) -> RunResult:
    """FL without DP (paper's non-private reference).

    fl_local_steps == 1 -> FedSGD with DeCaPH's cadence (the paper's FL
    comparison arm); > 1 -> FedAvg (McMahan et al.): each client takes k
    local SGD steps per round and the server size-weights the weights.
    """
    h = len(participants)
    n_total = sum(len(p) for p in participants)
    rate = cfg.batch_size / n_total
    pad = cfg.max_pad_batch or max(8, int(rate * max(len(p) for p in participants) * 4))
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)

    def batch_grad(p, b, m):
        def masked_loss(pp):
            losses = jax.vmap(lambda ex: model.loss_fn(pp, ex))(b)
            return jnp.sum(losses * m)
        return jax.grad(masked_loss)(p)

    batch_grad = jax.jit(batch_grad)
    logs: list[RoundLog] = []
    for t in range(cfg.rounds):
        if cfg.fl_local_steps <= 1:  # FedSGD
            grads, sizes = [], []
            for part in participants:
                b, m, k = _poisson_batch(rng, part, rate, pad)
                grads.append(batch_grad(params, b, jnp.asarray(m)))
                sizes.append(k)
            agg = int(sum(sizes))
            if agg == 0:
                continue
            total = jax.tree_util.tree_map(
                lambda *xs: sum(xs[1:], xs[0]), *grads
            )
            grad = jax.tree_util.tree_map(lambda x: x / agg, total)
            params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        else:  # FedAvg: local epochs then size-weighted weight averaging
            client_params, weights = [], []
            for part in participants:
                local = params
                for _ in range(cfg.fl_local_steps):
                    b, m, k = _poisson_batch(rng, part, rate, pad)
                    if k == 0:
                        continue
                    g = batch_grad(local, b, jnp.asarray(m))
                    g = jax.tree_util.tree_map(lambda x: x / max(k, 1), g)
                    local = _sgd_update(local, g, cfg.lr, cfg.weight_decay)
                client_params.append(local)
                weights.append(len(part))
            wsum = float(sum(weights))
            params = jax.tree_util.tree_map(
                lambda *xs: sum(w / wsum * x for w, x in zip(weights, xs)),
                *client_params,
            )
            agg = cfg.batch_size
        logs.append(RoundLog(t, t % h, float("nan"), 0.0, agg))
    return RunResult(params, logs, 0.0, len(logs))


def run_primia(
    model: Model,
    participants: Sequence[Participant],
    cfg: FederationConfig,
) -> RunResult:
    """PriMIA-style local-DP FL.

    Every client runs DP-SGD *locally*: local Poisson rate B_h/|D_h| with the
    same per-client mini-batch target, full noise N(0,(C sigma)^2) added by
    each client (local DP), and a local accountant.  Clients stop contributing
    once their own epsilon budget is spent — reproducing the paper's observed
    failure mode (clients with fewer points drop out first when rates differ).
    """
    h = len(participants)
    n_total = sum(len(p) for p in participants)
    key = jax.random.key(cfg.seed)
    params = model.init_fn(key)
    rng = np.random.default_rng(cfg.seed)

    per_client_batch = max(1, cfg.batch_size // h)
    rates = [min(1.0, per_client_batch / max(len(p), 1)) for p in participants]
    pads = [cfg.max_pad_batch or max(8, int(r * len(p) * 4) or 8)
            for r, p in zip(rates, participants)]
    accts = [
        RDPAccountant(
            sampling_rate=r, noise_multiplier=cfg.dp.noise_multiplier,
            delta=cfg.dp.delta,
        )
        for r in rates
    ]
    budget = cfg.epsilon_budget or float("inf")
    # A client participates only while ANOTHER step stays within its local
    # budget (never overshoots) — clients with higher sampling rates (small
    # silos) drop out first, the paper's PriMIA failure mode.
    if cfg.epsilon_budget is not None:
        from repro.core.accountant import steps_for_epsilon

        max_rounds = [
            steps_for_epsilon(r, cfg.dp.noise_multiplier, budget, cfg.dp.delta,
                              max_steps=cfg.rounds + 1)
            for r in rates
        ]
    else:
        max_rounds = [cfg.rounds] * h

    clipped_sum = jax.jit(
        lambda p, b, m: dp_lib.per_example_clipped_grad_sum(
            model.loss_fn, p, b,
            clip_norm=cfg.dp.clip_norm,
            microbatch_size=cfg.dp.microbatch_size,
            mask=m,
        ),
        static_argnames=(),
    )

    logs: list[RoundLog] = []
    for t in range(cfg.rounds):
        updates, sizes, active = [], [], 0
        for i, part in enumerate(participants):
            if accts[i].steps >= max_rounds[i]:
                continue  # client's local budget exhausted -> drops out
            active += 1
            b, m, k = _poisson_batch(rng, part, rates[i], pads[i])
            g_sum, _ = clipped_sum(params, b, jnp.asarray(m))
            nkey = jax.random.fold_in(jax.random.fold_in(key, 31 + t), i)
            # Local DP: the FULL noise per client (n_shares=1).
            g = dp_lib.tree_add_noise(
                g_sum, nkey, clip_norm=cfg.dp.clip_norm,
                noise_multiplier=cfg.dp.noise_multiplier, n_shares=1,
            )
            g = jax.tree_util.tree_map(lambda x: x / max(k, 1), g)
            updates.append(g)
            sizes.append(k)
            accts[i].step()
        if not updates:
            break
        total = jax.tree_util.tree_map(lambda *xs: sum(xs[1:], xs[0]), *updates)
        grad = jax.tree_util.tree_map(lambda x: x / len(updates), total)
        params = _sgd_update(params, grad, cfg.lr, cfg.weight_decay)
        eps = max(a.epsilon() for a in accts)
        logs.append(RoundLog(t, t % h, float("nan"), eps, int(sum(sizes))))
    eps = max(a.epsilon() for a in accts)
    return RunResult(params, logs, eps, len(logs))


def run_local(
    model: Model,
    participants: Sequence[Participant],
    cfg: FederationConfig,
) -> RunResult:
    """Silo-only baselines: one independent non-private model per hospital."""
    per_client = []
    rng = np.random.default_rng(cfg.seed)
    for i, part in enumerate(participants):
        key = jax.random.key(cfg.seed + i)
        params = model.init_fn(key)
        bs = min(cfg.batch_size, len(part))

        @jax.jit
        def batch_grad(p, b):
            def mean_loss(pp):
                return jnp.mean(jax.vmap(lambda ex: model.loss_fn(pp, ex))(b))
            return jax.grad(mean_loss)(p)

        for t in range(cfg.rounds):
            idx = rng.choice(len(part), size=bs, replace=False)
            b = {"x": jnp.asarray(part.x[idx]), "y": jnp.asarray(part.y[idx])}
            g = batch_grad(params, b)
            params = _sgd_update(params, g, cfg.lr, cfg.weight_decay)
        per_client.append(params)
    return RunResult(per_client[0], [], 0.0, cfg.rounds, per_client_params=per_client)


def run_pate(
    model: Model,
    participants: Sequence[Participant],
    cfg: FederationConfig,
    *,
    public_x: np.ndarray,
    n_classes: int = 2,
    gnmax_sigma: float = 2.0,
) -> RunResult:
    """PATE/GNMax baseline (paper Supplementary, "Existing frameworks").

    Each hospital trains a local teacher; a student is trained on public
    data labelled by the noisy argmax of teacher votes.  The paper argues
    this class of frameworks needs (a) a public dataset and (b) MANY
    teachers to get good labels at reasonable ε — with 3-8 hospitals the
    vote margin is tiny, so utility collapses; this runner exists to make
    that argument measurable (benchmarks/pate_ablation.py).

    ε accounting: each query is a Gaussian mechanism with per-teacher
    sensitivity 1 → RDP(α) = α/(2 σ²) per query, composed over queries
    (data-independent bound; the tighter data-dependent PATE analysis only
    helps with large teacher ensembles).
    """
    import math as _math

    from repro.core.accountant import DEFAULT_ORDERS, rdp_to_eps_delta

    # 1) local teachers (silo-only training)
    teachers = run_local(model, participants, cfg).per_client_params
    h = len(teachers)

    # 2) noisy-vote labelling of the public pool
    rng = np.random.default_rng(cfg.seed)
    votes = np.zeros((len(public_x), n_classes), np.float64)
    for t in teachers:
        pred = np.asarray(model.predict_fn(t, jnp.asarray(public_x)))
        if pred.ndim == 1:  # binary score -> two-column votes
            cls = (pred > 0.5).astype(int)
        else:
            cls = pred.argmax(-1)
        votes[np.arange(len(public_x)), cls] += 1.0
    noisy = votes + rng.normal(0, gnmax_sigma, votes.shape)
    labels = noisy.argmax(-1).astype(np.float32 if n_classes == 2 else np.int32)

    # 3) privacy: Q Gaussian queries composed in RDP
    orders = np.asarray(DEFAULT_ORDERS)
    rdp = len(public_x) * orders / (2.0 * gnmax_sigma**2)
    eps, _ = rdp_to_eps_delta(rdp, orders, cfg.dp.delta)

    # 4) student trained on the noisy labels (plain SGD; labels are public)
    student = Participant(public_x.astype(np.float32), labels)
    res = run_local(model, [student], cfg)
    return RunResult(res.per_client_params[0], [], float(eps), cfg.rounds)


RUNNERS = {
    "decaph": run_decaph,
    "fl": run_fl,
    "primia": run_primia,
    "local": run_local,
    "pate": run_pate,
}
