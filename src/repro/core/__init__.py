"""Core DeCaPH: distributed DP-SGD with secure aggregation and leader rotation."""

from repro.core.accountant import RDPAccountant, compute_epsilon, compute_rdp_sgm
from repro.core.dp import (
    DPConfig,
    clip_factor,
    dp_aggregate_gradients,
    global_l2_norm,
    noise_share,
    per_example_clipped_grad_sum,
    tree_add_noise,
)
from repro.core.leader import leader_schedule
from repro.core.secagg import SecAggConfig, SecAggSession

__all__ = [
    "RDPAccountant",
    "compute_epsilon",
    "compute_rdp_sgm",
    "DPConfig",
    "clip_factor",
    "dp_aggregate_gradients",
    "global_l2_norm",
    "noise_share",
    "per_example_clipped_grad_sum",
    "tree_add_noise",
    "leader_schedule",
    "SecAggConfig",
    "SecAggSession",
]
