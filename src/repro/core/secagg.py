"""Secure Aggregation (Bonawitz et al. 2017 style) in fixed-point arithmetic.

DeCaPH uses SecAgg in three places (paper Methods): (1) global feature
mean/variance at preparation, (2) aggregate mini-batch size per round,
(3) the gradient aggregation itself.  We implement the honest-but-curious
variant faithfully:

  * values are quantised to a finite field Z_{2^32} (fixed point, ``frac_bits``
    fractional bits),
  * every ordered pair (i < j) of participants derives a shared one-time pad
    from a pairwise PRG seed (``jax.random.fold_in`` stands in for the DH key
    agreement — both are PRF expansions of a shared secret),
  * participant i uploads  x_i + sum_{j>i} PRG(s_ij) - sum_{j<i} PRG(s_ji)
    (mod 2^32); masks cancel *exactly* in the field sum, so the aggregator
    only ever learns the total.

No dropout-recovery (Shamir shares) is implemented: the paper's threat model
assumes hospitals follow the protocol and stay online; this is recorded in
DESIGN.md.  Exactness (mask cancellation) is property-tested in
``tests/test_secagg.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_FIELD_DTYPE = np.uint32
_FIELD_BITS = 32

# Field arithmetic runs host-side in NumPy: the protocol is a host/network
# concern (uploads are ciphertexts, not device tensors) and NumPy gives exact
# 64->32-bit modular arithmetic regardless of jax_enable_x64.


@dataclasses.dataclass(frozen=True)
class SecAggConfig:
    n_participants: int
    frac_bits: int = 16  # fixed-point fractional bits
    seed: int = 0

    @property
    def scale(self) -> float:
        return float(1 << self.frac_bits)


def _encode(x, cfg: SecAggConfig) -> np.ndarray:
    """float -> field element (two's-complement embedding into uint32)."""
    q = np.round(np.asarray(x, np.float64) * cfg.scale).astype(np.int64)
    return (q % (1 << _FIELD_BITS)).astype(_FIELD_DTYPE)


def _decode(v: np.ndarray, cfg: SecAggConfig) -> np.ndarray:
    """field element -> float (centered: values >= 2^31 are negative)."""
    v = v.astype(np.int64)
    v = np.where(v >= (1 << (_FIELD_BITS - 1)), v - (1 << _FIELD_BITS), v)
    return (v.astype(np.float64) / cfg.scale).astype(np.float32)


def _pair_key(base: jax.Array, i: int, j: int) -> jax.Array:
    """Shared PRG seed for the (unordered) pair {i, j}; i < j canonical."""
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(base, lo), hi)


def _prg_mask(key: jax.Array, shape: tuple[int, ...]) -> np.ndarray:
    """Uniform field elements from the pairwise seed."""
    return np.asarray(jax.random.bits(key, shape, dtype=jnp.uint32))


class SecAggSession:
    """One aggregation round over a fixed pytree template."""

    def __init__(self, cfg: SecAggConfig, template: PyTree):
        self.cfg = cfg
        self.template = template
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._base_key = jax.random.key(cfg.seed)

    def mask_for(self, i: int) -> list[np.ndarray]:
        """Net mask participant i applies (sums to zero over participants)."""
        masks = []
        for li, leaf in enumerate(self._leaves):
            key_leaf = jax.random.fold_in(self._base_key, 1000 + li)
            shape = tuple(np.shape(leaf))
            m = np.zeros(shape, _FIELD_DTYPE)
            with np.errstate(over="ignore"):  # modular field arithmetic
                for j in range(self.cfg.n_participants):
                    if j == i:
                        continue
                    pk = _pair_key(key_leaf, i, j)
                    pad = _prg_mask(pk, shape)
                    # i adds the pad if i < j, subtracts if i > j: cancels in sum.
                    m = (m + pad) if i < j else (m - pad)
            masks.append(m)
        return masks

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        """Masked ciphertext participant i sends to the leader."""
        leaves = jax.tree_util.tree_leaves(values)
        if len(leaves) != len(self._leaves):
            raise ValueError("pytree structure mismatch")
        masks = self.mask_for(i)
        with np.errstate(over="ignore"):  # modular wraparound is the protocol
            return [_encode(x, self.cfg) + m for x, m in zip(leaves, masks)]

    def aggregate(self, uploads: Sequence[list[np.ndarray]]) -> PyTree:
        """Leader-side sum of ciphertexts; masks cancel exactly in Z_2^32."""
        if len(uploads) != self.cfg.n_participants:
            raise ValueError(
                "honest-but-curious SecAgg requires all participants "
                f"({len(uploads)} of {self.cfg.n_participants} uploads)"
            )
        total = [np.zeros(np.shape(x), _FIELD_DTYPE) for x in self._leaves]
        with np.errstate(over="ignore"):  # modular wraparound is the protocol
            for up in uploads:
                total = [t + u for t, u in zip(total, up)]
        decoded = [jnp.asarray(_decode(t, self.cfg)) for t in total]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def secure_sum(values: Sequence[PyTree], cfg: SecAggConfig) -> PyTree:
    """Convenience: full round (upload + aggregate) over a list of pytrees."""
    session = SecAggSession(cfg, values[0])
    uploads = [session.upload(i, v) for i, v in enumerate(values)]
    return session.aggregate(uploads)


def secagg_message_bytes(n_params: int, n_participants: int,
                         frac_bits: int = 16) -> dict[str, float]:
    """Communication-cost model for Supp. Table 1 (bytes per round).

    Per participant: one masked vector (4 B/elem in Z_2^32) plus the pairwise
    seed exchange (32 B per peer).  The aggregator receives all uploads.
    """
    per_participant = 4.0 * n_params + 32.0 * (n_participants - 1)
    aggregator = per_participant * n_participants
    plain = 4.0 * n_params
    return {
        "per_participant_bytes": per_participant,
        "aggregator_bytes": aggregator,
        "plain_per_participant_bytes": plain,
        "plain_aggregator_bytes": plain * n_participants,
    }
