"""Secure Aggregation (Bonawitz et al. 2017 style) in fixed-point arithmetic.

DeCaPH uses SecAgg in three places (paper Methods): (1) global feature
mean/variance at preparation, (2) aggregate mini-batch size per round,
(3) the gradient aggregation itself.  We implement the honest-but-curious
variant faithfully:

  * values are quantised to a finite field Z_{2^32} (fixed point, ``frac_bits``
    fractional bits),
  * every ordered pair (i < j) of participants derives a shared one-time pad
    from a pairwise PRG seed (``jax.random.fold_in`` stands in for the DH key
    agreement — both are PRF expansions of a shared secret),
  * participant i uploads  x_i + sum_{j>i} PRG(s_ij) - sum_{j<i} PRG(s_ji)
    (mod 2^32); masks cancel *exactly* in the field sum, so the aggregator
    only ever learns the total.

Two session flavours:

  * ``SecAggSession`` — the paper's honest-but-curious variant: hospitals
    follow the protocol and stay online, so every upload must arrive
    (``aggregate`` fails loudly otherwise — a missing upload would leave
    un-cancelled masks and a silently corrupt sum).
  * ``DropoutRobustSession`` — Bonawitz-style dropout recovery: every
    participant derives its pairwise pads from a real Diffie-Hellman
    exchange (toy 61-bit group standing in for X25519) and Shamir
    secret-shares its DH secret among the cohort at setup.  When a
    participant drops before uploading, any ``threshold`` survivors can
    reveal their shares, the facilitator reconstructs the dropped secret,
    regenerates the survivor-side pads involving the dropped party, and
    cancels them — the sum of the *surviving* uploads is recovered exactly.
    ``repro.sim`` injects dropouts against this path.

Exactness (mask cancellation) is property-tested in ``tests/test_secagg.py``;
dropout recovery in ``tests/test_secagg_dropout.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_FIELD_DTYPE = np.uint32
_FIELD_BITS = 32

# Field arithmetic runs host-side in NumPy: the protocol is a host/network
# concern (uploads are ciphertexts, not device tensors) and NumPy gives exact
# 64->32-bit modular arithmetic regardless of jax_enable_x64.


@dataclasses.dataclass(frozen=True)
class SecAggConfig:
    n_participants: int
    frac_bits: int = 16  # fixed-point fractional bits
    seed: int = 0

    @property
    def scale(self) -> float:
        return float(1 << self.frac_bits)


def _encode(x, cfg: SecAggConfig) -> np.ndarray:
    """float -> field element (two's-complement embedding into uint32)."""
    q = np.round(np.asarray(x, np.float64) * cfg.scale).astype(np.int64)
    return (q % (1 << _FIELD_BITS)).astype(_FIELD_DTYPE)


def _decode(v: np.ndarray, cfg: SecAggConfig) -> np.ndarray:
    """field element -> float (centered: values >= 2^31 are negative)."""
    v = v.astype(np.int64)
    v = np.where(v >= (1 << (_FIELD_BITS - 1)), v - (1 << _FIELD_BITS), v)
    return (v.astype(np.float64) / cfg.scale).astype(np.float32)


def _pair_key(base: jax.Array, i: int, j: int) -> jax.Array:
    """Shared PRG seed for the (unordered) pair {i, j}; i < j canonical."""
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(base, lo), hi)


def _prg_mask(key: jax.Array, shape: tuple[int, ...]) -> np.ndarray:
    """Uniform field elements from the pairwise seed."""
    return np.asarray(jax.random.bits(key, shape, dtype=jnp.uint32))


class SecAggSession:
    """One aggregation round over a fixed pytree template."""

    def __init__(self, cfg: SecAggConfig, template: PyTree):
        self.cfg = cfg
        self.template = template
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._base_key = jax.random.key(cfg.seed)

    def mask_for(self, i: int) -> list[np.ndarray]:
        """Net mask participant i applies (sums to zero over participants)."""
        masks = []
        for li, leaf in enumerate(self._leaves):
            key_leaf = jax.random.fold_in(self._base_key, 1000 + li)
            shape = tuple(np.shape(leaf))
            m = np.zeros(shape, _FIELD_DTYPE)
            with np.errstate(over="ignore"):  # modular field arithmetic
                for j in range(self.cfg.n_participants):
                    if j == i:
                        continue
                    pk = _pair_key(key_leaf, i, j)
                    pad = _prg_mask(pk, shape)
                    # i adds the pad if i < j, subtracts if i > j: cancels in sum.
                    m = (m + pad) if i < j else (m - pad)
            masks.append(m)
        return masks

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        """Masked ciphertext participant i sends to the leader."""
        leaves = jax.tree_util.tree_leaves(values)
        if len(leaves) != len(self._leaves):
            raise ValueError("pytree structure mismatch")
        masks = self.mask_for(i)
        with np.errstate(over="ignore"):  # modular wraparound is the protocol
            return [_encode(x, self.cfg) + m for x, m in zip(leaves, masks)]

    def aggregate(self, uploads: Sequence[list[np.ndarray]]) -> PyTree:
        """Leader-side sum of ciphertexts; masks cancel exactly in Z_2^32."""
        if len(uploads) != self.cfg.n_participants:
            raise ValueError(
                "honest-but-curious SecAgg requires all participants "
                f"({len(uploads)} of {self.cfg.n_participants} uploads); a "
                "missing upload leaves un-cancelled masks in the sum — use "
                "DropoutRobustSession if participants may drop out"
            )
        _check_uploads(uploads, self._leaves)
        total = [np.zeros(np.shape(x), _FIELD_DTYPE) for x in self._leaves]
        with np.errstate(over="ignore"):  # modular wraparound is the protocol
            for up in uploads:
                total = [t + u for t, u in zip(total, up)]
        decoded = [jnp.asarray(_decode(t, self.cfg)) for t in total]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def _check_uploads(
    uploads: Sequence[list[np.ndarray]], leaves: Sequence[Any]
) -> None:
    """Fail loudly on short/misshapen ciphertexts (silent-garbage guard)."""
    for k, up in enumerate(uploads):
        if len(up) != len(leaves):
            raise ValueError(
                f"upload {k} has {len(up)} leaves, template has "
                f"{len(leaves)} — truncated or mis-structured ciphertext"
            )
        for li, (u, leaf) in enumerate(zip(up, leaves)):
            if tuple(np.shape(u)) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"upload {k} leaf {li} shape {np.shape(u)} != template "
                    f"shape {np.shape(leaf)}"
                )


def secure_sum(values: Sequence[PyTree], cfg: SecAggConfig) -> PyTree:
    """Convenience: full round (upload + aggregate) over a list of pytrees."""
    values = list(values)
    if not values:
        raise ValueError("secure_sum: empty value list")
    if len(values) != cfg.n_participants:
        raise ValueError(
            f"secure_sum: {len(values)} value trees for "
            f"{cfg.n_participants} participants — every participant must "
            "contribute (dropouts need DropoutRobustSession)"
        )
    session = SecAggSession(cfg, values[0])
    uploads = [session.upload(i, v) for i, v in enumerate(values)]
    return session.aggregate(uploads)


# --------------------------------------------------------------------------
# Dropout-robust SecAgg: DH pairwise seeds + Shamir recovery (Bonawitz §4).
# --------------------------------------------------------------------------

# 2^61 - 1 (Mersenne prime).  One field for both the Shamir shares and the
# toy Diffie-Hellman group: large enough that pad seeds are unguessable in
# simulation, small enough that Python-int modexp stays negligible next to
# the gradient math.  A deployment would swap in X25519; the *protocol*
# (what is shared, who reveals what, when) is what we reproduce faithfully.
_SHAMIR_PRIME = (1 << 61) - 1
_DH_GENERATOR = 3


def shamir_share(
    secret: int, n_shares: int, threshold: int, rng: np.random.Generator
) -> list[tuple[int, int]]:
    """Split ``secret`` into n points of a degree-(threshold-1) polynomial."""
    if not 0 <= secret < _SHAMIR_PRIME:
        raise ValueError("secret out of field range")
    if not 1 <= threshold <= n_shares:
        raise ValueError("need 1 <= threshold <= n_shares")
    coeffs = [secret] + [
        int(rng.integers(0, _SHAMIR_PRIME)) for _ in range(threshold - 1)
    ]
    shares = []
    for x in range(1, n_shares + 1):
        y = 0
        for c in reversed(coeffs):  # Horner
            y = (y * x + c) % _SHAMIR_PRIME
        shares.append((x, y))
    return shares


def shamir_reconstruct(shares: Sequence[tuple[int, int]]) -> int:
    """Lagrange-interpolate the polynomial at 0 from >= threshold shares."""
    if not shares:
        raise ValueError("no shares to reconstruct from")
    if len({x for x, _ in shares}) != len(shares):
        raise ValueError("duplicate share indices")
    p = _SHAMIR_PRIME
    secret = 0
    for i, (xi, yi) in enumerate(shares):
        num, den = 1, 1
        for j, (xj, _) in enumerate(shares):
            if i == j:
                continue
            num = num * (-xj) % p
            den = den * (xi - xj) % p
        secret = (secret + yi * num * pow(den, p - 2, p)) % p
    return secret


class DropoutRobustSession:
    """SecAgg round that survives participants dropping before upload.

    Setup (simulated in-process; each step is one real protocol message):
      1. *advertise*: every participant i draws a DH secret u_i and
         publishes g^{u_i}.  The pairwise pad seed is the DH agreement
         s_ij = g^{u_i u_j} — unlike ``SecAggSession``'s shared base key,
         neither the facilitator nor any third party can derive it.
      2. *share keys*: i Shamir-shares u_i among all participants with a
         reconstruction ``threshold`` t (honest-majority default).

    On dropout of d (no upload received): any t survivors reveal their
    shares of u_d, the facilitator reconstructs u_d, recomputes the pads
    s_dj for every survivor j, and cancels them from the ciphertext sum.
    The result equals the plain sum of the *survivors'* values.

    Simplification vs. full Bonawitz: no self-masks (double masking), so a
    participant declared dropped *after* its upload was received would have
    its value exposed by unmasking.  We therefore never unmask received
    uploads — late-dropping participants simply stay in the sum (their
    contribution already arrived), matching the simulator's semantics.
    """

    def __init__(
        self,
        cfg: SecAggConfig,
        template: PyTree,
        *,
        threshold: int | None = None,
    ):
        n = cfg.n_participants
        if n < 2:
            raise ValueError("need at least 2 participants")
        self.cfg = cfg
        self.threshold = threshold if threshold is not None else n // 2 + 1
        if not 2 <= self.threshold <= n:
            raise ValueError(f"threshold {self.threshold} not in [2, {n}]")
        self.template = template
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        # Each participant's local randomness (one stream per party would be
        # the deployment picture; a single seeded stream keeps tests exact).
        rng = np.random.default_rng(np.uint64(cfg.seed) ^ np.uint64(0x5ECA66))
        self._secret_keys = [
            int(rng.integers(2, _SHAMIR_PRIME - 1)) for _ in range(n)
        ]
        self.public_keys = [
            pow(_DH_GENERATOR, u, _SHAMIR_PRIME) for u in self._secret_keys
        ]
        # shares[i][j] = participant j's share of u_i (index x = j + 1)
        self._shares = [
            shamir_share(u, n, self.threshold, rng) for u in self._secret_keys
        ]

    # -- pads ---------------------------------------------------------------

    def _pair_seed(self, holder: int, other: int) -> int:
        """DH agreement: pow(pk_other, u_holder) == g^(u_i u_j), symmetric."""
        return pow(
            self.public_keys[other], self._secret_keys[holder], _SHAMIR_PRIME
        )

    @staticmethod
    def _pad_from_seed(
        seed: int, leaf_index: int, shape: tuple[int, ...]
    ) -> np.ndarray:
        key = jax.random.fold_in(
            jax.random.key(seed % ((1 << 63) - 1)), leaf_index
        )
        return _prg_mask(key, shape)

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        """Masked ciphertext from participant i (pads vs. every peer)."""
        leaves = jax.tree_util.tree_leaves(values)
        if len(leaves) != len(self._leaves):
            raise ValueError("pytree structure mismatch")
        out = []
        with np.errstate(over="ignore"):  # modular field arithmetic
            for li, leaf in enumerate(leaves):
                shape = tuple(np.shape(self._leaves[li]))
                if tuple(np.shape(leaf)) != shape:
                    raise ValueError(
                        f"leaf {li} shape {np.shape(leaf)} != {shape}"
                    )
                v = _encode(leaf, self.cfg)
                for j in range(self.cfg.n_participants):
                    if j == i:
                        continue
                    pad = self._pad_from_seed(self._pair_seed(i, j), li, shape)
                    v = (v + pad) if i < j else (v - pad)
                out.append(v)
        return out

    # -- recovery -----------------------------------------------------------

    def recovery_shares(
        self, dropped: int, survivors: Sequence[int]
    ) -> list[tuple[int, int]]:
        """Shares of u_dropped that the survivors reveal to the facilitator."""
        return [self._shares[dropped][j] for j in survivors]

    def aggregate(
        self, uploads: dict[int, list[np.ndarray]]
    ) -> PyTree:
        """Sum received ciphertexts; reconstruct + cancel dropped pads.

        ``uploads`` maps participant index -> ciphertext.  Participants
        absent from the dict are treated as dropped and recovered via
        Shamir.  Raises if fewer than ``threshold`` uploads survive.
        """
        n = self.cfg.n_participants
        survivors = sorted(uploads)
        if any(not 0 <= s < n for s in survivors):
            raise ValueError("upload index out of range")
        dropped = [d for d in range(n) if d not in uploads]
        if len(survivors) < self.threshold:
            raise ValueError(
                f"only {len(survivors)} uploads for threshold "
                f"{self.threshold}: cannot reconstruct dropped masks"
            )
        _check_uploads([uploads[s] for s in survivors], self._leaves)
        total = [np.zeros(np.shape(x), _FIELD_DTYPE) for x in self._leaves]
        with np.errstate(over="ignore"):
            for s in survivors:
                total = [t + u for t, u in zip(total, uploads[s])]
            for d in dropped:
                # Any `threshold` survivors' shares reconstruct u_d exactly.
                shares = self.recovery_shares(d, survivors[: self.threshold])
                u_d = shamir_reconstruct(shares)
                for j in survivors:
                    seed = pow(self.public_keys[j], u_d, _SHAMIR_PRIME)
                    for li in range(len(total)):
                        pad = self._pad_from_seed(
                            seed, li, tuple(np.shape(self._leaves[li]))
                        )
                        # Survivor j applied +pad if j < d else -pad; remove.
                        total[li] = (
                            total[li] - pad if j < d else total[li] + pad
                        )
        decoded = [jnp.asarray(_decode(t, self.cfg)) for t in total]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def secure_sum_with_dropouts(
    values: Sequence[PyTree | None],
    cfg: SecAggConfig,
    *,
    threshold: int | None = None,
) -> PyTree:
    """Full dropout-robust round; ``None`` entries are dropped participants."""
    values = list(values)
    if len(values) != cfg.n_participants:
        raise ValueError(
            f"{len(values)} slots for {cfg.n_participants} participants"
        )
    template = next((v for v in values if v is not None), None)
    if template is None:
        raise ValueError("every participant dropped; nothing to aggregate")
    session = DropoutRobustSession(cfg, template, threshold=threshold)
    uploads = {
        i: session.upload(i, v) for i, v in enumerate(values) if v is not None
    }
    return session.aggregate(uploads)


def secagg_recovery_bytes(
    n_participants: int, n_dropped: int = 0
) -> dict[str, float]:
    """Wire-cost model for the dropout-robust extension.

    Setup: each participant broadcasts an 8 B public key and sends one 16 B
    Shamir share (8 B y + index) to each peer.  Recovery: each survivor
    reveals one share per dropped participant to the facilitator.
    """
    n, d = n_participants, n_dropped
    setup = n * 8.0 + n * (n - 1) * 16.0
    recovery = (n - d) * d * 16.0
    return {"setup_bytes": setup, "recovery_bytes": recovery}


def secagg_message_bytes(n_params: int, n_participants: int,
                         frac_bits: int = 16) -> dict[str, float]:
    """Communication-cost model for Supp. Table 1 (bytes per round).

    Per participant: one masked vector (4 B/elem in Z_2^32) plus the pairwise
    seed exchange (32 B per peer).  The aggregator receives all uploads.
    """
    per_participant = 4.0 * n_params + 32.0 * (n_participants - 1)
    aggregator = per_participant * n_participants
    plain = 4.0 * n_params
    return {
        "per_participant_bytes": per_participant,
        "aggregator_bytes": aggregator,
        "plain_per_participant_bytes": plain,
        "plain_aggregator_bytes": plain * n_participants,
    }
