"""Secure Aggregation (Bonawitz et al. 2017 style) in fixed-point arithmetic.

DeCaPH uses SecAgg in three places (paper Methods): (1) global feature
mean/variance at preparation, (2) aggregate mini-batch size per round,
(3) the gradient aggregation itself.  We implement the honest-but-curious
variant faithfully:

  * values are quantised to a finite field Z_{2^32} (fixed point, ``frac_bits``
    fractional bits),
  * every ordered pair (i < j) of participants derives a shared one-time pad
    from a pairwise PRG seed (``jax.random.fold_in`` stands in for the DH key
    agreement — both are PRF expansions of a shared secret),
  * participant i uploads  x_i + sum_{j>i} PRG(s_ij) - sum_{j<i} PRG(s_ji)
    (mod 2^32); masks cancel *exactly* in the field sum, so the aggregator
    only ever learns the total.

Two session flavours:

  * ``SecAggSession`` — the paper's honest-but-curious variant: hospitals
    follow the protocol and stay online, so every upload must arrive
    (``aggregate`` fails loudly otherwise — a missing upload would leave
    un-cancelled masks and a silently corrupt sum).
  * ``DropoutRobustSession`` — Bonawitz-style dropout recovery: every
    participant derives its pairwise pads from a real Diffie-Hellman
    exchange (toy 61-bit group standing in for X25519) and Shamir
    secret-shares its DH secret among the cohort at setup.  When a
    participant drops before uploading, any ``threshold`` survivors can
    reveal their shares, the facilitator reconstructs the dropped secret,
    regenerates the survivor-side pads involving the dropped party, and
    cancels them — the sum of the *surviving* uploads is recovered exactly.
    ``repro.sim`` injects dropouts against this path.

Exactness (mask cancellation) is property-tested in ``tests/test_secagg.py``;
dropout recovery in ``tests/test_secagg_dropout.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_FIELD_DTYPE = np.uint32
_FIELD_BITS = 32

# Field arithmetic runs host-side in NumPy: the protocol is a host/network
# concern (uploads are ciphertexts, not device tensors) and NumPy gives exact
# 64->32-bit modular arithmetic regardless of jax_enable_x64.


# Pairs per chunk of the in-jit mask accumulation: bounds resident pad
# memory at ``pad_chunk_pairs * L * 4`` bytes instead of the full
# O(H^2 * L) pad matrix (~5 GB at H=50 on a 1M-param model).
_DEFAULT_PAD_CHUNK = 1024


@dataclasses.dataclass(frozen=True)
class SecAggConfig:
    n_participants: int
    frac_bits: int = 16  # fixed-point fractional bits
    seed: int = 0
    pad_chunk_pairs: int = _DEFAULT_PAD_CHUNK  # memory knob, never numerics

    @property
    def scale(self) -> float:
        return float(1 << self.frac_bits)


def _encode(x, cfg: SecAggConfig) -> np.ndarray:
    """float -> field element (two's-complement embedding into uint32)."""
    q = np.round(np.asarray(x, np.float64) * cfg.scale).astype(np.int64)
    return (q % (1 << _FIELD_BITS)).astype(_FIELD_DTYPE)


def _decode(v: np.ndarray, cfg: SecAggConfig) -> np.ndarray:
    """field element -> float (centered: values >= 2^31 are negative)."""
    v = v.astype(np.int64)
    v = np.where(v >= (1 << (_FIELD_BITS - 1)), v - (1 << _FIELD_BITS), v)
    return (v.astype(np.float64) / cfg.scale).astype(np.float32)


# -- vectorized, chunked pair-pad machinery (DESIGN.md §7) -------------------
#
# Mask generation is the round's O(H^2 * leaves) hot spot when done naively:
# every (participant, peer, leaf) triple used to be its own fold_in + PRG
# dispatch, and each unordered pair's pad was generated twice (once with
# ``+`` by the lower index, once with ``-`` by the higher).  The vectorized
# path generates the pad of every unordered pair {lo, hi} exactly ONCE per
# round, and — rather than materialising the O(H^2 * L) pad matrix (~5 GB at
# H=50 on a 1M-param model) — accumulates the signed net-mask rows in-jit
# over chunks of ``pad_chunk_pairs`` pairs: each chunk's pads are generated
# by one batched PRG call, scatter-added with the sign convention (lo adds,
# hi subtracts — every pad appears exactly once with each sign and cancels
# in the field sum), and freed before the next chunk.  Field addition in
# Z_2^32 is exactly associative/commutative, so chunking changes no bit;
# the legacy per-leaf loop survives as a reference implementation in
# ``tests/_legacy_secagg.py`` and aggregates stay bit-identical to it.


def _pairs(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Index arrays (los, his) over the n*(n-1)/2 unordered pairs, lo < hi."""
    lo, hi = np.triu_indices(n, k=1)
    return lo.astype(np.uint32), hi.astype(np.uint32)


@partial(jax.jit, static_argnums=(4, 5))
def _pair_mask_scan(
    base_key: jax.Array, los: jax.Array, his: jax.Array, valid: jax.Array,
    n: int, length: int,
) -> jax.Array:
    """(n, L) signed net masks from (n_chunks, C) pair-index chunks."""

    def body(masks, inp):
        lo_c, hi_c, v_c = inp

        def one(lo, hi):
            k = jax.random.fold_in(jax.random.fold_in(base_key, lo), hi)
            return jax.random.bits(k, (length,), dtype=jnp.uint32)

        pads = jax.vmap(one)(lo_c, hi_c) * v_c[:, None]  # pad rows -> 0
        masks = masks.at[lo_c].add(pads)
        masks = masks.at[hi_c].add(-pads)  # uint32: exact two's complement
        return masks, None

    masks0 = jnp.zeros((n, length), jnp.uint32)
    masks, _ = jax.lax.scan(body, masks0, (los, his, valid))
    return masks


_SEED_PAD_KEY = jax.random.key(0x5ECA66)


def _seed_pad_row(hi, lo, length: int):
    """One pad row from a DH agreement split into 32-bit words.

    This is THE derivation: the masking scan and the dropout-recovery path
    must produce bit-identical pads from the same seed words, or survivors'
    regenerated pads no longer cancel a dropped party's masks — so both go
    through this one function.
    """
    k = jax.random.fold_in(jax.random.fold_in(_SEED_PAD_KEY, hi), lo)
    return jax.random.bits(k, (length,), dtype=jnp.uint32)


@partial(jax.jit, static_argnums=(5, 6))
def _seed_mask_scan(
    hi_words: jax.Array, lo_words: jax.Array,
    los: jax.Array, his: jax.Array, valid: jax.Array,
    n: int, length: int,
) -> jax.Array:
    """(n, L) signed net masks from chunked DH-seed words (the seed, not
    the pair indices, keys the PRG — so a pad can be regenerated from a
    Shamir-reconstructed secret during recovery)."""

    def body(masks, inp):
        hw_c, lw_c, lo_c, hi_c, v_c = inp
        pads = jax.vmap(
            lambda hi, lo: _seed_pad_row(hi, lo, length)
        )(hw_c, lw_c) * v_c[:, None]
        masks = masks.at[lo_c].add(pads)
        masks = masks.at[hi_c].add(-pads)
        return masks, None

    masks0 = jnp.zeros((n, length), jnp.uint32)
    masks, _ = jax.lax.scan(
        body, masks0, (hi_words, lo_words, los, his, valid)
    )
    return masks


def _chunked(arrs: Sequence[np.ndarray], chunk: int) -> list[np.ndarray]:
    """Zero-pad each 1-D array to a chunk multiple and reshape to chunks,
    plus a trailing validity row-mask for the padding."""
    n_items = len(arrs[0])
    c = max(1, min(int(chunk), n_items))
    n_chunks = -(-n_items // c)
    pad = n_chunks * c - n_items

    def shape(a):
        return np.concatenate(
            [a, np.zeros((pad,), a.dtype)]
        ).reshape(n_chunks, c)

    valid = shape(np.ones((n_items,), np.uint32))
    return [shape(a) for a in arrs] + [valid]


def _signed_masks(
    n: int,
    length: int,
    los: np.ndarray,
    his: np.ndarray,
    *,
    chunk: int = _DEFAULT_PAD_CHUNK,
    base_key: jax.Array | None = None,
    seeds: Sequence[int] | None = None,
) -> np.ndarray:
    """(n, L) net masks: row i = sum_{i=lo} pad - sum_{i=hi} pad (mod 2^32),
    accumulated in-jit over ``chunk``-pair slices; exactly one of
    ``base_key`` (pair-index keyed pads) / ``seeds`` (DH-agreement keyed
    pads) selects the PRG family."""
    if len(los) == 0:
        return np.zeros((n, length), _FIELD_DTYPE)
    if seeds is not None:
        hi_w, lo_w = _seed_words(seeds)
        hw, lw, lo_c, hi_c, valid = _chunked([hi_w, lo_w, los, his], chunk)
        out = _seed_mask_scan(hw, lw, lo_c, hi_c, valid, n, length)
    else:
        lo_c, hi_c, valid = _chunked([los, his], chunk)
        out = _pair_mask_scan(base_key, lo_c, hi_c, valid, n, length)
    return np.asarray(out)


def _seed_words(seeds: Sequence[int]) -> tuple[np.ndarray, np.ndarray]:
    arr = [int(s) for s in seeds]
    hi = np.asarray([s >> 32 for s in arr], np.uint32)
    lo = np.asarray([s & 0xFFFFFFFF for s in arr], np.uint32)
    return hi, lo


@partial(jax.jit, static_argnums=(2,))
def _batched_seed_pads(
    hi_words: jax.Array, lo_words: jax.Array, length: int
) -> jax.Array:
    """(n_seeds, length) pads from DH-seed words — the *recovery* path,
    where the handful of survivor-side pads of a dropped party really is
    needed as a matrix (to cancel them from the ciphertext sum).  Same
    ``_seed_pad_row`` derivation as the masking scan, by construction."""
    return jax.vmap(
        lambda hi, lo: _seed_pad_row(hi, lo, length)
    )(hi_words, lo_words)


def _flatten_encoded(
    leaves: Sequence[Any], template: Sequence[Any], cfg: SecAggConfig
) -> np.ndarray:
    """Encode every leaf and concatenate into one flat field vector."""
    out = []
    for li, (x, tmpl) in enumerate(zip(leaves, template)):
        shape = tuple(np.shape(tmpl))
        if tuple(np.shape(x)) != shape:
            raise ValueError(f"leaf {li} shape {np.shape(x)} != {shape}")
        out.append(_encode(x, cfg).ravel())
    return np.concatenate(out) if out else np.zeros((0,), _FIELD_DTYPE)


def _encode_cohort(
    trees: Sequence[PyTree], template: Sequence[Any], cfg: SecAggConfig
) -> np.ndarray:
    """(n, L) encoded field matrix for a whole cohort of payload trees.

    ONE ``jax.device_get`` moves every participant's (possibly
    device-resident) payload leaves to the host together, instead of the
    per-silo implicit transfers the per-upload ``np.asarray`` path paid;
    the fixed-point encode is elementwise, so batching changes no bit.
    """
    cohort_leaves = [jax.tree_util.tree_leaves(v) for v in trees]
    for leaves in cohort_leaves:
        if len(leaves) != len(template):
            raise ValueError("pytree structure mismatch")
    cohort_leaves = jax.device_get(cohort_leaves)
    return np.stack([
        _flatten_encoded(leaves, template, cfg) for leaves in cohort_leaves
    ]) if cohort_leaves else np.zeros((0, 0), _FIELD_DTYPE)


def _split_flat(flat: np.ndarray, template: Sequence[Any]) -> list[np.ndarray]:
    """Inverse of ``_flatten_encoded``: flat vector -> per-leaf arrays."""
    out, off = [], 0
    for leaf in template:
        shape = tuple(np.shape(leaf))
        # np.prod(()) == 1, so scalars count 1 and empty leaves count 0 —
        # matching exactly what _flatten_encoded ravels
        size = int(np.prod(shape))
        out.append(flat[off:off + size].reshape(shape))
        off += size
    return out


def _stack_ciphertexts(
    uploads: Sequence[list[np.ndarray]],
) -> np.ndarray:
    """(n_uploads, L) field matrix from per-leaf ciphertext lists."""
    return np.stack([
        np.concatenate([np.asarray(u).ravel() for u in up])
        for up in uploads
    ])


def _masked_cohort_uploads(
    session, values: Mapping[int, PyTree]
) -> dict[int, list[np.ndarray]]:
    """Shared ``upload_all`` body: one batched host transfer for the whole
    cohort's payloads + one vectorized masking pass.  Bit-identical to
    per-participant ``upload`` calls (encode is elementwise, masks are the
    same rows)."""
    if not values:
        return {}
    order = sorted(values)
    enc = _encode_cohort(
        [values[i] for i in order], session._leaves, session.cfg
    )
    with np.errstate(over="ignore"):  # modular field arithmetic
        enc = enc + session._flat_masks()[np.asarray(order, np.intp)]
    return {
        i: _split_flat(row, session._leaves) for i, row in zip(order, enc)
    }


class SecAggSession:
    """One aggregation round over a fixed pytree template."""

    def __init__(self, cfg: SecAggConfig, template: PyTree):
        self.cfg = cfg
        self.template = template
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._length = int(sum(
            np.prod(np.shape(x)) for x in self._leaves
        ))
        self._base_key = jax.random.key(cfg.seed)
        self._los, self._his = _pairs(cfg.n_participants)
        self._masks: np.ndarray | None = None  # (n, L), built lazily

    def _flat_masks(self) -> np.ndarray:
        """Every participant's net mask, accumulated over pair chunks."""
        if self._masks is None:
            self._masks = _signed_masks(
                self.cfg.n_participants, self._length,
                self._los, self._his,
                chunk=self.cfg.pad_chunk_pairs, base_key=self._base_key,
            )
        return self._masks

    def mask_for(self, i: int) -> list[np.ndarray]:
        """Net mask participant i applies (sums to zero over participants)."""
        return _split_flat(self._flat_masks()[i], self._leaves)

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        """Masked ciphertext participant i sends to the leader."""
        leaves = jax.tree_util.tree_leaves(values)
        if len(leaves) != len(self._leaves):
            raise ValueError("pytree structure mismatch")
        with np.errstate(over="ignore"):  # modular wraparound is the protocol
            flat = _flatten_encoded(leaves, self._leaves, self.cfg)
            flat = flat + self._flat_masks()[i]
        return _split_flat(flat, self._leaves)

    def upload_all(
        self, values: Mapping[int, PyTree]
    ) -> dict[int, list[np.ndarray]]:
        """Ciphertexts for a whole cohort: one host transfer, one masking
        pass (participant index -> masked ciphertext)."""
        return _masked_cohort_uploads(self, values)

    def aggregate(self, uploads: Sequence[list[np.ndarray]]) -> PyTree:
        """Leader-side sum of ciphertexts; masks cancel exactly in Z_2^32."""
        if len(uploads) != self.cfg.n_participants:
            raise ValueError(
                "honest-but-curious SecAgg requires all participants "
                f"({len(uploads)} of {self.cfg.n_participants} uploads); a "
                "missing upload leaves un-cancelled masks in the sum — use "
                "DropoutRobustSession if participants may drop out"
            )
        _check_uploads(uploads, self._leaves)
        with np.errstate(over="ignore"):  # modular wraparound is the protocol
            total = _stack_ciphertexts(uploads).sum(
                axis=0, dtype=_FIELD_DTYPE
            )
        decoded = [
            jnp.asarray(_decode(t, self.cfg))
            for t in _split_flat(total, self._leaves)
        ]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def _check_uploads(
    uploads: Sequence[list[np.ndarray]], leaves: Sequence[Any]
) -> None:
    """Fail loudly on short/misshapen ciphertexts (silent-garbage guard)."""
    for k, up in enumerate(uploads):
        if len(up) != len(leaves):
            raise ValueError(
                f"upload {k} has {len(up)} leaves, template has "
                f"{len(leaves)} — truncated or mis-structured ciphertext"
            )
        for li, (u, leaf) in enumerate(zip(up, leaves)):
            if tuple(np.shape(u)) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"upload {k} leaf {li} shape {np.shape(u)} != template "
                    f"shape {np.shape(leaf)}"
                )


def secure_sum(values: Sequence[PyTree], cfg: SecAggConfig) -> PyTree:
    """Convenience: full round (upload + aggregate) over a list of pytrees."""
    values = list(values)
    if not values:
        raise ValueError("secure_sum: empty value list")
    if len(values) != cfg.n_participants:
        raise ValueError(
            f"secure_sum: {len(values)} value trees for "
            f"{cfg.n_participants} participants — every participant must "
            "contribute (dropouts need DropoutRobustSession)"
        )
    session = SecAggSession(cfg, values[0])
    uploads = session.upload_all(dict(enumerate(values)))
    return session.aggregate([uploads[i] for i in range(len(values))])


def secure_sum_ints(values: Sequence[int], *, n_participants: int,
                    seed: int = 0) -> int:
    """Exact integer SecAgg sum — no float/fixed-point round-trip.

    Batch sizes (and any other small non-negative integer telemetry) embed
    directly into Z_2^32; the masked field sum is exact as long as the true
    total stays below 2^31 (it is validated).  This replaces the old route
    of ``frac_bits=0`` fixed-point encoding of ``float(size)``, which
    quantised through float64 for no reason.
    """
    values = [int(v) for v in values]
    if len(values) != n_participants:
        raise ValueError(
            f"secure_sum_ints: {len(values)} values for "
            f"{n_participants} participants — every participant must "
            "contribute"
        )
    if any(v < 0 for v in values):
        raise ValueError("secure_sum_ints: negative value")
    if sum(values) >= (1 << (_FIELD_BITS - 1)):
        raise ValueError("secure_sum_ints: total overflows the field")
    base_key = jax.random.key(seed)
    los, his = _pairs(n_participants)
    masks = _signed_masks(n_participants, 1, los, his,
                          base_key=base_key)[:, 0]
    with np.errstate(over="ignore"):  # modular field arithmetic
        ciphertexts = np.asarray(values, np.uint64).astype(_FIELD_DTYPE) + masks
        total = int(ciphertexts.sum(dtype=_FIELD_DTYPE))
    return total


# --------------------------------------------------------------------------
# Dropout-robust SecAgg: DH pairwise seeds + Shamir recovery (Bonawitz §4).
# --------------------------------------------------------------------------

# 2^61 - 1 (Mersenne prime).  One field for both the Shamir shares and the
# toy Diffie-Hellman group: large enough that pad seeds are unguessable in
# simulation, small enough that Python-int modexp stays negligible next to
# the gradient math.  A deployment would swap in X25519; the *protocol*
# (what is shared, who reveals what, when) is what we reproduce faithfully.
_SHAMIR_PRIME = (1 << 61) - 1
_DH_GENERATOR = 3


def shamir_share(
    secret: int, n_shares: int, threshold: int, rng: np.random.Generator
) -> list[tuple[int, int]]:
    """Split ``secret`` into n points of a degree-(threshold-1) polynomial."""
    if not 0 <= secret < _SHAMIR_PRIME:
        raise ValueError("secret out of field range")
    if not 1 <= threshold <= n_shares:
        raise ValueError("need 1 <= threshold <= n_shares")
    coeffs = [secret] + [
        int(rng.integers(0, _SHAMIR_PRIME)) for _ in range(threshold - 1)
    ]
    shares = []
    for x in range(1, n_shares + 1):
        y = 0
        for c in reversed(coeffs):  # Horner
            y = (y * x + c) % _SHAMIR_PRIME
        shares.append((x, y))
    return shares


def shamir_reconstruct(shares: Sequence[tuple[int, int]]) -> int:
    """Lagrange-interpolate the polynomial at 0 from >= threshold shares."""
    if not shares:
        raise ValueError("no shares to reconstruct from")
    if len({x for x, _ in shares}) != len(shares):
        raise ValueError("duplicate share indices")
    p = _SHAMIR_PRIME
    secret = 0
    for i, (xi, yi) in enumerate(shares):
        num, den = 1, 1
        for j, (xj, _) in enumerate(shares):
            if i == j:
                continue
            num = num * (-xj) % p
            den = den * (xi - xj) % p
        secret = (secret + yi * num * pow(den, p - 2, p)) % p
    return secret


class DropoutRobustSession:
    """SecAgg round that survives participants dropping before upload.

    Setup (simulated in-process; each step is one real protocol message):
      1. *advertise*: every participant i draws a DH secret u_i and
         publishes g^{u_i}.  The pairwise pad seed is the DH agreement
         s_ij = g^{u_i u_j} — unlike ``SecAggSession``'s shared base key,
         neither the facilitator nor any third party can derive it.
      2. *share keys*: i Shamir-shares u_i among all participants with a
         reconstruction ``threshold`` t (honest-majority default).

    On dropout of d (no upload received): any t survivors reveal their
    shares of u_d, the facilitator reconstructs u_d, recomputes the pads
    s_dj for every survivor j, and cancels them from the ciphertext sum.
    The result equals the plain sum of the *survivors'* values.

    Simplification vs. full Bonawitz: no self-masks (double masking), so a
    participant declared dropped *after* its upload was received would have
    its value exposed by unmasking.  We therefore never unmask received
    uploads — late-dropping participants simply stay in the sum (their
    contribution already arrived), matching the simulator's semantics.
    """

    def __init__(
        self,
        cfg: SecAggConfig,
        template: PyTree,
        *,
        threshold: int | None = None,
    ):
        n = cfg.n_participants
        if n < 2:
            raise ValueError("need at least 2 participants")
        self.cfg = cfg
        self.threshold = threshold if threshold is not None else n // 2 + 1
        if not 2 <= self.threshold <= n:
            raise ValueError(f"threshold {self.threshold} not in [2, {n}]")
        self.template = template
        self._leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._length = int(sum(
            np.prod(np.shape(x)) for x in self._leaves
        ))
        self._los, self._his = _pairs(n)
        self._masks: np.ndarray | None = None  # (n, L), built lazily
        # Each participant's local randomness (one stream per party would be
        # the deployment picture; a single seeded stream keeps tests exact).
        rng = np.random.default_rng(np.uint64(cfg.seed) ^ np.uint64(0x5ECA66))
        self._secret_keys = [
            int(rng.integers(2, _SHAMIR_PRIME - 1)) for _ in range(n)
        ]
        self.public_keys = [
            pow(_DH_GENERATOR, u, _SHAMIR_PRIME) for u in self._secret_keys
        ]
        # shares[i][j] = participant j's share of u_i (index x = j + 1)
        self._shares = [
            shamir_share(u, n, self.threshold, rng) for u in self._secret_keys
        ]

    # -- pads ---------------------------------------------------------------

    def _pair_seed(self, holder: int, other: int) -> int:
        """DH agreement: pow(pk_other, u_holder) == g^(u_i u_j), symmetric."""
        return pow(
            self.public_keys[other], self._secret_keys[holder], _SHAMIR_PRIME
        )

    def _pads_from_seeds(self, seeds: Sequence[int]) -> np.ndarray:
        """(len(seeds), L) pads from DH agreements, one batched PRG call."""
        if not seeds:
            return np.zeros((0, self._length), _FIELD_DTYPE)
        hi, lo = _seed_words(seeds)
        return np.asarray(_batched_seed_pads(hi, lo, self._length))

    def _flat_masks(self) -> np.ndarray:
        """Every participant's net mask; each pair's pad generated once,
        accumulated over pair chunks (never the full pad matrix)."""
        if self._masks is None:
            seeds = [
                self._pair_seed(int(lo), int(hi))
                for lo, hi in zip(self._los, self._his)
            ]
            self._masks = _signed_masks(
                self.cfg.n_participants, self._length,
                self._los, self._his,
                chunk=self.cfg.pad_chunk_pairs, seeds=seeds,
            )
        return self._masks

    def upload(self, i: int, values: PyTree) -> list[np.ndarray]:
        """Masked ciphertext from participant i (pads vs. every peer)."""
        leaves = jax.tree_util.tree_leaves(values)
        if len(leaves) != len(self._leaves):
            raise ValueError("pytree structure mismatch")
        with np.errstate(over="ignore"):  # modular field arithmetic
            flat = _flatten_encoded(leaves, self._leaves, self.cfg)
            flat = flat + self._flat_masks()[i]
        return _split_flat(flat, self._leaves)

    def upload_all(
        self, values: Mapping[int, PyTree]
    ) -> dict[int, list[np.ndarray]]:
        """Ciphertexts for a whole cohort: one host transfer, one masking
        pass (slot index -> masked ciphertext)."""
        return _masked_cohort_uploads(self, values)

    # -- recovery -----------------------------------------------------------

    def recovery_shares(
        self, dropped: int, survivors: Sequence[int]
    ) -> list[tuple[int, int]]:
        """Shares of u_dropped that the survivors reveal to the facilitator."""
        return [self._shares[dropped][j] for j in survivors]

    def aggregate(
        self, uploads: dict[int, list[np.ndarray]]
    ) -> PyTree:
        """Sum received ciphertexts; reconstruct + cancel dropped pads.

        ``uploads`` maps participant index -> ciphertext.  Participants
        absent from the dict are treated as dropped and recovered via
        Shamir.  Raises if fewer than ``threshold`` uploads survive.
        """
        n = self.cfg.n_participants
        survivors = sorted(uploads)
        if any(not 0 <= s < n for s in survivors):
            raise ValueError("upload index out of range")
        dropped = [d for d in range(n) if d not in uploads]
        if len(survivors) < self.threshold:
            raise ValueError(
                f"only {len(survivors)} uploads for threshold "
                f"{self.threshold}: cannot reconstruct dropped masks"
            )
        _check_uploads([uploads[s] for s in survivors], self._leaves)
        with np.errstate(over="ignore"):
            total = _stack_ciphertexts(
                [uploads[s] for s in survivors]
            ).sum(axis=0, dtype=_FIELD_DTYPE)
            for d in dropped:
                # Any `threshold` survivors' shares reconstruct u_d exactly.
                shares = self.recovery_shares(d, survivors[: self.threshold])
                u_d = shamir_reconstruct(shares)
                # Regenerate every survivor-side pad involving d from the
                # reconstructed secret (one batched PRG call per dropped
                # party) and cancel: survivor j applied +pad if j < d else
                # -pad, so subtract for j < d and add back for j > d.
                pads = self._pads_from_seeds([
                    pow(self.public_keys[j], u_d, _SHAMIR_PRIME)
                    for j in survivors
                ])
                before = np.asarray([j < d for j in survivors])
                total = total - pads[before].sum(axis=0, dtype=_FIELD_DTYPE)
                total = total + pads[~before].sum(axis=0, dtype=_FIELD_DTYPE)
        decoded = [
            jnp.asarray(_decode(t, self.cfg))
            for t in _split_flat(total, self._leaves)
        ]
        return jax.tree_util.tree_unflatten(self._treedef, decoded)


def secure_sum_with_dropouts(
    values: Sequence[PyTree | None],
    cfg: SecAggConfig,
    *,
    threshold: int | None = None,
) -> PyTree:
    """Full dropout-robust round; ``None`` entries are dropped participants."""
    values = list(values)
    if len(values) != cfg.n_participants:
        raise ValueError(
            f"{len(values)} slots for {cfg.n_participants} participants"
        )
    template = next((v for v in values if v is not None), None)
    if template is None:
        raise ValueError("every participant dropped; nothing to aggregate")
    session = DropoutRobustSession(cfg, template, threshold=threshold)
    uploads = session.upload_all(
        {i: v for i, v in enumerate(values) if v is not None}
    )
    return session.aggregate(uploads)


def secagg_recovery_bytes(
    n_participants: int, n_dropped: int = 0
) -> dict[str, float]:
    """Wire-cost model for the dropout-robust extension.

    Setup: each participant broadcasts an 8 B public key and sends one 16 B
    Shamir share (8 B y + index) to each peer.  Recovery: each survivor
    reveals one share per dropped participant to the facilitator.
    """
    n, d = n_participants, n_dropped
    setup = n * 8.0 + n * (n - 1) * 16.0
    recovery = (n - d) * d * 16.0
    return {"setup_bytes": setup, "recovery_bytes": recovery}


def secagg_message_bytes(n_params: int, n_participants: int,
                         frac_bits: int = 16) -> dict[str, float]:
    """Communication-cost model for Supp. Table 1 (bytes per round).

    Per participant: one masked vector (4 B/elem in Z_2^32) plus the pairwise
    seed exchange (32 B per peer).  The aggregator receives all uploads.
    """
    per_participant = 4.0 * n_params + 32.0 * (n_participants - 1)
    aggregator = per_participant * n_participants
    plain = 4.0 * n_params
    return {
        "per_participant_bytes": per_participant,
        "aggregator_bytes": aggregator,
        "plain_per_participant_bytes": plain,
        "plain_aggregator_bytes": plain * n_participants,
    }
