"""Membership inference via the Likelihood Ratio Attack (LiRA, Carlini 2022).

Used as the paper's empirical privacy audit (Fig. 5): the online attack
trains N shadow models on random half-splits, fits per-example Gaussians to
the scaled confidences of IN and OUT shadows, and scores the target model's
examples by the likelihood ratio.  The headline comparison is AUROC (and
TPR at low FPR) of the attack against FL-trained vs DeCaPH-trained targets.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


def _logit_scale(p: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    p = np.clip(p, eps, 1 - eps)
    return np.log(p) - np.log(1 - p)


def auroc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUROC (no sklearn)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    n_pos = int(labels.sum())
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float(
        (ranks[labels.astype(bool)].sum() - n_pos * (n_pos + 1) / 2)
        / (n_pos * n_neg)
    )


def roc_curve(scores: np.ndarray, labels: np.ndarray, n_points: int = 200):
    thresholds = np.quantile(scores, np.linspace(0, 1, n_points))
    tpr, fpr = [], []
    pos = labels.astype(bool)
    for t in thresholds[::-1]:
        pred = scores >= t
        tpr.append((pred & pos).sum() / max(pos.sum(), 1))
        fpr.append((pred & ~pos).sum() / max((~pos).sum(), 1))
    return np.asarray(fpr), np.asarray(tpr)


def tpr_at_fpr(scores, labels, target_fpr: float = 0.01) -> float:
    fpr, tpr = roc_curve(scores, labels, n_points=500)
    ok = fpr <= target_fpr
    return float(tpr[ok].max()) if ok.any() else 0.0


@dataclasses.dataclass
class LiRAResult:
    scores: np.ndarray
    membership: np.ndarray
    auroc: float
    tpr_at_1pct_fpr: float


def lira_attack(
    train_fn: Callable[[np.ndarray, np.ndarray, int], object],
    confidence_fn: Callable[[object, np.ndarray, np.ndarray], np.ndarray],
    x: np.ndarray,
    y: np.ndarray,
    *,
    n_shadows: int = 16,
    seed: int = 0,
    target_seed: int = 999,
) -> LiRAResult:
    """Online LiRA.

    train_fn(x_train, y_train, seed) -> model; confidence_fn(model, x, y) ->
    per-example probability assigned to the true label.  The target model is
    trained on a random half split (seed ``target_seed``); its training half
    forms the members.
    """
    rng = np.random.default_rng(seed)
    n = len(x)
    # shadow in/out masks: each example is IN for ~half the shadows
    in_masks = rng.random((n_shadows, n)) < 0.5
    phi = np.zeros((n_shadows, n), np.float64)
    for s in range(n_shadows):
        m = in_masks[s]
        model = train_fn(x[m], y[m], seed + 100 + s)
        phi[s] = _logit_scale(np.asarray(confidence_fn(model, x, y)))

    mu_in = np.zeros(n)
    mu_out = np.zeros(n)
    sd_in = np.ones(n)
    sd_out = np.ones(n)
    for i in range(n):
        pin = phi[in_masks[:, i], i]
        pout = phi[~in_masks[:, i], i]
        if len(pin) >= 2:
            mu_in[i], sd_in[i] = pin.mean(), max(pin.std(), 1e-3)
        if len(pout) >= 2:
            mu_out[i], sd_out[i] = pout.mean(), max(pout.std(), 1e-3)

    t_rng = np.random.default_rng(target_seed)
    member = t_rng.random(n) < 0.5
    target = train_fn(x[member], y[member], target_seed)
    phi_t = _logit_scale(np.asarray(confidence_fn(target, x, y)))

    def log_norm(v, mu, sd):
        return -0.5 * ((v - mu) / sd) ** 2 - np.log(sd)

    scores = log_norm(phi_t, mu_in, sd_in) - log_norm(phi_t, mu_out, sd_out)
    return LiRAResult(
        scores=scores,
        membership=member.astype(np.int32),
        auroc=auroc(scores, member.astype(np.int32)),
        tpr_at_1pct_fpr=tpr_at_fpr(scores, member.astype(np.int32), 0.01),
    )
