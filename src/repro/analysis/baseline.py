"""The committed baseline: known findings that do not fail the build.

The baseline is a JSON file of finding fingerprints (line-number-free, so
unrelated edits never churn it).  ``--fail-on-new`` exits nonzero only
for findings whose fingerprint is not baselined — the ratchet: existing
debt is visible but frozen, new debt is blocked.  This repo's committed
baseline is EMPTY (every genuine finding was fixed in the PR that landed
the pass), and the acceptance gate keeps it that way.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.analysis.findings import Finding

BASELINE_SCHEMA = 1
DEFAULT_BASELINE = "analysis_baseline.json"


def load_baseline(path: Path) -> set[str]:
    """Fingerprints in the baseline file ({} if absent)."""
    if not path.exists():
        return set()
    payload = json.loads(path.read_text())
    if payload.get("schema") != BASELINE_SCHEMA:
        raise ValueError(
            f"baseline schema {payload.get('schema')!r} != {BASELINE_SCHEMA}"
        )
    return set(payload["fingerprints"])


def write_baseline(path: Path, findings: list[Finding]) -> None:
    payload = {
        "schema": BASELINE_SCHEMA,
        "fingerprints": sorted(f.fingerprint() for f in findings),
        "sites": [
            {"rule": f.rule, "path": f.path, "snippet": f.snippet,
             "occurrence": f.occurrence}
            for f in sorted(findings, key=lambda x: (x.path, x.line))
        ],
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def split_new(
    findings: list[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding]]:
    """(new, baselined)."""
    new, old = [], []
    for f in findings:
        (old if f.fingerprint() in baseline else new).append(f)
    return new, old
