"""Report rendering: ``--format md`` (human) and ``--format json`` (CI)."""

from __future__ import annotations

import json
from typing import Any

from repro.analysis.engine import AnalysisResult, Rule


def json_report(
    result: AnalysisResult,
    rules: list[Rule],
    new_fps: set[str],
) -> dict[str, Any]:
    index = result.index
    return {
        "schema": 1,
        "rules": [
            {"id": r.id, "contract": r.contract, "design": r.design}
            for r in rules
        ],
        "files": len(result.contexts),
        "skipped": [{"path": p, "error": e} for p, e in result.skipped],
        "scopes": {
            "hot_path_defs": sorted(index.hot_path_scope()),
            "serve_thread_modules": sorted(index.serve_thread_modules()),
        },
        "findings": [
            {**f.to_dict(), "new": f.fingerprint() in new_fps}
            for f in result.findings
        ],
        "suppressed": [f.to_dict() for f in result.suppressed],
        "counts": {
            "findings": len(result.findings),
            "new": len(new_fps & {f.fingerprint() for f in result.findings}),
            "suppressed": len(result.suppressed),
        },
    }


def render_json(result, rules, new_fps) -> str:
    return json.dumps(json_report(result, rules, new_fps), indent=2,
                      sort_keys=True)


def render_md(result: AnalysisResult, rules: list[Rule],
              new_fps: set[str]) -> str:
    lines = ["# repro.analysis report", ""]
    lines.append(f"{len(result.contexts)} files scanned, "
                 f"{len(result.findings)} findings "
                 f"({len(result.suppressed)} suppressed in-line).")
    lines.append("")
    if result.findings:
        lines += ["| location | rule | finding |", "|---|---|---|"]
        for f in result.findings:
            mark = " **new**" if f.fingerprint() in new_fps else ""
            lines.append(
                f"| `{f.path}:{f.line}` | `{f.rule}`{mark} | {f.message} |"
            )
        lines.append("")
    else:
        lines += ["No findings.", ""]
    if result.suppressed:
        lines.append(f"Suppressed: " + ", ".join(
            f"`{f.path}:{f.line}` [{f.rule}]" for f in result.suppressed))
        lines.append("")
    if result.skipped:
        lines.append("Skipped (unparseable): " + ", ".join(
            p for p, _ in result.skipped))
        lines.append("")
    return "\n".join(lines)


def render_rule_list(rules: list[Rule]) -> str:
    lines = [
        "repro.analysis — contract rules (DESIGN.md §13)",
        "",
    ]
    width = max(len(r.id) for r in rules)
    for r in rules:
        lines.append(f"  {r.id:<{width}}  [{r.design}]  {r.contract}")
    lines.append("")
    lines.append("suppress one site:  # repro: allow[<rule-id>] <reason>")
    return "\n".join(lines)
