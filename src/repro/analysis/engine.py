"""The rule engine: file contexts, the rule registry, and the runner.

Layering (DESIGN.md §13): ``FileContext`` parses one file once — AST,
import-alias table, suppression comments — and every rule shares it.
Rules are registry-discovered citizens exactly like arms and backends
(``@register_rule``): each declares an ``id``, the one-line ``contract``
it enforces, and its DESIGN.md anchor, then implements ``check_file``
(per file) and/or ``check_project`` (cross-file, after the
``ModuleIndex`` is built).

The engine owns the mechanics every rule would otherwise reimplement:
name resolution through import aliases (``ctx.dotted``), finding
construction with repo-relative paths, suppression application, and the
``analysis-suppression`` meta-finding for reasonless allow-comments.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterable, Iterator

from repro.analysis.findings import (
    Finding,
    Suppression,
    apply_suppressions,
    assign_occurrences,
    parse_suppressions,
)
from repro.analysis.graphs import ModuleIndex


class FileContext:
    """One parsed source file: AST, aliases, suppressions, helpers."""

    def __init__(self, path: Path, rel: str, module: str, source: str) -> None:
        self.path = path
        self.rel = rel                      # repo-relative posix path
        self.module = module                # dotted module name
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        self.suppressions: dict[int, list[Suppression]] = \
            parse_suppressions(source)
        self.aliases = _collect_aliases(self.tree)

    # -- name resolution ------------------------------------------------------

    def dotted(self, node: ast.AST) -> str | None:
        """Resolve a Name/Attribute chain through the import-alias table.

        ``np.asarray`` -> "numpy.asarray" under ``import numpy as np``;
        ``fused.stack_poisson`` -> "repro.arms.fused.stack_poisson" under
        ``from repro.arms import fused``.  Unresolvable chains (calls on
        arbitrary objects) return the bare trailing chain or None.
        """
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id, node.id)
        return ".".join([base] + list(reversed(parts)))

    # -- finding construction -------------------------------------------------

    def finding(self, rule: "Rule | str", node: ast.AST, message: str) -> Finding:
        rule_id = rule if isinstance(rule, str) else rule.id
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) else ""
        return Finding(rule=rule_id, path=self.rel, line=line, col=col,
                       message=message, snippet=snippet)


def _collect_aliases(tree: ast.Module) -> dict[str, str]:
    """name -> dotted target, from every import statement in the file
    (function-level imports included: resolution is name-scoped enough)."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
                if a.asname is None and "." in a.name:
                    # "import jax.random" binds "jax" but makes the full
                    # dotted path resolvable; keep the root binding
                    aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


# -- rule registry ------------------------------------------------------------


class Rule:
    """Base class: one machine-checked repo contract."""

    id: str = ""
    contract: str = ""          # one line: the invariant enforced
    design: str = "§13"         # DESIGN.md anchor

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        return iter(())

    def check_project(
        self, contexts: list[FileContext], index: ModuleIndex
    ) -> Iterator[Finding]:
        return iter(())


_RULES: dict[str, type[Rule]] = {}


def register_rule(cls: type[Rule]) -> type[Rule]:
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in _RULES:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    _RULES[cls.id] = cls
    return cls


def all_rules() -> list[Rule]:
    import repro.analysis.rules  # noqa: F401  (registration side effect)
    return [_RULES[k]() for k in sorted(_RULES)]


# -- runner -------------------------------------------------------------------


@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]       # post-suppression
    suppressed: list[Finding]
    contexts: list[FileContext]
    index: ModuleIndex
    skipped: list[tuple[str, str]]  # (path, reason) — unparseable files


def module_name_for(rel: str) -> str:
    """Dotted module name from a repo-relative path.

    Files under ``src/`` get their import name (``repro.arms.fused``);
    everything else is dotted from the repo root (``tests.test_obs``).
    """
    p = Path(rel)
    parts = list(p.parts)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = p.stem
    return ".".join(parts)


def collect_files(paths: Iterable[Path], root: Path) -> list[tuple[Path, str]]:
    """(path, repo-relative posix) for every .py under ``paths``, sorted."""
    out = []
    for p in paths:
        p = Path(p)
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            if "__pycache__" in f.parts or f.suffix != ".py":
                continue
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            out.append((f, rel))
    return sorted(set(out), key=lambda t: t[1])


def run_analysis(
    paths: Iterable[Path],
    root: Path,
    rules: Iterable[Rule] | None = None,
    only_paths: set[str] | None = None,
) -> AnalysisResult:
    """Parse, index, run every rule, apply suppressions.

    ``only_paths`` (repo-relative) restricts *emission* to those files —
    the index (and therefore the computed scopes) is always built from the
    full file set, so ``--changed`` runs see the same scopes as full runs.
    """
    rules = list(rules) if rules is not None else all_rules()
    contexts: list[FileContext] = []
    skipped: list[tuple[str, str]] = []
    for path, rel in collect_files(paths, root):
        try:
            source = path.read_text()
            contexts.append(FileContext(path, rel, module_name_for(rel), source))
        except (OSError, SyntaxError, ValueError) as e:
            skipped.append((rel, str(e)))
    index = ModuleIndex.build(contexts)

    raw: list[Finding] = []
    for rule in rules:
        for ctx in contexts:
            raw.extend(rule.check_file(ctx, index))
        raw.extend(rule.check_project(contexts, index))

    # reasonless allow-comments are findings themselves (dedup: an own-line
    # comment registers under two line keys but is one suppression)
    for ctx in contexts:
        seen: set[tuple[str, int]] = set()
        for sups in ctx.suppressions.values():
            for s in sups:
                if s.reason or (s.rule, s.line) in seen:
                    continue
                seen.add((s.rule, s.line))
                raw.append(Finding(
                    rule="analysis-suppression", path=ctx.rel,
                    line=s.line, col=0,
                    message=f"allow[{s.rule}] without a reason — "
                            "suppressions must say why",
                    snippet=ctx.lines[s.line - 1].strip()
                    if s.line <= len(ctx.lines) else "",
                ))

    if only_paths is not None:
        raw = [f for f in raw if f.path in only_paths]
    raw = assign_occurrences(raw)
    sup_map = {ctx.rel: ctx.suppressions for ctx in contexts}
    kept, suppressed = apply_suppressions(raw, sup_map)
    return AnalysisResult(findings=kept, suppressed=suppressed,
                          contexts=contexts, index=index, skipped=skipped)
