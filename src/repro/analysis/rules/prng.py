"""prng-key-discipline: no PRNG stream may be consumed twice.

A reused JAX key makes two "independent" noise draws identical — the DP
mechanism then adds *correlated* noise and the ledger's ε is a fiction.
Three checks, matching how keys are actually derived in this repo:

  1. **Key reuse across draw sites** — the same key variable consumed by
     two or more ``jax.random.<draw>`` calls with no rebinding between
     them (including a draw inside a loop whose key never changes per
     iteration).  Keys must be split or folded before every draw.
  2. **Salt-constant collisions** — module-level ``*_SALT`` integers are
     the per-purpose key-stream namespaces (decaph 17, primia 31,
     gossip-dp 53, dp.TOPUP_SALT 1_000_003); two modules defining the
     same value collapse two namespaces onto one stream.  src/ only —
     vendored legacy snapshots under tests/ intentionally freeze old
     salts.
  3. **Untagged stdlib seeds** — ``random.Random(seed)`` in src/ must use
     the ``f"{seed}:{tag}"`` tagged-stream discipline from
     ``repro.population.spec``: int-seeded streams with the same seed are
     byte-identical, so two untagged consumers of one run seed silently
     correlate.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import FileContext, Rule, register_rule
from repro.analysis.findings import Finding
from repro.analysis.graphs import ModuleIndex

DRAW_FNS = frozenset(
    f"jax.random.{n}" for n in (
        "normal", "uniform", "laplace", "bernoulli", "truncated_normal",
        "categorical", "gumbel", "exponential", "poisson", "randint",
        "permutation", "choice", "gamma", "beta", "rademacher", "bits",
    )
)


def _assigned_names(node: ast.AST) -> set[str]:
    """Every name (re)bound anywhere under ``node``."""
    out: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
        elif isinstance(n, ast.NamedExpr) and isinstance(n.target, ast.Name):
            out.add(n.target.id)
    return out


@register_rule
class PrngKeyDiscipline(Rule):
    id = "prng-key-discipline"
    contract = ("every noise/draw key is fresh (split/fold_in per draw); "
                "salt namespaces unique; stdlib seeds tagged f\"{seed}:{tag}\"")
    design = "§13.1"

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        yield from self._key_reuse(ctx)
        if ctx.rel.startswith("src/"):
            yield from self._untagged_random(ctx)

    # -- 1: key reuse ---------------------------------------------------------

    def _key_reuse(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            draws = []        # (lineno, key_name, node)
            rebinds = []      # (lineno, name)
            comp_targets = {}  # name -> comprehension node it is bound by
            loops = []        # loop nodes, for per-iteration analysis
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    dotted = ctx.dotted(node.func)
                    if dotted in DRAW_FNS and node.args and \
                            isinstance(node.args[0], ast.Name):
                        draws.append((node.lineno, node.args[0].id, node))
                elif isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Store):
                    rebinds.append((node.lineno, node.id))
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.GeneratorExp, ast.DictComp)):
                    for gen in node.generators:
                        for t in ast.walk(gen.target):
                            if isinstance(t, ast.Name):
                                comp_targets[t.id] = node
                elif isinstance(node, (ast.For, ast.While)):
                    loops.append(node)

            # (a) sequential reuse: two draws on one name, no rebind between
            by_name: dict[str, list[tuple[int, ast.AST]]] = {}
            for lineno, name, node in draws:
                if name in comp_targets:
                    continue  # fresh binding per comprehension iteration
                by_name.setdefault(name, []).append((lineno, node))
            for name, sites in by_name.items():
                sites.sort(key=lambda t: t[0])
                for (l1, _), (l2, node2) in zip(sites, sites[1:]):
                    if not any(l1 < lr <= l2 and nr == name
                               for lr, nr in rebinds):
                        yield ctx.finding(
                            self, node2,
                            f"key {name!r} consumed by a second draw without "
                            f"split/fold_in since line {l1} — reused PRNG "
                            "stream",
                        )

            # (b) loop reuse: a draw inside a loop whose key is never
            # rebound inside that loop body
            for loop in loops:
                bound_in_loop = _assigned_names(loop)
                for node in ast.walk(loop):
                    if isinstance(node, ast.Call):
                        dotted = ctx.dotted(node.func)
                        if dotted in DRAW_FNS and node.args and \
                                isinstance(node.args[0], ast.Name):
                            name = node.args[0].id
                            if name not in bound_in_loop and \
                                    name not in comp_targets:
                                yield ctx.finding(
                                    self, node,
                                    f"key {name!r} drawn from inside a loop "
                                    "but never rebound per iteration — every "
                                    "pass reuses the same stream",
                                )

    # -- 3: untagged stdlib seeds --------------------------------------------

    def _untagged_random(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if ctx.dotted(node.func) != "random.Random":
                continue
            if not node.args:
                yield ctx.finding(self, node,
                                  "unseeded random.Random() — draws are "
                                  "irreproducible")
                continue
            arg = node.args[0]
            if isinstance(arg, ast.JoinedStr):
                text = "".join(v.value for v in arg.values
                               if isinstance(v, ast.Constant)
                               and isinstance(v.value, str))
                if ":" in text:
                    continue
            elif isinstance(arg, ast.Constant) and \
                    isinstance(arg.value, str) and ":" in arg.value:
                continue
            yield ctx.finding(
                self, node,
                "random.Random seed must use the tagged f\"{seed}:{tag}\" "
                "stream discipline (repro.population.spec) — int-seeded "
                "streams with a shared seed are byte-identical",
            )

    # -- 2: salt collisions (cross-file) --------------------------------------

    def check_project(self, contexts, index) -> Iterator[Finding]:
        salts: dict[int, list[tuple[FileContext, ast.AST, str]]] = {}
        for ctx in contexts:
            if not ctx.rel.startswith("src/"):
                continue
            for node in ctx.tree.body:
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and node.targets[0].id.endswith("_SALT") \
                        and isinstance(node.value, ast.Constant) \
                        and isinstance(node.value.value, int):
                    salts.setdefault(node.value.value, []).append(
                        (ctx, node, node.targets[0].id)
                    )
        for value, sites in sorted(salts.items()):
            if len(sites) < 2:
                continue
            where = ", ".join(f"{c.rel}:{n.lineno}" for c, n, _ in sites)
            for ctx, node, name in sites:
                yield ctx.finding(
                    self, node,
                    f"salt {name} = {value} collides with another module's "
                    f"salt ({where}) — fold_in namespaces must be unique",
                )
