"""Rule modules register themselves on import — registry-style, like
``repro.arms`` and ``repro.arms.backends``: adding a rule is one module
with one ``@register_rule`` class, plus its DESIGN.md §13 entry."""

from repro.analysis.rules import (  # noqa: F401
    determinism,
    hashing,
    hostsync,
    locking,
    noise,
    prng,
)
