"""canonical-hash-discipline: one byte encoding per content address.

``population.graph`` node ids, ``obs.ledger`` entry ids and
``scenarios.spec`` cache keys all hash the SAME canonical JSON bytes
(sorted keys, compact separators — ``repro.canon``).  A hand-rolled
``hashlib.sha256(json.dumps(...).encode())`` drifts the moment someone
forgets ``sort_keys`` or leaves the default separators: the same record
then has two addresses, re-traces stop matching, ledgers fork.

Rule: a function (or module body) in src/ that calls both ``json.dumps``
and a ``hashlib`` digest is hand-rolling a content hash — route it
through ``repro.canon.content_hash``/``canonical_json_bytes`` instead.
``repro.canon`` itself is the one sanctioned definition site.  tests/ are
exempt: tamper tests legitimately re-derive hashes to cross-check the
helpers.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import FileContext, Rule, register_rule
from repro.analysis.findings import Finding
from repro.analysis.graphs import ModuleIndex

def _walk_scope(body):
    """Walk a scope's statements, pruning nested function subtrees (they
    are their own scopes) but not lambdas/comprehensions."""
    stack = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


_DIGESTS = frozenset(
    f"hashlib.{n}" for n in
    ("sha256", "sha1", "sha512", "sha384", "md5", "blake2b", "blake2s",
     "sha3_256", "new")
)


@register_rule
class CanonicalHashDiscipline(Rule):
    id = "canonical-hash-discipline"
    contract = ("json.dumps feeding hashlib goes through "
                "repro.canon.content_hash — one byte encoding per address")
    design = "§13.5"

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        if not ctx.rel.startswith("src/") or ctx.module == "repro.canon":
            return
        # scopes: each def's body (nested defs excluded from the parent),
        # plus the module body itself
        scopes: list[tuple[str, list[ast.AST]]] = [("<module>", ctx.tree.body)]
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node.name, node.body))
        for name, body in scopes:
            dumps, digest = None, None
            for node in _walk_scope(body):
                if not isinstance(node, ast.Call):
                    continue
                dotted = ctx.dotted(node.func)
                if dotted == "json.dumps":
                    dumps = dumps or node
                elif dotted in _DIGESTS:
                    digest = digest or node
            if dumps is not None and digest is not None:
                yield ctx.finding(
                    self, digest,
                    f"{name}() hand-rolls json.dumps + hashlib — use "
                    "repro.canon.content_hash/canonical_json_bytes so the "
                    "byte encoding cannot drift",
                )
