"""locked-shared-state: the PR 8 trainer-thread race class, as a rule.

``python -m repro.serve --train-rounds N`` runs a federation trainer
thread concurrently with the decode loop; both traverse shared modules
(``repro.instrument``'s dispatch counter lost ticks exactly this way
before PR 8 locked it).  The rule audits every module in the
import-closure of a ``threading.Thread(target=…)`` function — a scope
computed from the scanned tree, so a new thread widens it automatically —
for module-level mutable state mutated inside a function without an
enclosing ``with <lock>:``.

What counts as module state: module-level names bound to dict/list/set
literals (or dict()/list()/set()/defaultdict/deque constructors), or
rebound via ``global`` inside a function (the ``_STATE = None`` +
``global`` pattern).  Import-time registration is exempt by convention:
mutations inside functions named ``register*`` run under the import lock
before any thread exists.  ``threading.local()`` values are inherently
per-thread and never flagged.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import FileContext, Rule, register_rule
from repro.analysis.findings import Finding
from repro.analysis.graphs import ModuleIndex

_MUTATORS = frozenset({
    "append", "add", "update", "pop", "setdefault", "extend", "insert",
    "remove", "clear", "popitem", "discard", "appendleft",
})

_MUTABLE_CTORS = frozenset({
    "dict", "list", "set", "collections.defaultdict", "collections.deque",
    "collections.OrderedDict", "collections.Counter",
})


def _module_state_names(ctx: FileContext) -> set[str]:
    """Module-level names holding (potentially) shared mutable state."""
    mutable: set[str] = set()
    for node in ctx.tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = [t for t in node.targets if isinstance(t, ast.Name)]
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and node.value is not None:
            targets = [node.target]
        if not targets:
            continue
        value = node.value
        if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                              ast.ListComp, ast.SetComp)) or (
                isinstance(value, ast.Call)
                and (ctx.dotted(value.func) or "") in _MUTABLE_CTORS):
            mutable.update(t.id for t in targets)
    # the `_STATE = None` + `global _STATE` rebind pattern
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Global):
            mutable.update(node.names)
    return {n for n in mutable if not (n.startswith("__") and n.endswith("__"))}


@register_rule
class LockedSharedState(Rule):
    id = "locked-shared-state"
    contract = ("module-level mutable state in serve-thread-reachable "
                "modules is only mutated under a lock")
    design = "§13.4"

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        if ctx.module not in index.serve_thread_modules():
            return
        state = _module_state_names(ctx)
        if not state:
            return

        findings: list[Finding] = []

        def visit(node: ast.AST, fn: ast.AST | None, lock_depth: int,
                  globals_in_fn: frozenset[str]) -> None:
            for child in ast.iter_child_nodes(node):
                child_fn, child_lock, child_globals = fn, lock_depth, globals_in_fn
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if child.name.startswith("register"):
                        continue  # import-time registration convention
                    child_fn = child
                    child_globals = frozenset(
                        n for g in ast.walk(child)
                        if isinstance(g, ast.Global) for n in g.names
                    )
                elif isinstance(child, ast.With):
                    if any("lock" in ast.unparse(i.context_expr).lower()
                           for i in child.items):
                        child_lock = lock_depth + 1
                if fn is not None and lock_depth == 0:
                    hit = self._mutation(child, state, globals_in_fn)
                    if hit:
                        fn_name = getattr(fn, "name", "<fn>")
                        findings.append(ctx.finding(
                            self, child,
                            f"module state {hit!r} mutated in {fn_name}() "
                            "without a lock — racy when the serve trainer "
                            "thread runs concurrently (use a lock or "
                            "threading.local)",
                        ))
                visit(child, child_fn, child_lock, child_globals)

        visit(ctx.tree, None, 0, frozenset())
        yield from findings

    @staticmethod
    def _mutation(node: ast.AST, state: set[str],
                  globals_in_fn: frozenset[str]) -> str | None:
        """The state name this statement mutates, if any."""
        def target_hit(t: ast.AST, allow_bare: bool) -> str | None:
            if isinstance(t, ast.Subscript) and \
                    isinstance(t.value, ast.Name) and t.value.id in state:
                return t.value.id
            if allow_bare and isinstance(t, ast.Name) and t.id in state \
                    and t.id in globals_in_fn:
                return t.id
            return None

        if isinstance(node, ast.Assign):
            for t in node.targets:
                hit = target_hit(t, allow_bare=True)
                if hit:
                    return hit
        elif isinstance(node, ast.AugAssign):
            return target_hit(node.target, allow_bare=True)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                hit = target_hit(t, allow_bare=False)
                if hit:
                    return hit
        elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            call = node.value
            if isinstance(call.func, ast.Attribute) and \
                    call.func.attr in _MUTATORS and \
                    isinstance(call.func.value, ast.Name) and \
                    call.func.value.id in state:
                return call.func.value.id
        return None
