"""unaccounted-noise: every DP noise draw flows through core/dp.py.

The RDP accountant's ε is a statement about the noise ``core.dp``
calibrates (``noise_share`` / ``tree_topup_noise``: N(0, (Cσ)²/n) shares,
conservative top-ups).  A ``jax.random.normal`` scaled by some local
sigma anywhere else is noise the ledger never hears about — the run
*looks* private and isn't, the exact implementation-correctness gap the
PPML surveys call out.

Two triggers, src/ only (tests and benchmarks draw normals as fixtures):

  * any ``jax.random.normal``/``laplace`` outside ``repro.core.dp`` and
    outside ``repro/models`` + ``repro/kernels`` (parameter initialisers
    and kernel references draw normals that are not noise);
  * anywhere at all (models included): a draw multiplied by an expression
    mentioning sigma/noise/std/clip — that is a privacy-noise shape, and
    it must live in core/dp.py.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from repro.analysis.engine import FileContext, Rule, register_rule
from repro.analysis.findings import Finding
from repro.analysis.graphs import ModuleIndex

_NOISE_FNS = frozenset({"jax.random.normal", "jax.random.laplace"})
_EXEMPT_MODULE = "repro.core.dp"
_INIT_PREFIXES = ("repro.models", "repro.kernels")
_SIGMA_RE = re.compile(r"sigma|noise|(^|[^a-z])std([^a-z]|$)|clip",
                       re.IGNORECASE)


@register_rule
class UnaccountedNoise(Rule):
    id = "unaccounted-noise"
    contract = ("every sigma-scaled Gaussian/Laplace draw lives in "
                "core/dp.py where the accountant calibrates it")
    design = "§13.3"

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        if not ctx.rel.startswith("src/") or ctx.module == _EXEMPT_MODULE:
            return
        init_exempt = ctx.module.startswith(_INIT_PREFIXES)
        # draw node -> enclosing BinOp multiplier text (if any)
        scaled: dict[ast.AST, str] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
                for side, other in ((node.left, node.right),
                                    (node.right, node.left)):
                    if isinstance(side, ast.Call) and \
                            ctx.dotted(side.func) in _NOISE_FNS:
                        scaled[side] = ast.unparse(other)
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and ctx.dotted(node.func) in _NOISE_FNS):
                continue
            multiplier = scaled.get(node)
            if multiplier is not None and _SIGMA_RE.search(multiplier):
                yield ctx.finding(
                    self, node,
                    f"draw scaled by {multiplier!r} outside core/dp.py — "
                    "noise bypassing the accountant/ledger",
                )
            elif not init_exempt:
                yield ctx.finding(
                    self, node,
                    "jax.random.normal/laplace outside core/dp.py (and "
                    "outside the models/kernels initialiser exemption) — "
                    "route noise through repro.core.dp",
                )
