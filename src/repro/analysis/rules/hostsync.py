"""host-sync-hygiene: the §7 one-sync-per-round contract, machine-checked.

The fused cohort round-step is ONE jit dispatch plus ONE host sync per
round (DESIGN.md §7; `benchmarks/hotpath.py` certifies the dispatch half
in CI).  The sync half was only spot-tested: any ``.item()``,
``jax.device_get``, ``block_until_ready``, ``float(array)`` or
``np.asarray(device_array)`` that creeps into code reachable from a
``fused_round`` silently serialises the device pipeline once per call
site — the exact regression class PR 4 removed.

Scope is *computed*: every def reachable through the call graph from any
``fused_round`` definition (``ModuleIndex.hot_path_scope``), minus the
sanctioned sync points — ``repro.arms.fused:build_contributions`` is THE
one host sync the contract allows.

Heuristics, chosen so host-side cohort bookkeeping stays quiet:
``np.asarray`` with an explicit dtype argument constructs host data
(``np.asarray(active, np.int32)``) and is not flagged — a device->host
sync never passes a dtype; ``float()`` of constants, ``len(...)``, or
string literals is host arithmetic, not a sync.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import FileContext, Rule, register_rule
from repro.analysis.findings import Finding
from repro.analysis.graphs import ModuleIndex

# the sanctioned sync points (§7): one host transfer per round, here only
WHITELIST = frozenset({
    "repro.arms.fused:build_contributions",
})

_SYNC_DOTTED = frozenset({"jax.device_get", "numpy.asarray"})
_SYNC_METHODS = frozenset({"item", "block_until_ready"})


@register_rule
class HostSyncHygiene(Rule):
    id = "host-sync-hygiene"
    contract = ("no device->host sync inside code reachable from a "
                "fused_round, except the sanctioned sync points")
    design = "§13.2"

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        scope = index.hot_path_scope() - WHITELIST
        in_file = [index.defs[fid] for fid in scope
                   if fid in index.defs and index.defs[fid].path == ctx.rel]
        for info in in_file:
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                # skip nested defs that are themselves whitelisted? nested
                # defs are separate index entries only if reachable; the
                # walk here deliberately includes closures defined inline —
                # they run inside the same dispatch region.
                dotted = ctx.dotted(node.func)
                if dotted in _SYNC_DOTTED:
                    if dotted == "numpy.asarray" and (
                            len(node.args) > 1 or node.keywords):
                        continue  # dtype given: host-data construction
                    yield ctx.finding(
                        self, node,
                        f"{dotted} inside the fused hot path "
                        f"({info.full_id}) — device sync outside the "
                        "sanctioned sync point",
                    )
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _SYNC_METHODS and not node.args:
                    yield ctx.finding(
                        self, node,
                        f".{node.func.attr}() inside the fused hot path "
                        f"({info.full_id}) — device sync outside the "
                        "sanctioned sync point",
                    )
                elif isinstance(node.func, ast.Name) and \
                        node.func.id == "float" and node.args:
                    arg = node.args[0]
                    if isinstance(arg, ast.Constant):
                        continue
                    if isinstance(arg, ast.Call) and \
                            isinstance(arg.func, ast.Name) and \
                            arg.func.id in ("len", "int", "round"):
                        continue
                    yield ctx.finding(
                        self, node,
                        f"float(...) on a possible device value inside the "
                        f"fused hot path ({info.full_id}) — blocking host "
                        "sync",
                    )
