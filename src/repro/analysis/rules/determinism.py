"""nondeterminism: content-addressed modules must be pure in (spec, seed).

The §10 determinism contract — same spec + seed ⇒ byte-identical compute
graph — and the §11 ledger chain are stated over *content*: a wall-clock
read, an unseeded global-``random`` draw, or a ``hash()`` (salted per
process by PYTHONHASHSEED) anywhere in the trace/solve/graph/ledger
modules breaks the address space silently — the re-trace gate in CI would
catch it a build later, with no pointer to the line that did it.

Scope: the ``repro.population`` package and ``repro.obs.ledger``.  CLI
modules (``*.cli``) are reporting layers — they time and print but never
feed content hashes — and are excluded.  Host wall timing inside
``solve`` is legitimate *measurement* (reported beside, never inside, the
content-addressed records) and carries per-site ``allow[...]``
suppressions saying exactly that.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import FileContext, Rule, register_rule
from repro.analysis.findings import Finding
from repro.analysis.graphs import ModuleIndex

SCOPED_PREFIXES = ("repro.population",)
SCOPED_MODULES = ("repro.obs.ledger",)

_BANNED = {
    "time.time": "wall clock",
    "time.time_ns": "wall clock",
    "time.monotonic": "process-relative clock",
    "time.perf_counter": "process-relative clock",
    "datetime.datetime.now": "wall clock",
    "datetime.datetime.utcnow": "wall clock",
    "datetime.datetime.today": "wall clock",
    "datetime.date.today": "wall clock",
    "os.urandom": "entropy source",
    "uuid.uuid4": "entropy source",
    "uuid.uuid1": "host/time-derived id",
    "secrets.token_bytes": "entropy source",
    "secrets.token_hex": "entropy source",
}

# global-``random`` module draws (unseeded process-wide stream); seeded
# ``random.Random(...)`` instances are the sanctioned spelling
_GLOBAL_RANDOM = frozenset(
    f"random.{n}" for n in (
        "random", "randint", "randrange", "choice", "choices", "shuffle",
        "sample", "uniform", "gauss", "expovariate", "betavariate",
        "lognormvariate", "normalvariate", "vonmisesvariate", "paretovariate",
        "weibullvariate", "triangular", "getrandbits", "randbytes",
    )
)


@register_rule
class Nondeterminism(Rule):
    id = "nondeterminism"
    contract = ("trace/solve/graph/ledger modules are pure in (spec, seed): "
                "no wall clock, no unseeded random, no process-salted hash()")
    design = "§13.6"

    def _in_scope(self, module: str) -> bool:
        if module.split(".")[-1] == "cli" or module.endswith("__main__"):
            return False
        return module in SCOPED_MODULES or module.startswith(SCOPED_PREFIXES)

    def check_file(self, ctx: FileContext, index: ModuleIndex) -> Iterator[Finding]:
        if not self._in_scope(ctx.module):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.dotted(node.func)
            if dotted in _BANNED:
                yield ctx.finding(
                    self, node,
                    f"{dotted} ({_BANNED[dotted]}) in content-addressed "
                    f"module {ctx.module} — breaks same-(spec,seed) ⇒ "
                    "same-bytes",
                )
            elif dotted in _GLOBAL_RANDOM:
                yield ctx.finding(
                    self, node,
                    f"global {dotted} (process-wide unseeded stream) in "
                    f"{ctx.module} — use a tagged random.Random instance",
                )
            elif isinstance(node.func, ast.Name) and node.func.id == "hash" \
                    and len(node.args) == 1:
                yield ctx.finding(
                    self, node,
                    "builtin hash() is salted per process (PYTHONHASHSEED) — "
                    "use repro.canon.content_hash for stable addresses",
                )
