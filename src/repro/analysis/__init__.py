"""repro.analysis — contract-aware static analysis for the repro tree.

A stdlib-``ast`` rule engine (DESIGN.md §13) that machine-checks the
invariants the rest of the repo is built on: PRNG key discipline,
one-host-sync-per-round, noise accounting, lock coverage of
thread-shared state, canonical hashing, and (spec, seed) determinism.
Scopes like "the fused hot path" and "serve-thread-reachable modules"
are computed from a module-import + call graph, never hand-listed.

Run it: ``python -m repro.analysis src tests benchmarks --fail-on-new``.
"""

from repro.analysis.engine import (
    AnalysisResult,
    FileContext,
    Rule,
    all_rules,
    register_rule,
    run_analysis,
)
from repro.analysis.findings import Finding, Suppression

__all__ = [
    "AnalysisResult",
    "FileContext",
    "Finding",
    "Rule",
    "Suppression",
    "all_rules",
    "register_rule",
    "run_analysis",
]
