"""``python -m repro.analysis`` — run the contract rules over the tree.

Exit status: 0 when no findings fail the gate, 1 otherwise, 2 on usage
errors.  Without ``--fail-on-new`` every finding fails; with it, only
findings absent from the baseline do (the CI ratchet).  ``--changed``
restricts *reporting* to files touched vs a git ref — the module index
(and therefore the computed hot-path / serve-thread scopes) is still
built from the full path set, so scoped runs agree with full runs.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
from pathlib import Path

from repro.analysis.baseline import (
    DEFAULT_BASELINE,
    load_baseline,
    split_new,
    write_baseline,
)
from repro.analysis.engine import all_rules, run_analysis
from repro.analysis.report import (
    render_json,
    render_md,
    render_rule_list,
)


def _repo_root(start: Path) -> Path:
    for cand in [start, *start.parents]:
        if (cand / ".git").exists():
            return cand
    return start


def _changed_files(root: Path, ref: str) -> set[str]:
    """Repo-relative paths changed vs ``ref`` (plus untracked)."""
    out = subprocess.run(
        ["git", "diff", "--name-only", ref, "--"],
        cwd=root, capture_output=True, text=True, check=True,
    ).stdout
    untracked = subprocess.run(
        ["git", "ls-files", "--others", "--exclude-standard"],
        cwd=root, capture_output=True, text=True, check=True,
    ).stdout
    return {ln.strip() for ln in (out + untracked).splitlines() if ln.strip()}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract-aware static analysis for the repro tree",
    )
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to scan (default: src)")
    p.add_argument("--format", choices=("json", "md"), default="md")
    p.add_argument("--out", type=Path, default=None,
                   help="write the report here instead of stdout")
    p.add_argument("--baseline", type=Path, default=None,
                   help=f"baseline file (default: <root>/{DEFAULT_BASELINE})")
    p.add_argument("--write-baseline", action="store_true",
                   help="write current findings to the baseline and exit 0")
    p.add_argument("--fail-on-new", action="store_true",
                   help="fail only on findings not in the baseline")
    p.add_argument("--changed", nargs="?", const="HEAD", default=None,
                   metavar="REF",
                   help="report only files changed vs REF (default HEAD); "
                        "scopes still come from the full path set")
    p.add_argument("--list-rules", action="store_true",
                   help="print every rule id, contract, and DESIGN anchor")
    p.add_argument("--root", type=Path, default=None,
                   help="repo root override (default: nearest .git upward)")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    rules = all_rules()

    if args.list_rules:
        print(render_rule_list(rules))
        return 0

    root = (args.root or _repo_root(Path.cwd())).resolve()
    paths = [root / p if not Path(p).is_absolute() else Path(p)
             for p in (args.paths or ["src"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"error: no such path: {', '.join(map(str, missing))}",
              file=sys.stderr)
        return 2

    only_paths: set[str] | None = None
    if args.changed is not None:
        try:
            only_paths = {p for p in _changed_files(root, args.changed)
                          if p.endswith(".py")}
        except (subprocess.CalledProcessError, FileNotFoundError) as e:
            print(f"error: --changed needs a git checkout ({e})",
                  file=sys.stderr)
            return 2

    result = run_analysis(paths, root, rules=rules, only_paths=only_paths)

    baseline_path = args.baseline or (root / DEFAULT_BASELINE)
    if args.write_baseline:
        write_baseline(baseline_path, result.findings)
        print(f"wrote {len(result.findings)} fingerprints to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, _old = split_new(result.findings, baseline)
    new_fps = {f.fingerprint() for f in new}

    report = (render_json if args.format == "json" else render_md)(
        result, rules, new_fps)
    if args.out:
        args.out.write_text(report + "\n")
    else:
        print(report)

    failing = new if args.fail_on_new else result.findings
    if failing:
        for f in failing:
            print(f.render(), file=sys.stderr)
        label = "new " if args.fail_on_new else ""
        print(f"FAILED: {len(failing)} {label}finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
