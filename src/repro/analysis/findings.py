"""Findings, fingerprints, and `# repro: allow[...]` suppressions.

A ``Finding`` is one rule violation at one source location.  Its
``fingerprint`` deliberately excludes the line number: baselines must
survive unrelated edits above a site, so identity is (rule, file, the
offending source line's text, occurrence index of that text within the
file).  Two textually identical violations in one file get distinct
occurrence indices, so fixing one of them surfaces the other as "new".

Suppressions are per-line comments::

    noised = g + noise  # repro: allow[unaccounted-noise] calibrated in caller

The reason is mandatory — a bare ``allow[rule]`` does not suppress, it
shows up as an ``analysis-suppression`` finding instead, so every escape
hatch in the tree carries its own justification.  A suppression comment on
its own line covers the line below it (for sites too long to share a
line).
"""

from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Iterable, Mapping

from repro.canon import content_hash

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[(?P<rule>[a-z0-9-]+)\]\s*(?P<reason>.*)"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str          # repo-relative, posix separators
    line: int          # 1-indexed
    col: int
    message: str
    snippet: str       # the stripped offending source line
    occurrence: int = 0  # index among identical (rule, snippet) in this file

    def fingerprint(self) -> str:
        return content_hash({
            "rule": self.rule, "path": self.path,
            "snippet": self.snippet, "occurrence": self.occurrence,
        })

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


def assign_occurrences(findings: Iterable[Finding]) -> list[Finding]:
    """Number identical (path, rule, snippet) findings so fingerprints are
    unique; sort by location for stable reports."""
    ordered = sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))
    seen: dict[tuple, int] = {}
    out = []
    for f in ordered:
        key = (f.path, f.rule, f.snippet)
        n = seen.get(key, 0)
        seen[key] = n + 1
        out.append(dataclasses.replace(f, occurrence=n))
    return out


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    reason: str
    line: int


def parse_suppressions(source: str) -> dict[int, list[Suppression]]:
    """line -> suppressions covering that line (same line or line above)."""
    by_line: dict[int, list[Suppression]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string, t.start[1])
                    for t in tokens if t.type == tokenize.COMMENT]
    except tokenize.TokenError:
        return by_line
    for lineno, text, col in comments:
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        sup = Suppression(rule=m.group("rule"),
                          reason=m.group("reason").strip(), line=lineno)
        # a comment owning its whole line covers the NEXT line too
        lines = source.splitlines()
        own_line = lines[lineno - 1].lstrip().startswith("#") \
            if lineno <= len(lines) else False
        by_line.setdefault(lineno, []).append(sup)
        if own_line:
            by_line.setdefault(lineno + 1, []).append(sup)
    return by_line


def apply_suppressions(
    findings: list[Finding],
    suppressions_by_path: Mapping[str, Mapping[int, list[Suppression]]],
) -> tuple[list[Finding], list[Finding]]:
    """(kept, suppressed).  A reasonless allow-comment does not suppress —
    it is reported as an ``analysis-suppression`` finding by the engine."""
    kept, suppressed = [], []
    for f in findings:
        sups = suppressions_by_path.get(f.path, {}).get(f.line, [])
        if any(s.rule == f.rule and s.reason for s in sups):
            suppressed.append(f)
        else:
            kept.append(f)
    return kept, suppressed
