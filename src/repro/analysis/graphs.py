"""Module-import and call graphs: computed scopes for contract rules.

Two scopes in this repo are *sets of code*, not sets of names, and grow
every time an arm or a thread lands — so they are computed from the
source instead of hand-listed (the whole point of DESIGN.md §13):

  * **fused hot path** — every function reachable, through the lightweight
    call graph, from any ``fused_round`` definition (the §7 one-dispatch /
    one-sync cohort round step).  ``host-sync-hygiene`` flags device syncs
    inside this scope.
  * **serve-thread-reachable modules** — the module-import closure of
    every module whose function is passed as ``threading.Thread(target=…)``
    anywhere in the scanned tree (the PR 8 trainer-thread race class).
    ``locked-shared-state`` audits module-level mutable state there.

The call graph is deliberately lightweight and *over-approximate*: calls
are resolved through each module's import-alias table when possible;
bare-attribute calls (``self.foo()``, ``obj.foo()``) fall back to every
known def named ``foo`` whose module is the caller's module or in its
import closure.  Over-approximation only widens a scope — a too-wide
scope can surface a spurious finding (suppressible, visibly), a too-narrow
one silently waives the contract, so widening is the safe direction.
Closures stashed on ``self`` (e.g. the fused cohort programs built in arm
``__init__``) are invisible to it; those bodies are pure-jax by
construction and carry their own jit-boundary guarantees.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.analysis.engine import FileContext


@dataclasses.dataclass
class DefInfo:
    """One function/method definition."""

    full_id: str             # "repro.arms.decaph:DeCaPHArm.fused_round"
    module: str
    qual: str                # "DeCaPHArm.fused_round"
    name: str                # "fused_round"
    path: str
    lineno: int
    node: ast.AST


class ModuleIndex:
    """Cross-file index: defs, import graph, call graph, computed scopes."""

    def __init__(self) -> None:
        self.defs: dict[str, DefInfo] = {}
        self.by_name: dict[str, list[str]] = {}       # bare name -> full_ids
        self.module_imports: dict[str, set[str]] = {}  # module -> modules
        self.calls: dict[str, set[tuple[str, str]]] = {}
        # full_id -> {("dotted", "a.b.c") | ("bare", "foo")}
        self.thread_targets: list[str] = []            # resolved root full_ids
        self.modules: set[str] = set()
        self._raw_thread_targets: list[tuple[str, str, str]] = []

    # -- construction --------------------------------------------------------

    def add_file(self, ctx: "FileContext") -> None:
        self.modules.add(ctx.module)
        imports = self.module_imports.setdefault(ctx.module, set())
        for alias_target in ctx.aliases.values():
            imports.add(alias_target)
        _DefCollector(self, ctx).visit(ctx.tree)

    def finish(self) -> None:
        """Resolve thread targets after every file is indexed."""
        resolved = []
        for ref in self._raw_thread_targets:
            resolved.extend(self._resolve(ref[0], ref[1], ref[2]))
        self.thread_targets = resolved

    @classmethod
    def build(cls, contexts: Iterable["FileContext"]) -> "ModuleIndex":
        index = cls()
        for ctx in contexts:
            index.add_file(ctx)
        index.finish()
        return index

    # -- resolution ----------------------------------------------------------

    def _import_closure(self, module: str) -> set[str]:
        seen, frontier = {module}, [module]
        while frontier:
            m = frontier.pop()
            for dep in self.module_imports.get(m, ()):
                # imports may name objects ("pkg.mod.func"): walk prefixes
                # until one is a known module
                candidate = dep
                while candidate and candidate not in self.modules:
                    candidate = candidate.rpartition(".")[0]
                if candidate and candidate not in seen:
                    seen.add(candidate)
                    frontier.append(candidate)
        return seen

    def _resolve(self, kind: str, ref: str, caller_module: str) -> list[str]:
        """Resolve one call edge to zero or more known defs."""
        if kind == "dotted":
            mod, _, name = ref.rpartition(".")
            hit = self.defs.get(f"{mod}:{name}")
            if hit:
                return [hit.full_id]
            # "module:Class.method" via "pkg.mod.Class.method"
            mod2, _, cls = mod.rpartition(".")
            hit = self.defs.get(f"{mod2}:{cls}.{name}")
            return [hit.full_id] if hit else []
        # bare attribute call: every same-named def visible from the caller
        closure = self._import_closure(caller_module)
        return [fid for fid in self.by_name.get(ref, ())
                if self.defs[fid].module in closure]

    # -- reachability --------------------------------------------------------

    def reachable_from(self, roots: Iterable[str]) -> set[str]:
        seen = set(roots)
        frontier = list(seen)
        while frontier:
            fid = frontier.pop()
            caller_module = self.defs[fid].module if fid in self.defs else ""
            for kind, ref in self.calls.get(fid, ()):
                for callee in self._resolve(kind, ref, caller_module):
                    if callee not in seen:
                        seen.add(callee)
                        frontier.append(callee)
        return seen

    # -- the two computed scopes ---------------------------------------------

    def hot_path_scope(self) -> set[str]:
        """full_ids reachable from any ``fused_round`` definition."""
        roots = [fid for fid, d in self.defs.items() if d.name == "fused_round"]
        return self.reachable_from(roots)

    def serve_thread_modules(self) -> set[str]:
        """Import closure of every module owning a Thread-target function."""
        out: set[str] = set()
        for fid in self.thread_targets:
            if fid in self.defs:
                out |= self._import_closure(self.defs[fid].module)
        return out


class _DefCollector(ast.NodeVisitor):
    """Collect defs, call edges, and Thread(target=...) sites for one file."""

    def __init__(self, index: ModuleIndex, ctx: "FileContext") -> None:
        self.index = index
        self.ctx = ctx
        self.stack: list[str] = []   # class/function qualname parts
        self.current_fn: list[str] = []  # full_id stack

    # defs ---------------------------------------------------------------

    def _visit_def(self, node) -> None:
        qual = ".".join(self.stack + [node.name])
        full_id = f"{self.ctx.module}:{qual}"
        info = DefInfo(full_id=full_id, module=self.ctx.module, qual=qual,
                       name=node.name, path=self.ctx.rel, lineno=node.lineno,
                       node=node)
        self.index.defs[full_id] = info
        self.index.by_name.setdefault(node.name, []).append(full_id)
        self.stack.append(node.name)
        self.current_fn.append(full_id)
        self.generic_visit(node)
        self.current_fn.pop()
        self.stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    # call edges + thread targets ---------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        dotted = self.ctx.dotted(node.func)
        if dotted in ("threading.Thread", "Thread"):
            for kw in node.keywords:
                if kw.arg == "target":
                    ref = self.ctx.dotted(kw.value)
                    if ref:
                        kind = "dotted" if "." in ref else "bare"
                        self.index._raw_thread_targets.append(
                            (kind, ref, self.ctx.module)
                        )
        if self.current_fn:
            caller = self.current_fn[-1]
            edges = self.index.calls.setdefault(caller, set())
            if dotted and "." in dotted:
                edges.add(("dotted", dotted))
            elif dotted:
                # bare local call: same-module def or visible same-named def
                edges.add(("dotted", f"{self.ctx.module}.{dotted}"))
                edges.add(("bare", dotted))
            elif isinstance(node.func, ast.Attribute):
                edges.add(("bare", node.func.attr))
        self.generic_visit(node)
