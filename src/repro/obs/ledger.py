"""The per-hospital privacy ledger: an append-only, hash-chained audit log.

DeCaPH's pitch is *auditable* collaboration — each hospital must be able
to show, after the fact, exactly what left its walls and what privacy
budget was spent doing so.  The ledger is that artifact: one record per
(accounted round, hospital), for EVERY hospital, not just the round's
cohort.  Under Poisson cohort subsampling a non-sampled hospital's data is
still covered by the round's composition step (the accountant composes at
the *marginal* inclusion rate ``q * p``), so its ε advances even in rounds
it sat out; the ``member``/``delivered`` flags record the participation
story separately from the accounting story.

Integrity discipline is the same content-hash chain ``population.graph``
uses for its Merkle compute graph: each entry's ``id`` is the sha256 of
its canonical JSON record — which includes ``prev``, the previous entry's
id — so the newest id pins the entire history.  Any in-place edit (a
doctored ε, a reordered round, a deleted entry) breaks either an id
recomputation or the prev chain, and ``validate_entries`` says which.

Stdlib-only, like the rest of the obs core.

Entry schema (JSONL, one object per line — DESIGN.md §11):

    {"seq", "prev", "id",                    # chain bookkeeping
     "kind": "round",
     "round", "hospital", "arm", "backend",
     "member", "delivered",                  # cohort membership / upload landed
     "eps", "delta",                         # cumulative (ε, δ) AFTER this round
     "sampling_rate", "participation_rate", "noise_multiplier",
     "bytes_up",                             # bytes that left this hospital
     "topup"}                                # DP noise top-up applied (shares
                                             # lost mid-round; DESIGN.md §10)
"""

from __future__ import annotations

import json
import os
import threading
from typing import Iterable, Mapping, Sequence

from repro.canon import content_hash

GENESIS = "0" * 16

LEDGER_SCHEMA = 1


def entry_id(record: Mapping) -> str:
    """Content hash of one entry (minus its own ``id``) — graph.py style."""
    material = {k: v for k, v in record.items() if k != "id"}
    return content_hash(material)


class LedgerError(ValueError):
    """A ledger failed hash-chain or semantic validation."""


class PrivacyLedger:
    """Append-only, thread-safe, hash-chained privacy audit log."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: list[dict] = []

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def append(self, **fields) -> dict:
        """Append one entry; chain bookkeeping (seq/prev/id) is added here
        and only here, under the lock, so concurrent writers cannot fork
        the chain."""
        with self._lock:
            prev = self._entries[-1]["id"] if self._entries else GENESIS
            record = {"seq": len(self._entries), "prev": prev, **fields}
            record["id"] = entry_id(record)
            self._entries.append(record)
            return record

    def record_round(
        self,
        *,
        round: int,
        arm: str,
        backend: str,
        hospitals: int,
        cohort: Iterable[int],
        delivered: Iterable[int],
        epsilon: float,
        delta: float,
        sampling_rate: float,
        participation_rate: float,
        noise_multiplier: float,
        bytes_up: float,
        topup: bool = False,
    ) -> list[dict]:
        """One accounted round -> one entry per hospital (all H of them).

        ``epsilon`` is the accountant's cumulative ε AFTER this round's
        composition step; every hospital records it (aggregate-dataset DP:
        the guarantee is shared).  ``bytes_up`` is charged only to
        hospitals whose upload actually left (``delivered``).
        """
        cohort_set, delivered_set = set(cohort), set(delivered)
        out = []
        for i in range(hospitals):
            out.append(self.append(
                kind="round", round=round, hospital=i, arm=arm,
                backend=backend,
                member=i in cohort_set, delivered=i in delivered_set,
                eps=float(epsilon), delta=float(delta),
                sampling_rate=float(sampling_rate),
                participation_rate=float(participation_rate),
                noise_multiplier=float(noise_multiplier),
                bytes_up=float(bytes_up) if i in delivered_set else 0.0,
                topup=bool(topup),
            ))
        return out

    def entries(self) -> list[dict]:
        with self._lock:
            return list(self._entries)

    # -- export ---------------------------------------------------------------

    def to_jsonl(self) -> str:
        header = json.dumps({"type": "ledger-meta", "schema": LEDGER_SCHEMA},
                            sort_keys=True)
        lines = [header] + [json.dumps(e, sort_keys=True)
                            for e in self.entries()]
        return "\n".join(lines) + "\n"

    def write_jsonl(self, path: str | os.PathLike) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())


# -- reading / validation ------------------------------------------------------


def read_entries(path: str | os.PathLike) -> list[dict]:
    """Parse a ledger JSONL file (skipping the meta header line)."""
    out = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise LedgerError(f"line {lineno}: not JSON: {e}") from e
            if rec.get("type") == "ledger-meta":
                continue
            out.append(rec)
    return out


def validate_entries(entries: Sequence[Mapping]) -> dict:
    """Full chain + semantic validation; returns a summary dict.

    Checks, in order, for each entry: ``seq`` is its position, ``prev``
    matches the previous entry's ``id`` (GENESIS for the first), the
    ``id`` recomputes from the record's own content, and per-hospital ε is
    non-decreasing (budgets are only ever spent).  Raises ``LedgerError``
    naming the first entry that fails.
    """
    eps_seen: dict[tuple[str, int], float] = {}
    prev = GENESIS
    for i, rec in enumerate(entries):
        if rec.get("seq") != i:
            raise LedgerError(f"entry {i}: seq {rec.get('seq')} != {i} "
                              "(reordered or deleted entries)")
        if rec.get("prev") != prev:
            raise LedgerError(f"entry {i}: prev {rec.get('prev')!r} breaks "
                              f"the chain (expected {prev!r})")
        if entry_id(rec) != rec.get("id"):
            raise LedgerError(f"entry {i}: content hash mismatch — the "
                              "record was modified after it was chained")
        if rec.get("kind") == "round":
            key = (rec.get("arm", ""), rec["hospital"])
            before = eps_seen.get(key, 0.0)
            if rec["eps"] < before - 1e-12:
                raise LedgerError(
                    f"entry {i}: hospital {rec['hospital']} ε decreased "
                    f"({before} -> {rec['eps']})")
            eps_seen[key] = rec["eps"]
        prev = rec["id"]
    return {
        "entries": len(entries),
        "hospitals": len({r["hospital"] for r in entries
                          if r.get("kind") == "round"}),
        "rounds": len({r["round"] for r in entries
                       if r.get("kind") == "round"}),
        "final_eps": per_hospital_epsilon(entries),
        "head": prev,
    }


def per_hospital_epsilon(entries: Sequence[Mapping]) -> dict[int, float]:
    """Cumulative ε per hospital: the last round entry's ε for each."""
    out: dict[int, float] = {}
    for rec in entries:
        if rec.get("kind") == "round":
            out[rec["hospital"]] = rec["eps"]
    return out


def bytes_by_hospital(entries: Sequence[Mapping]) -> dict[int, float]:
    """Total bytes each hospital shipped, per the ledger."""
    out: dict[int, float] = {}
    for rec in entries:
        if rec.get("kind") == "round":
            out[rec["hospital"]] = out.get(rec["hospital"], 0.0) \
                + rec.get("bytes_up", 0.0)
    return out
