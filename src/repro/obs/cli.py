"""``python -m repro.obs`` — summarize / validate / convert obs artifacts.

Examples::

    # human summary of an export directory (events + ledger)
    python -m repro.obs obs_out

    # CI gate: structural validation of the event stream, the ledger's
    # content-hash chain, and (when present) the Chrome trace
    python -m repro.obs --validate obs_out

    # convert a raw event stream to a Perfetto/chrome://tracing file
    python -m repro.obs --to-chrome obs_out/events.jsonl --out trace.json

Paths may be export directories (containing ``events.jsonl`` /
``ledger.jsonl`` / ``trace.json``) or individual files; directories
validate every artifact they contain.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.obs import (
    EVENTS_FILE,
    LEDGER_FILE,
    TRACE_FILE,
    bytes_by_hospital,
    per_hospital_epsilon,
    read_entries,
    read_events,
    validate_entries,
    validate_events,
    write_chrome_trace,
)
from repro.obs.convert import validate_chrome_trace


def _artifacts(path: Path) -> dict[str, Path]:
    """Map a CLI path to the artifact files it names."""
    if path.is_dir():
        found = {}
        for key, name in (("events", EVENTS_FILE), ("ledger", LEDGER_FILE),
                          ("trace", TRACE_FILE)):
            if (path / name).exists():
                found[key] = path / name
        if not found:
            raise FileNotFoundError(
                f"{path}: no obs artifacts ({EVENTS_FILE}/{LEDGER_FILE}/"
                f"{TRACE_FILE}) found")
        return found
    if path.name == LEDGER_FILE or "ledger" in path.name:
        return {"ledger": path}
    if path.suffix == ".json":
        return {"trace": path}
    return {"events": path}


def _validate_one(path: Path) -> list[str]:
    lines = []
    arts = _artifacts(path)
    if "events" in arts:
        summary = validate_events(read_events(arts["events"]))
        lines.append(f"{arts['events']}: OK — {summary['events']} events "
                     f"{summary['by_type']}")
    if "ledger" in arts:
        summary = validate_entries(read_entries(arts["ledger"]))
        lines.append(
            f"{arts['ledger']}: OK — chain of {summary['entries']} entries "
            f"({summary['hospitals']} hospitals x {summary['rounds']} "
            f"rounds), head {summary['head']}")
    if "trace" in arts:
        summary = validate_chrome_trace(arts["trace"])
        lines.append(f"{arts['trace']}: OK — {summary['trace_events']} "
                     "trace events")
    return lines


def _summarize_one(path: Path) -> list[str]:
    lines = []
    arts = _artifacts(path)
    if "events" in arts:
        events = read_events(arts["events"])
        spans: dict[str, tuple[int, float]] = {}
        counters: dict[str, float] = {}
        for ev in events:
            if ev.get("type") == "span":
                n, s = spans.get(ev["name"], (0, 0.0))
                spans[ev["name"]] = (n + 1, s + ev["dur"])
            elif ev.get("type") == "counter":
                counters[ev["name"]] = ev["total"]
        lines.append(f"{arts['events']}: {len(events)} events")
        for name, (n, total) in sorted(spans.items(),
                                       key=lambda kv: -kv[1][1]):
            lines.append(f"  span    {name:<28} x{n:<6} {total:9.4f}s")
        for name, total in sorted(counters.items()):
            lines.append(f"  counter {name:<28} {total:g}")
    if "ledger" in arts:
        entries = read_entries(arts["ledger"])
        eps = per_hospital_epsilon(entries)
        by = bytes_by_hospital(entries)
        lines.append(f"{arts['ledger']}: {len(entries)} entries")
        for hosp in sorted(eps):
            lines.append(f"  hospital {hosp:<4} eps={eps[hosp]:10.4f}  "
                         f"bytes_up={by.get(hosp, 0.0):12.0f}")
    return lines


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="summarize / validate / convert repro.obs artifacts",
    )
    p.add_argument("paths", nargs="*", type=Path,
                   help="export directories or artifact files")
    p.add_argument("--validate", action="store_true",
                   help="validate event streams, ledger hash chains, and "
                        "Chrome traces; exit 1 on the first violation")
    p.add_argument("--to-chrome", type=Path, metavar="EVENTS",
                   help="convert an events.jsonl to a Chrome trace")
    p.add_argument("--out", type=Path, default=Path("trace.json"),
                   help="output path for --to-chrome")
    args = p.parse_args(argv)

    if args.to_chrome is not None:
        write_chrome_trace(read_events(args.to_chrome), args.out)
        print(f"wrote {args.out}")
        return 0
    if not args.paths:
        p.error("need at least one path (or --to-chrome)")
    rc = 0
    for path in args.paths:
        try:
            lines = (_validate_one if args.validate else _summarize_one)(path)
        except Exception as e:  # noqa: BLE001 - CLI reports, exit code gates
            print(f"{path}: FAILED — {e}", file=sys.stderr)
            rc = 1
            continue
        print("\n".join(lines))
    return rc
