"""The structured tracing core: spans, counters, gauges, one Recorder.

Zero-dependency by design (stdlib only — no numpy, no JAX): the trace
phase of ``repro.population`` and the report layer of ``repro.scenarios``
both import without paying for the JAX stack, and observability must never
change that.  The ``jax.profiler`` bridge is opt-in and imported lazily.

A ``Recorder`` is a process-wide, **thread-safe** event buffer.  Three
typed event kinds, all host-side timestamps only (``time.perf_counter``
relative to the recorder's epoch — recording never forces a device sync):

  * **span**  — a named duration with thread id and nesting ``depth``
    (per-thread stack), recorded as ONE complete event at exit;
  * **counter** — a monotonically accumulated metric; each increment
    records the post-increment ``total`` so the export is a time series;
  * **gauge** — a sampled instantaneous value.

Spans come in two spellings with identical output: the ``span()`` context
manager for straight-line code, and ``now()`` + ``complete()`` for loop
bodies full of ``continue``/``break`` where a ``with`` block would force
re-indenting a whole phase.

The event schema (the JSONL export, one object per line — DESIGN.md §11):

    {"type": "meta", "schema": 1, "pid": ..., "epoch": ...}       # line 1
    {"type": "span", "name", "cat", "ts", "dur", "tid", "depth", "args"}
    {"type": "counter", "name", "ts", "inc", "total", "tid", "args"}
    {"type": "gauge", "name", "ts", "value", "tid", "args"}

``ts``/``dur`` are float seconds since the recorder epoch; the Chrome
trace converter (``repro.obs.convert``) scales to microseconds.  Events
append under one lock in completion order, so a reader never sees a
half-written record; ``ts`` across threads is NOT monotone in file order
(two threads finish spans interleaved) and validation does not pretend
otherwise.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Callable, Iterator, Mapping, Sequence

SCHEMA_VERSION = 1

EVENT_TYPES = ("meta", "span", "counter", "gauge")


class Recorder:
    """Thread-safe, process-wide buffer of spans / counters / gauges."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 jax_profiler: bool = False) -> None:
        self._lock = threading.Lock()
        self._clock = clock
        self._epoch = clock()
        self._events: list[dict] = []
        self._counters: dict[str, float] = {}
        self._tls = threading.local()     # per-thread span stack (depth)
        self._annotation = None           # jax.profiler.TraceAnnotation class
        if jax_profiler:
            self.attach_jax_profiler()
        # the privacy ledger rides the recorder so one enable() call turns
        # on the whole observability story; import here would be circular
        # only in spirit — ledger.py is stdlib-only too
        from repro.obs.ledger import PrivacyLedger

        self.ledger = PrivacyLedger()

    # -- clock ----------------------------------------------------------------

    def now(self) -> float:
        """Seconds since the recorder's epoch (host clock, no device sync)."""
        return self._clock() - self._epoch

    # -- jax bridge -----------------------------------------------------------

    def attach_jax_profiler(self) -> bool:
        """Opt in to bracketing spans with ``jax.profiler.TraceAnnotation``
        so obs spans show up inside XLA profiler traces.  Returns False
        (and stays detached) when JAX is unavailable — the core must never
        require it."""
        try:
            from jax.profiler import TraceAnnotation
        except Exception:  # pragma: no cover - depends on environment
            return False
        self._annotation = TraceAnnotation
        return True

    # -- spans ----------------------------------------------------------------

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "obs",
             **args: Any) -> Iterator[None]:
        """Nestable timed region; one complete event is recorded at exit."""
        depth = self._depth()
        self._tls.depth = depth + 1
        annot = self._annotation(name) if self._annotation else None
        if annot is not None:
            annot.__enter__()
        t0 = self.now()
        try:
            yield
        finally:
            t1 = self.now()
            if annot is not None:
                annot.__exit__(None, None, None)
            self._tls.depth = depth
            self._emit({
                "type": "span", "name": name, "cat": cat,
                "ts": t0, "dur": t1 - t0,
                "tid": threading.get_ident(), "depth": depth,
                "args": args,
            })

    def complete(self, name: str, t_start: float, *, cat: str = "obs",
                 **args: Any) -> None:
        """Record a span that started at ``t_start`` (from ``now()``) and
        ends now — the non-context-manager spelling for loop bodies."""
        t1 = self.now()
        self._emit({
            "type": "span", "name": name, "cat": cat,
            "ts": t_start, "dur": t1 - t_start,
            "tid": threading.get_ident(), "depth": self._depth(),
            "args": args,
        })

    # -- counters / gauges ----------------------------------------------------

    def counter(self, name: str, inc: float = 1.0, **args: Any) -> float:
        """Accumulate ``inc`` onto counter ``name``; returns the new total.
        The event records the post-increment total so the JSONL stream is a
        ready-made time series for the Chrome-trace ``C`` phase."""
        ts = self.now()
        with self._lock:
            total = self._counters.get(name, 0.0) + inc
            self._counters[name] = total
            self._events.append({
                "type": "counter", "name": name, "ts": ts,
                "inc": inc, "total": total,
                "tid": threading.get_ident(), "args": args,
            })
        return total

    def gauge(self, name: str, value: float, **args: Any) -> None:
        self._emit({
            "type": "gauge", "name": name, "ts": self.now(),
            "value": value, "tid": threading.get_ident(), "args": args,
        })

    # -- reads ----------------------------------------------------------------

    def _emit(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)

    def events(self) -> list[dict]:
        """Snapshot of all recorded events (completion order)."""
        with self._lock:
            return list(self._events)

    def counter_totals(self) -> dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def span_totals(self) -> dict[str, tuple[int, float]]:
        """name -> (count, total seconds) over recorded spans."""
        out: dict[str, tuple[int, float]] = {}
        for ev in self.events():
            if ev["type"] != "span":
                continue
            n, s = out.get(ev["name"], (0, 0.0))
            out[ev["name"]] = (n + 1, s + ev["dur"])
        return out

    # -- export ---------------------------------------------------------------

    def meta(self) -> dict:
        return {"type": "meta", "schema": SCHEMA_VERSION,
                "pid": os.getpid(), "epoch": self._epoch}

    def to_jsonl(self) -> str:
        lines = [json.dumps(self.meta(), sort_keys=True)]
        lines += [json.dumps(ev, sort_keys=True) for ev in self.events()]
        return "\n".join(lines) + "\n"

    def write_jsonl(self, path: str | os.PathLike) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())


# -- stream readers / validation ----------------------------------------------


class EventStreamError(ValueError):
    """A JSONL event stream failed structural validation."""


def read_events(path: str | os.PathLike) -> list[dict]:
    """Parse a JSONL event file (including the leading meta line)."""
    events = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise EventStreamError(f"line {lineno}: not JSON: {e}") from e
    return events


_REQUIRED: Mapping[str, tuple[str, ...]] = {
    "meta": ("schema", "pid"),
    "span": ("name", "ts", "dur", "tid", "depth", "args"),
    "counter": ("name", "ts", "inc", "total", "tid"),
    "gauge": ("name", "ts", "value", "tid"),
}


def validate_events(events: Sequence[Mapping]) -> dict:
    """Structural validation of an event stream; returns a summary dict.

    Checks: known event types, required fields, non-negative durations and
    depths, per-name counter totals consistent with the per-event
    increments.  Raises ``EventStreamError`` on the first violation.
    """
    totals: dict[str, float] = {}
    n_by_type: dict[str, int] = {}
    for i, ev in enumerate(events):
        etype = ev.get("type")
        if etype not in EVENT_TYPES:
            raise EventStreamError(f"event {i}: unknown type {etype!r}")
        missing = [k for k in _REQUIRED[etype] if k not in ev]
        if missing:
            raise EventStreamError(
                f"event {i} ({etype}): missing fields {missing}")
        n_by_type[etype] = n_by_type.get(etype, 0) + 1
        if etype == "span":
            if ev["dur"] < 0:
                raise EventStreamError(
                    f"event {i}: span {ev['name']!r} has negative duration")
            if ev["depth"] < 0:
                raise EventStreamError(
                    f"event {i}: span {ev['name']!r} has negative depth")
        elif etype == "counter":
            # counters are monotone per name and each event carries its
            # post-increment total; within one thread's stream the totals
            # must chain.  Across threads the totals interleave but remain
            # consistent because increments happen under the recorder lock
            # in file order.
            expect = totals.get(ev["name"], 0.0) + ev["inc"]
            if abs(expect - ev["total"]) > 1e-9 * max(1.0, abs(expect)):
                raise EventStreamError(
                    f"event {i}: counter {ev['name']!r} total {ev['total']} "
                    f"does not chain from running sum {expect}")
            totals[ev["name"]] = ev["total"]
    return {"events": len(events), "by_type": n_by_type,
            "counter_totals": totals}
