"""repro.obs — unified tracing, metrics, and the per-hospital privacy ledger.

One process-wide switch turns the whole observability story on: spans and
counters from every subsystem (fused training rounds, SecAgg encode/decode,
the serving tier's admit/decode hot path, population trace/solve, sweep
cells) feed one thread-safe ``Recorder``, and privacy-relevant round
completions additionally append to a hash-chained ``PrivacyLedger``
(DESIGN.md §11).

Recording is OFF by default and a disabled recorder is a structural no-op:
``span()`` returns a shared ``nullcontext``, ``counter()``/``gauge()``/
``ledger_round()`` return immediately, and nothing on any hot path
dispatches extra programs or syncs a device (``tests/test_obs.py`` pins
that enabling recording adds ZERO jit dispatches per fused round).

    import repro.obs as obs

    with obs.recording() as rec:                 # scoped enable
        report = arms.run("decaph", model, silos, cfg, backend="sim",
                          nodes=nodes)
        obs.export("obs_out")                    # events + ledger + trace

    # or process-wide, e.g. behind a CLI flag:
    obs.enable(jax_profiler=True)                # spans bracket XLA traces

Artifacts (``obs.export(dir)``):

  * ``events.jsonl``  — the raw structured event stream (schema in
    ``recorder.py``);
  * ``ledger.jsonl``  — the append-only privacy ledger with its content
    hash chain (schema in ``ledger.py``);
  * ``trace.json``    — Chrome-trace/Perfetto conversion of the events.

``python -m repro.obs`` summarizes, validates, or converts any of these.
"""

from __future__ import annotations

import contextlib
import os
import threading
from pathlib import Path
from typing import Any, Iterable, Iterator

from repro.obs.convert import chrome_trace, write_chrome_trace
from repro.obs.ledger import (
    LedgerError,
    PrivacyLedger,
    bytes_by_hospital,
    per_hospital_epsilon,
    read_entries,
    validate_entries,
)
from repro.obs.recorder import (
    EventStreamError,
    Recorder,
    read_events,
    validate_events,
)

_LOCK = threading.Lock()
_RECORDER: Recorder | None = None

# One shared no-op context for the disabled path: span() must cost a global
# read and a return, nothing more.
_NULL = contextlib.nullcontext()


# -- process-wide switch -------------------------------------------------------


def recorder() -> Recorder | None:
    """The active process-wide recorder, or None when recording is off."""
    return _RECORDER


def enable(rec: Recorder | None = None, *,
           jax_profiler: bool = False) -> Recorder:
    """Install ``rec`` (or a fresh ``Recorder``) process-wide."""
    global _RECORDER
    with _LOCK:
        if rec is None:
            rec = Recorder(jax_profiler=jax_profiler)
        elif jax_profiler:
            rec.attach_jax_profiler()
        _RECORDER = rec
    return rec


def disable() -> Recorder | None:
    """Uninstall and return the active recorder (None if none was)."""
    global _RECORDER
    with _LOCK:
        rec, _RECORDER = _RECORDER, None
    return rec


@contextlib.contextmanager
def recording(rec: Recorder | None = None, *,
              jax_profiler: bool = False) -> Iterator[Recorder]:
    """Scoped recording: installs a recorder, restores the previous one on
    exit (so tests and nested tools cannot leak global state)."""
    global _RECORDER
    with _LOCK:
        prev = _RECORDER
    rec = enable(rec, jax_profiler=jax_profiler)
    try:
        yield rec
    finally:
        with _LOCK:
            _RECORDER = prev


# -- recording API (no-ops when disabled) --------------------------------------


def span(name: str, *, cat: str = "obs", **args: Any):
    """Nestable timed region; a shared no-op context when recording is off."""
    rec = _RECORDER
    return rec.span(name, cat=cat, **args) if rec is not None else _NULL


def now() -> float | None:
    """Span start timestamp for the ``complete()`` spelling; None = off."""
    rec = _RECORDER
    return rec.now() if rec is not None else None


def complete(name: str, t_start: float | None, *, cat: str = "obs",
             **args: Any) -> None:
    """Close a span opened with ``now()``; no-op when recording is off (or
    when ``t_start`` was taken while it was off)."""
    rec = _RECORDER
    if rec is not None and t_start is not None:
        rec.complete(name, t_start, cat=cat, **args)


def counter(name: str, inc: float = 1.0, **args: Any) -> None:
    rec = _RECORDER
    if rec is not None:
        rec.counter(name, inc, **args)


def gauge(name: str, value: float, **args: Any) -> None:
    rec = _RECORDER
    if rec is not None:
        rec.gauge(name, value, **args)


def ledger_round(arm: Any, *, round: int, backend: str,
                 cohort: Iterable[int], delivered: Iterable[int],
                 bytes_up: float, topup: bool = False) -> None:
    """Append one accounted round to the privacy ledger (one entry per
    hospital).  ``arm`` is duck-typed (any object with ``name``, ``h``,
    ``cfg``, ``epsilon()`` — i.e. a ``repro.arms`` arm) so the obs core
    stays import-free of the JAX stack.  Call AFTER ``arm.account()``:
    the ledger records the post-round cumulative ε."""
    rec = _RECORDER
    if rec is None:
        return
    cfg = arm.cfg
    rec.ledger.record_round(
        round=round, arm=arm.name, backend=backend, hospitals=arm.h,
        cohort=cohort, delivered=delivered,
        epsilon=arm.epsilon(), delta=cfg.dp.delta,
        sampling_rate=getattr(arm, "rate", 0.0),
        participation_rate=cfg.participation_rate,
        noise_multiplier=cfg.dp.noise_multiplier,
        bytes_up=bytes_up, topup=topup,
    )


# -- artifact export -----------------------------------------------------------

EVENTS_FILE = "events.jsonl"
LEDGER_FILE = "ledger.jsonl"
TRACE_FILE = "trace.json"


def export(out_dir: str | os.PathLike,
           rec: Recorder | None = None) -> dict[str, Path]:
    """Write events.jsonl + ledger.jsonl + trace.json into ``out_dir``.

    Uses the active recorder when ``rec`` is not given; raises if neither
    exists (exporting nothing silently would defeat the audit trail).
    """
    rec = rec if rec is not None else _RECORDER
    if rec is None:
        raise RuntimeError("obs.export: recording is not enabled and no "
                           "recorder was passed")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    paths = {
        "events": out / EVENTS_FILE,
        "ledger": out / LEDGER_FILE,
        "trace": out / TRACE_FILE,
    }
    rec.write_jsonl(paths["events"])
    rec.ledger.write_jsonl(paths["ledger"])
    write_chrome_trace(rec.events(), paths["trace"])
    return paths


__all__ = [
    "EventStreamError",
    "LedgerError",
    "PrivacyLedger",
    "Recorder",
    "bytes_by_hospital",
    "chrome_trace",
    "complete",
    "counter",
    "disable",
    "enable",
    "export",
    "gauge",
    "ledger_round",
    "now",
    "per_hospital_epsilon",
    "read_entries",
    "read_events",
    "recorder",
    "recording",
    "span",
    "validate_entries",
    "validate_events",
    "write_chrome_trace",
]
