"""JSONL event stream -> Chrome trace (``chrome://tracing`` / Perfetto).

The converter targets the Trace Event Format's JSON-object flavour:
``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with

  * spans as complete (``"ph": "X"``) events — ``ts``/``dur`` in
    microseconds, ``pid`` from the stream's meta line, ``tid`` the
    recording thread;
  * counters as ``"ph": "C"`` events carrying the post-increment total
    (the recorder emits totals precisely so this series renders as the
    familiar monotone staircase);
  * gauges as ``"ph": "C"`` too (Perfetto has no separate gauge phase);
  * thread metadata (``"ph": "M"`` / ``thread_name``) naming each thread
    by its first span so the timeline is readable without decoding raw
    thread ids.

Open the output via ``chrome://tracing`` ("Load") or https://ui.perfetto.dev
("Open trace file") — see DESIGN.md §11.

Stdlib-only, like the rest of the obs core.
"""

from __future__ import annotations

import json
import os
from typing import Mapping, Sequence


def chrome_trace(events: Sequence[Mapping]) -> dict:
    """Convert recorder events (see ``recorder.py`` schema) to a Chrome
    trace dict.  Unknown event types are skipped — the converter must keep
    working on streams from newer schema versions."""
    pid = os.getpid()
    out: list[dict] = []
    thread_names: dict[int, str] = {}
    for ev in events:
        etype = ev.get("type")
        if etype == "meta":
            pid = ev.get("pid", pid)
        elif etype == "span":
            tid = ev.get("tid", 0)
            thread_names.setdefault(tid, f"thread ({ev['name']})")
            out.append({
                "name": ev["name"], "ph": "X", "cat": ev.get("cat", "obs"),
                "ts": ev["ts"] * 1e6, "dur": ev["dur"] * 1e6,
                "pid": pid, "tid": tid, "args": ev.get("args", {}),
            })
        elif etype == "counter":
            out.append({
                "name": ev["name"], "ph": "C", "cat": "counter",
                "ts": ev["ts"] * 1e6, "pid": pid, "tid": 0,
                "args": {ev["name"]: ev["total"]},
            })
        elif etype == "gauge":
            out.append({
                "name": ev["name"], "ph": "C", "cat": "gauge",
                "ts": ev["ts"] * 1e6, "pid": pid, "tid": 0,
                "args": {ev["name"]: ev["value"]},
            })
    meta = [
        {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
         "args": {"name": name}}
        for tid, name in sorted(thread_names.items())
    ]
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def write_chrome_trace(events: Sequence[Mapping],
                       path: str | os.PathLike) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f)


def validate_chrome_trace(path: str | os.PathLike) -> dict:
    """Light structural check of an exported trace file."""
    with open(path) as f:
        payload = json.load(f)
    evs = payload.get("traceEvents")
    if not isinstance(evs, list):
        raise ValueError(f"{path}: no traceEvents list")
    for i, ev in enumerate(evs):
        if "ph" not in ev or "pid" not in ev:
            raise ValueError(f"{path}: traceEvents[{i}] missing ph/pid")
        if ev["ph"] == "X" and (ev.get("dur", -1.0) < 0 or "ts" not in ev):
            raise ValueError(f"{path}: traceEvents[{i}] bad complete event")
    return {"trace_events": len(evs)}
