"""Production mesh factory (function, not constant — never touches jax device
state at import time)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 per pod; 2 pods multi-pod.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    DeCaPH maps hospitals onto ("pod", "data") — the secure-aggregation sum is
    the gradient reduction over those axes (DESIGN.md §3).  The `shard`
    backend accepts these meshes directly
    (``ShardedRunner(mesh=make_production_mesh(multi_pod=True))``): hospitals
    shard over ("pod", "data"), model-parallel params over ("model",).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires XLA host device count >= product)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_host_data_mesh(n_data: int | None = None):
    """1-D ("data",) mesh over ``n_data`` devices (default: all available).

    The federated SPMD backend (``launch.federated.ShardedRunner``) shards
    the fused cohort round-step's *example* axis over it; the tabular-scale
    params stay replicated.  On CPU CI the devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = n_data or jax.device_count()
    return jax.make_mesh((n,), ("data",))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the batch/participant dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "model")
