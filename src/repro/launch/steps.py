"""Sharded step factories: one place that builds the jit-able programs the
train driver, the serve driver and the dry-run all lower.

Three program kinds per (arch, shape):

  * train_step  — the full DeCaPH round body: per-example clipped grads
    (microbatched scan), aggregate noise, optimizer update.  The gradient
    reduce over ("pod","data") IS the secure-aggregation dataflow.
  * prefill     — forward -> logits (+ the compile-time proof the prefill
    sharding is coherent).
  * serve_step  — one-token decode against a seq_len KV cache.

Everything is built from ShapeDtypeStructs; no parameters are materialised.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.configs.shapes import input_specs
from repro.core import dp as dp_lib
from repro.launch import sharding as sh
from repro.models import transformer as tf
from repro.models.layers import activation_sharding
from repro.optim import get_optimizer

PyTree = Any


@dataclasses.dataclass
class ShardedProgram:
    """A lowered-ready program plus its arg specs (all SDS)."""

    fn: Any                       # callable(*args)
    args_sds: tuple               # ShapeDtypeStructs with .sharding set
    kind: str                     # train | prefill | decode
    cfg: Any
    meta: dict


def _with_shardings(sds_tree: PyTree, spec_tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sp),
        sds_tree, spec_tree,
    )


def _dp_mode(cfg, dp_override: str | None) -> str:
    if dp_override is not None:
        return dp_override
    return "per_example"  # paper-faithful default


def build_train_program(cfg, shape_name: str, mesh,
                        policy: sh.ShardingPolicy | None = None,
                        dp_mode: str | None = None) -> ShardedProgram:
    policy = policy or sh.ShardingPolicy()
    cfg, batch_sds, kind = input_specs(cfg, shape_name)
    assert kind == "train"
    shape = INPUT_SHAPES[shape_name]
    global_batch = shape["global_batch"]
    mode = _dp_mode(cfg, dp_mode)

    # cfg.moe_groups aligns token groups with data shards for local routing
    if cfg.n_experts:
        cfg = cfg.replace(moe_groups=mesh.shape["data"])

    params_sds = jax.eval_shape(lambda k: tf.init(cfg, k), jax.random.key(0))
    pspecs = sh.param_specs(params_sds, mesh, policy)
    opt = get_optimizer(cfg.optimizer, cfg.lr)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    ospecs = sh.opt_state_specs(cfg.optimizer, params_sds, pspecs, opt_sds, mesh)
    bspecs = sh.batch_specs(batch_sds, mesh, policy)
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    data_size = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            data_size *= mesh.shape[a]
    # cfg.dp_microbatch is the GLOBAL microbatch per scan step.  When it
    # covers the data axes the microbatch shards one example per data shard;
    # below that (the giant models) the batch stays unsharded and the
    # *sequence* shards over data instead (activation_rules per_example).
    micro = max(1, min(cfg.dp_microbatch, global_batch))
    rules = sh.activation_rules(
        mesh, policy, global_batch=global_batch,
        per_example=(mode == "per_example" and micro % data_size != 0),
    )

    constrain = lambda tree: jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, pspecs
    )

    def train_step(params, opt_state, batch, rng):
        with activation_sharding(rules):
            if mode == "none":
                def batched_loss(p):
                    return tf.loss_fn(cfg, p, batch)

                loss, grads = jax.value_and_grad(batched_loss)(params)
                grads = constrain(grads)
            elif mode == "ghost":
                # Beyond-paper optimized DeCaPH step: exact per-example norms
                # from ONE batched backward (collector custom-vjp), then one
                # clip-weighted backward — see core/ghost.py and §Perf.
                from repro.core.ghost import ghost_clipped_grad_sum

                g_sum, loss, _ = ghost_clipped_grad_sum(
                    cfg, params, batch, clip_norm=cfg.dp_clip,
                    chunk_size=min(cfg.ghost_chunk, global_batch),
                    constrain_grads=constrain,
                )
                g_sum = dp_lib.tree_add_noise(
                    g_sum, jax.random.wrap_key_data(rng),
                    clip_norm=cfg.dp_clip, noise_multiplier=cfg.dp_sigma,
                    n_shares=1,
                )
                grads = constrain(jax.tree_util.tree_map(
                    lambda x: x / float(global_batch), g_sum
                ))
            else:
                g_sum, loss = dp_lib.per_example_clipped_grad_sum(
                    lambda p, ex: tf.per_example_loss_fn(cfg, p, ex),
                    params, batch,
                    clip_norm=cfg.dp_clip,
                    microbatch_size=max(1, micro),
                    constrain_grads=constrain,
                )
                g_sum = dp_lib.tree_add_noise(
                    g_sum, jax.random.wrap_key_data(rng),
                    clip_norm=cfg.dp_clip, noise_multiplier=cfg.dp_sigma,
                    n_shares=1,
                )
                grads = jax.tree_util.tree_map(
                    lambda x: x / float(global_batch), g_sum
                )
                grads = constrain(grads)
            new_params, new_opt = opt.update(grads, opt_state, params)
            new_params = constrain(new_params)
            return new_params, new_opt, {"loss": loss}

    args_sds = (
        _with_shardings(params_sds, pspecs),
        _with_shardings(opt_sds, ospecs),
        _with_shardings(batch_sds, bspecs),
        jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=sh.replicated(mesh)),
    )
    meta = {"global_batch": global_batch, "seq_len": shape["seq_len"],
            "dp_mode": mode, "microbatch": micro}
    return ShardedProgram(train_step, args_sds, "train", cfg, meta)


def build_prefill_program(cfg, shape_name: str, mesh,
                          policy: sh.ShardingPolicy | None = None
                          ) -> ShardedProgram:
    policy = policy or sh.ShardingPolicy()
    cfg, batch_sds, kind = input_specs(cfg, shape_name)
    assert kind == "prefill"
    shape = INPUT_SHAPES[shape_name]
    if cfg.n_experts:
        cfg = cfg.replace(moe_groups=mesh.shape["data"])
    params_sds = jax.eval_shape(lambda k: tf.init(cfg, k), jax.random.key(0))
    pspecs = sh.param_specs(params_sds, mesh, policy)
    bspecs = sh.batch_specs(batch_sds, mesh, policy)
    rules = sh.activation_rules(mesh, policy, global_batch=shape["global_batch"])

    def prefill(params, batch):
        with activation_sharding(rules):
            logits, _ = tf.forward(cfg, params, batch)
            return logits

    args_sds = (
        _with_shardings(params_sds, pspecs),
        _with_shardings(batch_sds, bspecs),
    )
    meta = {"global_batch": shape["global_batch"], "seq_len": shape["seq_len"]}
    return ShardedProgram(prefill, args_sds, "prefill", cfg, meta)


def build_decode_program(cfg, shape_name: str, mesh,
                         policy: sh.ShardingPolicy | None = None
                         ) -> ShardedProgram:
    policy = policy or sh.ShardingPolicy()
    cfg, specs, kind = input_specs(cfg, shape_name)
    assert kind == "decode"
    shape = INPUT_SHAPES[shape_name]
    b = shape["global_batch"]
    if cfg.n_experts:
        groups = mesh.shape["data"] if b % mesh.shape["data"] == 0 else 1
        cfg = cfg.replace(moe_groups=groups)
    params_sds = jax.eval_shape(lambda k: tf.init(cfg, k), jax.random.key(0))
    pspecs = sh.param_specs(params_sds, mesh, policy)
    cache_sp = sh.cache_specs(specs["cache"], mesh, policy, global_batch=b)
    tok_spec = sh.batch_specs({"tokens": specs["tokens"]}, mesh, policy)["tokens"]
    rules = sh.activation_rules(
        mesh, policy, global_batch=b,
        shard_kv_seq=(b % mesh.shape["data"] != 0),
    )

    def serve_step(params, cache, tokens, index):
        with activation_sharding(rules):
            logits, new_cache = tf.decode_step(cfg, params, cache, tokens, index)
            return logits, new_cache

    args_sds = (
        _with_shardings(params_sds, pspecs),
        _with_shardings(specs["cache"], cache_sp),
        jax.ShapeDtypeStruct(specs["tokens"].shape, specs["tokens"].dtype,
                             sharding=tok_spec),
        jax.ShapeDtypeStruct((), jnp.int32, sharding=sh.replicated(mesh)),
    )
    meta = {"global_batch": b, "seq_len": shape["seq_len"]}
    return ShardedProgram(serve_step, args_sds, "decode", cfg, meta)


def build_program(cfg, shape_name: str, mesh, policy=None,
                  dp_mode: str | None = None) -> ShardedProgram:
    kind = INPUT_SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_program(cfg, shape_name, mesh, policy, dp_mode)
    if kind == "prefill":
        return build_prefill_program(cfg, shape_name, mesh, policy)
    return build_decode_program(cfg, shape_name, mesh, policy)
