"""End-to-end DeCaPH training driver (pod-scale path).

Runs the SPMD DeCaPH train step on real devices (CPU here; the mesh shape
adapts to the available device count).  Hospitals map onto the data axis —
each data shard's examples come from one silo's stream — and the gradient
all-reduce is the secure-aggregation sum (DESIGN.md §3).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 100 --batch 8 --seq 256 [--scale 0.1] [--no-dp]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHITECTURES, get_config, get_smoke_config
from repro.configs.base import dense_stack
from repro.core.accountant import RDPAccountant
from repro.data import make_lm_stream
from repro.launch import sharding as sh
from repro.launch.steps import ShardedProgram
from repro.models import transformer as tf
from repro.models.layers import activation_sharding
from repro.core import dp as dp_lib
from repro.optim import get_optimizer


def scaled_config(arch: str, scale: str):
    if scale == "full":
        return get_config(arch)
    if scale == "smoke":
        return get_smoke_config(arch)
    if scale == "100m":
        # ~100M-param member of the arch family for the e2e example
        cfg = get_smoke_config(arch)
        return cfg.replace(
            d_model=512, n_heads=8, n_kv_heads=4, head_dim=64, d_ff=1536,
            vocab_size=8192,
            stack=dense_stack(12) if cfg.arch_type == "dense" else cfg.stack,
            n_layers=12 if cfg.arch_type == "dense" else cfg.n_layers,
        )
    raise ValueError(scale)


def build_mesh_for_host():
    n = len(jax.devices())
    model = 1
    data = n
    while data % 2 == 0 and model < 2 and data > 1:
        if data // 2 >= 1:
            data //= 2
            model *= 2
    return jax.make_mesh((data, model), ("data", "model"))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(ARCHITECTURES), default="smollm-360m")
    p.add_argument("--scale", default="smoke", choices=["full", "smoke", "100m"])
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--no-dp", action="store_true")
    p.add_argument("--clip", type=float, default=1.0)
    p.add_argument("--sigma", type=float, default=0.8)
    p.add_argument("--eps-budget", type=float, default=None)
    p.add_argument("--n-silos", type=int, default=4,
                   help="synthetic hospitals feeding the data shards")
    p.add_argument("--lr", type=float, default=None)
    p.add_argument("--log-every", type=int, default=5)
    p.add_argument("--checkpoint", default=None)
    args = p.parse_args()

    cfg = scaled_config(args.arch, args.scale)
    if args.lr:
        cfg = cfg.replace(lr=args.lr)
    mesh = build_mesh_for_host()
    policy = sh.ShardingPolicy()
    print(f"mesh={dict(mesh.shape)} arch={args.arch} scale={args.scale} "
          f"dp={'off' if args.no_dp else 'on'}")

    key = jax.random.key(0)
    params = tf.init(cfg, key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.1f}M")
    opt = get_optimizer(cfg.optimizer, cfg.lr)
    opt_state = opt.init(params)

    pspecs = sh.param_specs(params, mesh, policy)
    params = jax.device_put(params, pspecs)
    rules = sh.activation_rules(mesh, policy, global_batch=args.batch)
    constrain = lambda tree: jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, pspecs
    )

    # Every silo contributes batch/n_silos examples per round (one DeCaPH
    # round == one step); streams differ per silo (covariate shift via seed).
    streams = [
        make_lm_stream(cfg.vocab_size, args.seq, seed=17 * i + 1)
        for i in range(args.n_silos)
    ]
    acct = None
    if not args.no_dp:
        acct = RDPAccountant(
            sampling_rate=min(1.0, args.batch / (args.batch * 50)),
            noise_multiplier=args.sigma, delta=1e-5,
        )

    def train_step(params, opt_state, batch, rng):
        with activation_sharding(rules):
            if args.no_dp:
                loss, grads = jax.value_and_grad(
                    lambda p: tf.loss_fn(cfg, p, batch)
                )(params)
            else:
                g_sum, loss = dp_lib.per_example_clipped_grad_sum(
                    lambda p, ex: tf.per_example_loss_fn(cfg, p, ex),
                    params, batch, clip_norm=args.clip,
                    microbatch_size=max(1, args.batch // 2),
                    constrain_grads=constrain,
                )
                g_sum = dp_lib.tree_add_noise(
                    g_sum, rng, clip_norm=args.clip,
                    noise_multiplier=args.sigma, n_shares=1,
                )
                grads = jax.tree_util.tree_map(
                    lambda x: x / float(args.batch), g_sum
                )
            new_p, new_o = opt.update(grads, opt_state, params)
            return new_p, new_o, loss

    step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    t0 = time.time()
    for step in range(args.steps):
        per_silo = max(1, args.batch // args.n_silos)
        parts = [s.batch(step, per_silo) for s in streams]
        batch = {
            k: jnp.asarray(np.concatenate([p[k] for p in parts]))
            for k in parts[0]
        }
        rng = jax.random.fold_in(key, 1000 + step)
        params, opt_state, loss = step_jit(params, opt_state, batch, rng)
        if acct:
            acct.step()
        if step % args.log_every == 0 or step == args.steps - 1:
            eps = acct.epsilon() if acct else 0.0
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"eps {eps:.3f} ({time.time()-t0:.1f}s)")
        if acct and args.eps_budget and acct.epsilon() > args.eps_budget:
            print(f"privacy budget {args.eps_budget} reached at step {step}")
            break
    if args.checkpoint:
        save_checkpoint(args.checkpoint, jax.device_get(params), step=args.steps)
        print("checkpoint written:", args.checkpoint)


if __name__ == "__main__":
    main()
