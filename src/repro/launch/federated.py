"""The ``shard`` backend: SPMD execution of the fused cohort round-step.

This is the registry module that carries the fused hot path (DESIGN.md §7)
into the SPMD world of ``launch/``: ``ShardedRunner`` defaults to a 1-D
``data`` mesh via ``launch.mesh.make_host_data_mesh`` and also accepts the
full production ``("pod", "data", "model")`` meshes from
``launch.mesh.make_production_mesh`` / ``make_debug_mesh``; either way it
executes every fused cohort program — the exact same traced function the
idealized backend jits — under GSPMD with explicit placements:

  * on a 1-D mesh the stacked cohort batches (``fused.stack_poisson``
    output) are sharded along the *example* axis over the mesh's data axes —
    the cohort pad is rounded up to the data-axis size first, which is free
    because masks keep pad rows exactly inert;
  * on a pod mesh the *hospital* (participant) axis shards over the combined
    ``("pod", "data")`` axes instead, whenever the cohort size divides them —
    each pod owns a slice of the federation and the in-jit cohort reduction
    (DeCaPH's SecAgg-summed aggregate) lowers to cross-pod all-reduces, never
    a host gather.  The participant axis is NEVER padded: a padded slot would
    add a phantom per-participant noise share.  Non-divisible cohorts fall
    back to the example-axis rule above;
  * on meshes with a ``model`` axis, model-parallel params shard over
    ``("model",)`` per the ``launch/sharding.py`` logical-axis rules
    (mlp/qheads/kv_heads/vocab → "model"; tabular params have no encoded
    axes and stay replicated);
  * every other operand (noise salts, cohort index vectors, control-variate
    stacks) is replicated, matching the ``launch/sharding.py`` fallback rule
    for non-divisible leaves;
  * outputs get explicit replicated out-shardings: the per-participant
    payload stacks and the in-jit reduced aggregate come back whole, so the
    arm's eager aggregation math is identical to the idealized backend's.

The gradient reductions over the sharded example axis lower to all-reduces
over ``data`` — exactly the collective DeCaPH's secure sum maps onto in the
production mesh story (DESIGN.md §3).  Partitioned reductions re-associate
float math, so ``shard`` sits in its own ``bit_exact_group`` ("spmd"):
against the host backends it agrees to the fused-vs-loop tolerance class
(atol 1e-5 on the tabular presets; see ``tests/test_backends.py``), not bit
for bit.

Capability record: fused-only (there is no per-participant loop to fall
back to) and no SecAgg (the point of the fast path is that payloads never
leave the device; a spec asking for ciphertext uploads here fails at
validation time instead of silently shipping plaintext).
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.arms import fused
from repro.arms.backends import (
    BackendInfo,
    RunSetup,
    compatibility_error,
    register_backend,
)
from repro.arms.runners import LocalRunner
from repro.launch.mesh import data_axes, make_host_data_mesh
from repro.launch.sharding import ShardingPolicy, param_specs

_DEVICE_HINT = (
    "needs >= 2 XLA devices; on CPU launch with "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8"
)


class MeshExecutor:
    """Re-dispatches ``instrumented_jit`` cohort programs onto a mesh.

    Installed around each fused round via ``fused.execution_context``; the
    wrapper hands over ``(raw fn, jit kwargs, args)`` and this executor
    places the operands (example axis sharded for arrays marked by
    ``stack_poisson``, everything else replicated), stages the program once
    per wrapper with explicit replicated out-shardings, and launches it.
    Python-int operands (static argnums) pass through untouched.
    """

    def __init__(self, mesh) -> None:
        self.mesh = mesh
        axes = data_axes(mesh)
        self._axis_entry = axes if len(axes) > 1 else axes[0]
        self._pod_mesh = len(axes) > 1  # ("pod","data",...) production shape
        self.data_size = int(np.prod([mesh.shape[a] for a in axes]))
        self._replicated = NamedSharding(mesh, P())
        # model-parallel param placement (pod meshes): TP only — FSDP would
        # split the embed dim over the same axes that carry hospitals
        self._param_policy = (
            ShardingPolicy(fsdp=False, tp=True)
            if "model" in mesh.axis_names else None
        )
        self._marks: dict[int, tuple[Any, NamedSharding]] = {}
        self._staged: dict[Any, Any] = {}
        self.sharded_puts = 0  # placements that actually split an axis
        self.participant_shards = 0  # cohorts split over ("pod","data")
        self.param_shards = 0  # param leaves placed over ("model",)

    # -- hooks consumed by repro.arms.fused -----------------------------------

    def round_pad(self, pad: int) -> int:
        """Round a cohort pad up to a multiple of the data-axis size."""
        return -(-pad // self.data_size) * self.data_size

    def mark(self, arr: np.ndarray, axis: int) -> None:
        """Declare ``arr`` a cohort batch to shard along ``axis``.

        Pod meshes prefer splitting the *participant* axis (0) over the
        combined ``("pod","data")`` axes — but only when the cohort size
        divides them exactly: unlike the example axis (mask-inert pad rows),
        a padded participant slot would draw its own DP noise share, so the
        fallback is the example-axis split, never padding.
        """
        if self._pod_mesh and arr.shape[0] % self.data_size == 0:
            spec = P(*[self._axis_entry if d == 0 else None
                       for d in range(arr.ndim)])
            self._marks[id(arr)] = (arr, NamedSharding(self.mesh, spec))
            self.participant_shards += 1
            return
        if arr.shape[axis] % self.data_size:
            return  # replication fallback (same rule as launch/sharding.py)
        spec = P(*[self._axis_entry if d == axis else None
                   for d in range(arr.ndim)])
        self._marks[id(arr)] = (arr, NamedSharding(self.mesh, spec))

    def mark_params(self, params) -> None:
        """Declare model params for TP placement over the ``model`` axis.

        No-op on meshes without a ``model`` axis.  Leaves whose keys encode
        no shardable logical axes (all tabular models) resolve to replicated
        specs and are skipped — placement falls through to the default.
        """
        if self._param_policy is None:
            return
        specs = param_specs(params, self.mesh, self._param_policy)
        for leaf, sh in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(specs)):
            if sh.spec != P(*([None] * leaf.ndim)):
                self._marks[id(leaf)] = (leaf, sh)
                self.param_shards += 1

    def begin_round(self) -> None:
        self._marks.clear()

    def execute(self, wrapper, args, kwargs):
        staged = self._staged.get(wrapper)
        if staged is None:
            # donation is dropped: donated buffers cannot be re-placed with
            # device_put round after round, and the state stacks involved
            # (scaffold's control variates) are tabular-scale
            jkw = {k: v for k, v in wrapper.jit_kwargs.items()
                   if k != "donate_argnums"}
            staged = jax.jit(wrapper.fn, out_shardings=self._replicated,
                             **jkw)
            self._staged[wrapper] = staged
        placed = jax.tree_util.tree_map(self._place_leaf, (args, kwargs))
        return staged(*placed[0], **placed[1])

    def _place_leaf(self, leaf):
        if isinstance(leaf, (bool, int, float)):
            return leaf  # static argnums stay python scalars
        mark = self._marks.get(id(leaf))
        if mark is not None:
            self.sharded_puts += 1
            return jax.device_put(leaf, mark[1])
        return jax.device_put(leaf, self._replicated)


@register_backend(BackendInfo(
    name="shard",
    supports_fused=True,
    supports_secagg=False,
    supports_sim_time=False,
    fused_only=True,
    bit_exact_group="spmd",
    device_requirements=_DEVICE_HINT,
    description="SPMD execution of the fused cohort round-step on a device "
                "mesh (example axis sharded over data, params replicated)",
))
class ShardedRunner(LocalRunner):
    """Idealized round schedule, SPMD round numerics.

    Inherits the lockstep cohort/round loop from ``LocalRunner`` (everyone
    online, communication free) and overrides the fused-program seam so the
    cohort step runs sharded on the mesh.
    """

    def __init__(self, topo=None, *, mesh=None) -> None:
        super().__init__(topo=topo)
        if mesh is None:
            reason = self.available()
            if reason is not None:
                raise RuntimeError(f"backend 'shard' unavailable: {reason}")
            mesh = make_host_data_mesh()
        self.mesh = mesh
        self.executor = MeshExecutor(mesh)

    @classmethod
    def from_setup(cls, setup: RunSetup) -> "ShardedRunner":
        return cls(topo=setup.topo, mesh=setup.mesh)

    @classmethod
    def available(cls) -> str | None:
        if jax.device_count() < 2:
            return _DEVICE_HINT
        return None

    def run(self, arm):
        # belt and braces under direct construction: repro.arms.run already
        # negotiates these pairs — same rules, single source of truth
        err = compatibility_error(
            type(arm), self.info, use_secagg=arm.cfg.use_secagg,
            fused_rounds=arm.cfg.fused_rounds,
        )
        if err is not None:
            raise ValueError(err)
        return super().run(arm)

    def _fused_round(self, arm, params, active, t, rng, *,
                     need_payloads, need_reduced):
        self.executor.begin_round()
        self.executor.mark_params(params)
        with fused.execution_context(self.executor):
            fr = super()._fused_round(arm, params, active, t, rng,
                                      need_payloads=need_payloads,
                                      need_reduced=need_reduced)
        if fr is None:
            raise RuntimeError(
                f"arm {arm.name!r} fell back to the per-participant loop "
                "under the fused-only 'shard' backend"
            )
        return fr
