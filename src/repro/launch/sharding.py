"""Logical-axis -> mesh sharding rules (GSPMD via NamedSharding).

Param specs are derived from the axis names encoded in parameter keys
(``models.layers.pname``), so they cannot diverge from the param tree.
Policy:

  * tensor parallel ("model"): mlp, qheads, kv_heads, vocab, inner (Mamba),
    experts (expert parallelism);
  * FSDP ("data", optionally +"pod"): the embed dim of every weight — ZeRO-3
    style; gradient reduce-scatters over data are exactly DeCaPH's secure sum;
  * anything non-divisible falls back to replication (e.g. smollm's 15 heads
    stay replicated while its flattened 960-wide q projection shards).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import logical_axes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True            # shard embed dim over data axes
    fsdp_over_pod: bool = False  # include "pod" in the FSDP axes
    tp: bool = True              # shard mlp/heads/vocab/experts over model
    shard_experts: bool = True
    batch_over_pod: bool = True
    # For archs whose head count cannot shard over "model" (smollm's 15
    # heads): reshard the attention batch over (data, model) instead of
    # replicating the quadratic attention work on every model rank (§Perf).
    attn_batch_over_model: bool = False


def _axis_rules(mesh, policy: ShardingPolicy) -> dict[str, Any]:
    names = mesh.axis_names
    has_pod = "pod" in names
    data_axes: tuple[str, ...] = tuple(
        a for a in (("pod",) if (has_pod and policy.batch_over_pod) else ())
    ) + ("data",)
    fsdp_axes = (("pod", "data") if (has_pod and policy.fsdp_over_pod)
                 else ("data",)) if policy.fsdp else None
    model = "model" if policy.tp else None
    return {
        "batch": data_axes,
        "embed": fsdp_axes,
        "mlp": model,
        "qheads": model,
        "kv_heads": model,
        "heads": model,
        "vocab": model,
        "experts": model if policy.shard_experts else None,
        "expert_mlp": None,
        "inner": model,
        "dc": None,
        "rope": None,
        "state": None,
        "conv": None,
        "layers": None,
        "kv_seq": ("data",),
        None: None,
    }


def _mesh_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for_leaf(key: str, shape: tuple[int, ...], mesh,
                  rules: dict) -> P:
    axes = logical_axes(key, len(shape))
    entries = []
    used: set = set()
    for dim, ax in zip(shape, axes):
        mesh_ax = rules.get(ax)
        flat = tuple(mesh_ax) if isinstance(mesh_ax, tuple) else (mesh_ax,)
        if (
            mesh_ax is None
            or dim % _mesh_size(mesh, mesh_ax) != 0
            or any(a in used for a in flat)
        ):
            entries.append(None)
        else:
            entries.append(mesh_ax)
            used.update(flat)
    return P(*entries)


def param_specs(params: PyTree, mesh, policy: ShardingPolicy) -> PyTree:
    """NamedSharding tree matching ``params`` (works on SDS trees too)."""
    rules = _axis_rules(mesh, policy)

    def walk(path, leaf):
        key = str(path[-1].key) if hasattr(path[-1], "key") else ""
        spec = spec_for_leaf(key, tuple(leaf.shape), mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(walk, params)


def activation_rules(mesh, policy: ShardingPolicy, *, global_batch: int,
                     shard_kv_seq: bool = False,
                     per_example: bool = False) -> dict:
    """Rules consumed by ``models.layers.shard`` during forward.

    per_example=True is the DP microbatch path: the (tiny) per-example batch
    dim stays unsharded and the *sequence* shards over data instead, so one
    example's forward/backward still spans the whole pod.
    """
    rules = _axis_rules(mesh, policy)
    batch_axes = rules["batch"]
    seq_axes = None
    if per_example or global_batch % _mesh_size(mesh, batch_axes) != 0:
        batch_axes = None  # e.g. long_500k batch=1 -> shard KV seq instead
        seq_axes = ("data",)
    attn_batch = batch_axes
    if policy.attn_batch_over_model and batch_axes is not None:
        flat = tuple(batch_axes) if isinstance(batch_axes, tuple) else (batch_axes,)
        cand = flat + ("model",)
        if global_batch % _mesh_size(mesh, cand) == 0:
            attn_batch = cand
    act = {
        "__mesh__": mesh,
        "batch": batch_axes,
        "attn_batch": attn_batch,
        "seq": seq_axes,
        "mlp": rules["mlp"],
        "heads": rules["heads"],
        "vocab": rules["vocab"],
        "experts": rules["experts"],
        "kv_seq": ("data",) if shard_kv_seq else None,
    }
    return act


def batch_specs(batch_sds: PyTree, mesh, policy: ShardingPolicy) -> PyTree:
    """Shard every batch leaf's leading (example) axis over the data axes."""
    rules = _axis_rules(mesh, policy)
    batch_axes = rules["batch"]

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        if b % _mesh_size(mesh, batch_axes) == 0:
            return NamedSharding(mesh, P(batch_axes, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map(one, batch_sds)


def cache_specs(cache_sds: PyTree, mesh, policy: ShardingPolicy, *,
                global_batch: int) -> PyTree:
    """KV-cache sharding: batch over data when divisible; otherwise the cache
    *sequence* shards over data (long_500k) — attention softmax reductions
    then lower to the LSE-merge collectives."""
    rules = _axis_rules(mesh, policy)
    batch_axes = rules["batch"]
    batch_ok = global_batch % _mesh_size(mesh, batch_axes) == 0
    model_ok = policy.tp

    def walk(path, leaf):
        key = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = leaf.ndim
        # stacked caches carry a leading layers dim
        lead = [None]
        shape = leaf.shape[1:]
        nd_body = nd - 1
        if key in ("k", "v"):          # [B, L, KV, hd]
            b, l, kvh, hd = shape
            spec = [None, None, None, None]
            if batch_ok:
                spec[0] = batch_axes
            elif l % mesh.shape["data"] == 0:
                spec[1] = ("data",)
            if model_ok and kvh % mesh.shape["model"] == 0:
                spec[2] = "model"
            return NamedSharding(mesh, P(*lead, *spec))
        if key in ("c", "kr"):          # MLA latents [B, L, d]
            b, l, d = shape
            spec = [None, None, None]
            if batch_ok:
                spec[0] = batch_axes
            elif l % mesh.shape["data"] == 0:
                spec[1] = ("data",)
            return NamedSharding(mesh, P(*lead, *spec))
        if key == "conv":               # [B, K, DI]
            b, kk, di = shape
            spec = [batch_axes if batch_ok else None, None,
                    "model" if model_ok and di % mesh.shape["model"] == 0 else None]
            return NamedSharding(mesh, P(*lead, *spec))
        if key == "ssm":                # [B, DI, DS]
            b, di, ds = shape
            spec = [batch_axes if batch_ok else None,
                    "model" if model_ok and di % mesh.shape["model"] == 0 else None,
                    None]
            return NamedSharding(mesh, P(*lead, *spec))
        if key == "x_prev":             # [B, 1, D]
            return NamedSharding(
                mesh, P(*lead, batch_axes if batch_ok else None, None, None)
            )
        if key == "wkv":                # [B, NH, HS, HS]
            b, nh, hs, _ = shape
            spec = [batch_axes if batch_ok else None,
                    "model" if model_ok and nh % mesh.shape["model"] == 0 else None,
                    None, None]
            return NamedSharding(mesh, P(*lead, *spec))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(walk, cache_sds)


def opt_state_specs(opt_name: str, params: PyTree, pspecs: PyTree,
                    opt_state_sds: PyTree, mesh) -> PyTree:
    """Optimizer-state shardings derived from the param specs.

    adamw mu/nu mirror the params; adafactor vr drops the last param axis and
    vc drops the second-to-last; counts are replicated.
    """
    flat_p, _ = jax.tree_util.tree_flatten(params)
    flat_s, _ = jax.tree_util.tree_flatten(pspecs)
    shape_to_spec = {}
    for p, s in zip(flat_p, flat_s):
        shape_to_spec.setdefault(tuple(p.shape), s.spec)
        if len(p.shape) >= 2:
            shape_to_spec.setdefault(tuple(p.shape[:-1]), P(*s.spec[:-1]))
            shape_to_spec.setdefault(
                tuple(p.shape[:-2] + p.shape[-1:]), P(*s.spec[:-2], s.spec[-1])
            )

    def one(leaf):
        spec = shape_to_spec.get(tuple(leaf.shape))
        if spec is None:
            spec = P(*([None] * leaf.ndim))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, opt_state_sds)


def replicated(mesh):
    return NamedSharding(mesh, P())
