import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Test hook only: allow scaling the placeholder device count down BEFORE jax
# initializes (jax locks the device count on first init).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers AND compiles.

For each combination this lowers the paper-faithful DeCaPH train step (or the
serve/prefill program for inference shapes) onto the production mesh with 512
placeholder CPU devices, compiles it, prints memory/cost analysis, and writes
a JSON artifact with the trip-count-corrected roofline terms
(launch/roofline.py) into ``benchmarks/artifacts/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_config
from repro.configs.shapes import ShapeSkip
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_compiled, model_flops, roofline_terms
from repro.launch.steps import build_program

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun"
)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            mesh=None, dp_mode: str | None = None, policy=None,
            out_dir: str | None = None, tag: str = "",
            cfg_overrides: dict | None = None) -> dict:
    """Lower + compile one (arch, shape, mesh) and write the artifact."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()
    program = build_program(cfg, shape_name, mesh, policy=policy, dp_mode=dp_mode)
    donate = (1,) if program.kind == "decode" else ()
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else _null():
        lowered = jax.jit(program.fn, donate_argnums=donate).lower(*program.args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis:", ma)
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:
        print("memory_analysis unavailable:", e)
    try:
        ca = compiled.cost_analysis()
        print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis flops:",
              ca.get("flops"), "bytes:", ca.get("bytes accessed"))
    except Exception as e:
        print("cost_analysis unavailable:", e)

    analysis = analyze_compiled(compiled)
    n_chips = int(mesh.devices.size)
    # The partitioned HLO carries PER-DEVICE shapes; scale to global so the
    # roofline formulas (which divide by chips x peak) apply consistently.
    # Replicated compute (e.g. attention that cannot shard over "model") is
    # genuinely duplicated across ranks and therefore genuinely counted.
    for k in ("corrected_flops", "collective_bytes", "toplevel_result_bytes",
              "hbm_traffic_model_bytes", "dot_bytes", "dus_bytes"):
        analysis[k] = analysis[k] * n_chips
    analysis["collective_by_kind"] = {
        k: v * n_chips for k, v in analysis["collective_by_kind"].items()
    }
    mf = model_flops(program.cfg, INPUT_SHAPES[shape_name], program.kind)
    hlo_flops = analysis["corrected_flops"]
    terms = roofline_terms(
        flops=hlo_flops,
        hbm_bytes=analysis["hbm_traffic_model_bytes"],
        coll_bytes=analysis["collective_bytes"],
        n_chips=n_chips,
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "axis_names": list(mesh.axis_names),
        "n_chips": n_chips,
        "kind": program.kind,
        "meta": program.meta,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "model_flops": mf,
        "useful_flops_ratio": mf / hlo_flops if hlo_flops else None,
        **analysis,
        "roofline": terms,
        "tag": tag,
    }
    out_dir = out_dir or ARTIFACT_DIR
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    try:  # cache the optimized HLO so analyses can be re-run w/o recompiling
        import zstandard as zstd

        hlo_path = path.replace(".json", ".hlo.zst")
        with open(hlo_path, "wb") as f:
            f.write(zstd.ZstdCompressor(level=6).compress(
                compiled.as_text().encode()
            ))
    except Exception as e:  # pragma: no cover
        print("HLO cache write failed:", e)
    print(
        f"[{arch} x {shape_name} x {mesh_name}] OK "
        f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
        f"flops={hlo_flops:.3e} coll={analysis['collective_bytes']:.3e}B "
        f"bottleneck={terms['bottleneck']}"
    )
    return record


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(ARCHITECTURES), default=None)
    p.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true",
                   help="every (arch x shape) on the selected mesh")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--dp-mode", default=None,
                   choices=["per_example", "ghost", "none"])
    p.add_argument("--tag", default="")
    p.add_argument("--out", default=None)
    args = p.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    combos = []
    if args.all:
        for arch in ARCHITECTURES:
            for shape in INPUT_SHAPES:
                combos.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    failures, skips = [], []
    for arch, shape in combos:
        out_dir = args.out or ARTIFACT_DIR
        suffix = f"__{args.tag}" if args.tag else ""
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[{arch} x {shape}] exists, skipping")
            continue
        try:
            run_one(arch, shape, mesh=mesh, dp_mode=args.dp_mode,
                    out_dir=args.out, tag=args.tag)
        except ShapeSkip as e:
            print(f"[{arch} x {shape}] SKIP: {e}")
            skips.append((arch, shape, str(e)))
        except Exception as e:
            print(f"[{arch} x {shape}] FAIL: {type(e).__name__}: {e}")
            traceback.print_exc(limit=8)
            failures.append((arch, shape, f"{type(e).__name__}: {e}"))
    print(f"\ndone: {len(combos) - len(failures) - len(skips)} ok, "
          f"{len(skips)} skipped, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
