"""Roofline analysis from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once** (verified
empirically — scan vs unrolled differ by exactly the trip count), so layer
scans would hide ~L× of the model's FLOPs.  This module therefore parses the
post-SPMD optimized HLO text and computes *trip-count-corrected* totals:

  * dot FLOPs: 2 · |result| · |contracted dims| per dot, recursively expanded
    through fusions / calls / while bodies (× known_trip_count);
  * collective bytes: per-device result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops (post-partitioning
    shapes are already per-shard), with a 2x wire factor for all-reduce
    (ring = reduce-scatter + all-gather);
  * HBM traffic model: sum of top-level op result bytes (fusion boundaries
    are materialisation points) + entry parameter bytes, ×(1 read + 1 write
    amortised) — documented approximation, cross-checked against
    cost_analysis bytes.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link (ICI)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Cost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    result_bytes: float = 0.0
    dot_bytes: float = 0.0      # matmul operand+result traffic
    dus_bytes: float = 0.0      # dynamic-update-slice (KV-cache writes)
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "_Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.coll_bytes += other.coll_bytes * mult
        self.result_bytes += other.result_bytes * mult
        self.dot_bytes += other.dot_bytes * mult
        self.dus_bytes += other.dus_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\("
)
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\]))")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(([^)]*(?:\([^)]*\))?[^)]*)\))?.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[="\{:\s]+n["\{:\s]*"?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


class HLOAnalyzer:
    """Trip-count-corrected cost analysis from optimized HLO text."""

    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[str]] = {}
        self.entry: str | None = None
        self._parse_blocks(hlo_text)
        self._memo: dict[str, _Cost] = {}

    def _parse_blocks(self, text: str) -> None:
        cur_name, cur_lines = None, []
        for line in text.splitlines():
            if not line.startswith(" ") and line.rstrip().endswith("{"):
                m = _COMP_HEADER_RE.match(line.strip())
                if m:
                    cur_name = m.group(1)
                    cur_lines = [line]
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur_name
                    continue
            if cur_name is not None:
                cur_lines.append(line)
                if line.rstrip() == "}":
                    self.computations[cur_name] = cur_lines
                    cur_name = None
        if self.entry is None and self.computations:
            # fall back: ENTRY may carry a different formatting
            for name in self.computations:
                if "main" in name:
                    self.entry = name
                    break

    def _symbols(self, lines: list[str]) -> dict[str, str]:
        """name -> type string (params + instruction results)."""
        sym: dict[str, str] = {}
        header = lines[0]
        for m in _PARAM_RE.finditer(header):
            sym[m.group(1)] = m.group(2)
        for line in lines[1:]:
            m = _INSTR_RE.match(line)
            if m:
                sym[m.group(1)] = m.group(2)
        return sym

    def cost_of(self, comp: str) -> _Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = _Cost()  # cycle guard
        lines = self.computations.get(comp, [])
        sym = self._symbols(lines)
        total = _Cost()
        for line in lines[1:]:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, opcode = m.group(1), m.group(2), m.group(3)
            rbytes = _shape_bytes(type_str)
            total.result_bytes += rbytes
            if opcode == "dot":
                flops = self._dot_flops(line, type_str, sym)
                total.flops += flops
                total.dot_bytes += rbytes + self._operand_bytes(line, sym)
            elif opcode == "dynamic-update-slice":
                # KV-cache style in-place update: the written slice + read
                # dominate; count the updated operand once.
                total.dus_bytes += rbytes
            elif opcode == "convolution":
                total.flops += 2 * max(
                    1, int(rbytes / max(_DTYPE_BYTES.get("f32", 4), 1))
                )  # coarse: counted as >=1 flop per output elem pair
            elif opcode.startswith(_COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if opcode.startswith(c))
                wire = 2.0 if kind == "all-reduce" else 1.0
                total.coll_bytes += rbytes * wire
                total.coll_by_kind[kind] = (
                    total.coll_by_kind.get(kind, 0.0) + rbytes * wire
                )
            elif opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if bm:
                    total.add(self.cost_of(bm.group(1)), trip)
            elif opcode in ("fusion", "call", "map", "reduce", "reduce-window",
                            "scatter", "select-and-scatter", "sort",
                            "conditional", "custom-call", "async-start"):
                for cm in _CALLS_RE.finditer(line):
                    callee = cm.group(1)
                    if callee in self.computations and callee != comp:
                        total.add(self.cost_of(callee), 1)
        self._memo[comp] = total
        return total

    def _operand_bytes(self, line: str, sym: dict[str, str]) -> float:
        """Sum of operand tensor bytes for an instruction's call arguments."""
        m = re.search(r"\b[\w\-]+\(([^)]*)\)", line)
        if not m:
            return 0.0
        total = 0.0
        for om in _OPERAND_RE.finditer(m.group(1)):
            total += _shape_bytes(sym.get(om.group(1), ""))
        return total

    def _dot_flops(self, line: str, result_type: str, sym: dict[str, str]) -> float:
        res_dims = _shape_dims(result_type)
        res_n = 1
        for d in res_dims:
            res_n *= d
        cm = _CONTRACT_RE.search(line)
        # first operand name after "dot("
        try:
            args = line.split("dot(", 1)[1]
        except IndexError:
            return 0.0
        om = _OPERAND_RE.search(args)
        contract = 1
        if cm and om:
            lhs_type = sym.get(om.group(1), "")
            lhs_dims = _shape_dims(lhs_type)
            idxs = [int(i) for i in cm.group(1).split(",") if i != ""]
            for i in idxs:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        return 2.0 * res_n * contract

    def totals(self) -> _Cost:
        if self.entry is None:
            return _Cost()
        return self.cost_of(self.entry)

    def entry_param_bytes(self) -> int:
        lines = self.computations.get(self.entry or "", [])
        if not lines:
            return 0
        return _shape_bytes(lines[0])


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(
    *, flops: float, hbm_bytes: float, coll_bytes: float, n_chips: int
) -> dict[str, float]:
    compute_t = flops / (n_chips * PEAK_FLOPS)
    memory_t = hbm_bytes / (n_chips * HBM_BW)
    coll_t = coll_bytes / (n_chips * LINK_BW)
    terms = {"compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


def model_flops(cfg, shape: dict, kind: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active per token (decode)."""
    from repro.configs.base import active_param_count

    n_active = active_param_count(cfg)
    b, s = shape["global_batch"], shape["seq_len"]
    if kind == "train":
        return 6.0 * n_active * b * s
    if kind == "prefill":
        return 2.0 * n_active * b * s
    return 2.0 * n_active * b  # one token per sequence


def ghost_norm_flops(b: int, s: int, d_in: int, d_out: int) -> float:
    """FLOPs of one ghost-norm collector site ``||A^T G||_F^2`` per example.

    The Gram identity costs two [B,S,S] batched matmuls (2·B·S²·d each) plus
    the elementwise product-reduce (2·B·S²) — what the Pallas kernel (and
    the blocked XLA path) actually execute, tile by tile.
    """
    return float(b) * s * s * (2.0 * (d_in + d_out) + 2.0)


def _ghost_collector_sites(cfg) -> list[tuple[int, int]]:
    """(d_in, d_out) of every per-layer dense collector site + the head."""
    d, hd = cfg.d_model, cfg.head_dim
    per_layer = [
        (d, cfg.n_heads * hd),            # wq
        (d, cfg.n_kv_heads * hd),         # wk
        (d, cfg.n_kv_heads * hd),         # wv
        (cfg.n_heads * hd, d),            # wo
        (d, cfg.d_ff),                    # w_up
        (cfg.d_ff, d),                    # w_down
    ]
    if cfg.ffn_kind in ("swiglu", "geglu"):
        per_layer.append((d, cfg.d_ff))   # w_gate
    return per_layer * cfg.n_layers + [(d, cfg.vocab_size)]  # + head


def dp_round_flops(cfg, *, cohort: int, batch_per_silo: int, seq_len: int,
                   clipping: str = "ghost") -> float:
    """Analytic FLOPs of one fused DP round over the cohort.

    Faithful per-example clipping is one fwd+bwd per example (6·N·tokens
    total — its cost problem is the per-example gradient *memory traffic*,
    not FLOPs).  The ghost path runs TWO batched passes (norms, then the
    factor-weighted grad: 12·N·tokens) plus the ghost-norm Gram contractions
    at every collector site — more arithmetic, no per-example gradients,
    which is exactly the trade the roofline makes visible: ghost moves the
    round from the memory roof toward the compute roof.
    """
    from repro.configs.base import active_param_count

    n_active = active_param_count(cfg)
    tokens = float(cohort) * batch_per_silo * seq_len
    if clipping != "ghost":
        return 6.0 * n_active * tokens
    collector = sum(
        ghost_norm_flops(cohort * batch_per_silo, seq_len, di, do)
        for di, do in _ghost_collector_sites(cfg)
    )
    return 12.0 * n_active * tokens + collector


def dp_round_roofline(cfg, *, cohort: int, batch_per_silo: int,
                      seq_len: int, wall_seconds: float | None = None,
                      clipping: str = "ghost", n_chips: int = 1) -> dict:
    """%-of-roofline terms for one measured fused DP round.

    ``pct_of_roofline`` is the analytic round FLOPs over the measured wall
    clock, as a percentage of ``n_chips`` worth of TPU-v5e peak — on a CPU
    host this is a *hardware-model* figure (how far the measured round sits
    from what the TPU roofline allows), the same convention the serve-tier
    BENCH rows use.  ``per_example_grad_bytes`` is the faithful path's
    per-example gradient materialisation floor (read+write), the traffic
    the ghost path deletes.
    """
    from repro.configs.base import active_param_count

    flops = dp_round_flops(cfg, cohort=cohort, batch_per_silo=batch_per_silo,
                           seq_len=seq_len, clipping=clipping)
    n_active = active_param_count(cfg)
    # HBM floor: one param read + one grad-sum write for either path (8N);
    # the faithful path additionally writes then re-reads one full gradient
    # per example (8NB) — the traffic the ghost path deletes, and what makes
    # the faithful round memory-bound on the TPU roofline as B grows.
    grad_bytes = (0.0 if clipping == "ghost"
                  else 2.0 * 4.0 * n_active * cohort * batch_per_silo)
    hbm_bytes = 2.0 * 4.0 * n_active + grad_bytes
    terms = roofline_terms(flops=flops, hbm_bytes=hbm_bytes,
                           coll_bytes=0.0, n_chips=n_chips)
    out = {
        "round_flops": flops,
        "per_example_grad_bytes": grad_bytes,
        "roofline_round_s": max(terms["compute_s"], terms["memory_s"]),
        "roofline_bottleneck": terms["bottleneck"],
        "clipping": clipping,
    }
    if wall_seconds is not None:
        achieved = flops / max(wall_seconds, 1e-12)
        out["achieved_flops_per_s"] = achieved
        out["pct_of_roofline"] = 100.0 * achieved / (n_chips * PEAK_FLOPS)
    return out


def analyze_compiled(compiled, lowered=None) -> dict[str, Any]:
    """Extract corrected totals + raw cost/memory analysis from a compiled
    executable."""
    text = compiled.as_text()
    an = HLOAnalyzer(text)
    tot = an.totals()
    raw = {}
    try:
        ca = compiled.cost_analysis()
        raw = {k: float(v) for k, v in ca.items()
               if isinstance(v, (int, float)) and k in
               ("flops", "bytes accessed", "transcendentals",
                "utilization operand 0 {}", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        raw = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    param_bytes = an.entry_param_bytes()
    # HBM traffic model for the TPU target: matmul operand+result traffic,
    # KV-cache updates, collective buffers and one read of the entry params.
    # Elementwise chains are assumed fused (kept in VMEM) on TPU; the blanket
    # sum of every top-level op result is recorded separately for reference.
    hbm_traffic = tot.dot_bytes + tot.dus_bytes + tot.coll_bytes + param_bytes
    return {
        "corrected_flops": tot.flops,
        "collective_bytes": tot.coll_bytes,
        "collective_by_kind": tot.coll_by_kind,
        "toplevel_result_bytes": tot.result_bytes,
        "dot_bytes": tot.dot_bytes,
        "dus_bytes": tot.dus_bytes,
        "entry_param_bytes": param_bytes,
        "hbm_traffic_model_bytes": hbm_traffic,
        "raw_cost_analysis": raw,
        "memory_analysis": mem,
    }
