"""Batched serving driver: prefill + decode loop with KV cache.

Demonstrates the serve_step path end to end on host devices (the dry-run
lowers the same program on the production mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.models import transformer as tf
from repro.models import attention as attn_lib


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(ARCHITECTURES), default="smollm-360m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.key(0)
    params = tf.init(cfg, key)
    b = args.batch
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (b, args.prompt_len), 0, cfg.vocab_size
    )

    cache = tf.init_cache(cfg, b, max_len)
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.n_audio_ctx, cfg.d_model)
        ) * 0.1
        enc = tf._encode(cfg, params, frames)

        def fill(stacked_params):
            def one(lp):
                return attn_lib.cross_kv_cache(lp["e0"]["cross"], enc, cfg)
            return jax.vmap(one)(stacked_params)

        cache["group0"]["e0"]["cross"] = fill(params["group0"])

    decode = jax.jit(
        lambda p_, c_, t_, i_: tf.decode_step(cfg, p_, c_, t_, i_),
        donate_argnums=(1,),
    )

    # prefill via repeated decode (smoke-scale; prod uses the prefill program)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.asarray(t, jnp.int32))
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for t in range(args.prompt_len, max_len):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32))
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits[:, -1] / args.temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"arch={args.arch} generated {gen.shape} in {dt:.2f}s "
          f"({b * args.gen / dt:.1f} tok/s)")
    print("sample tokens:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
