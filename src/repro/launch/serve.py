"""Static-batch serving driver — a thin shim over ``repro.serve``.

Kept for its original purpose (a one-command smoke of the decode path on
host devices; the dry-run lowers the same programs on the production mesh)
but the machinery now lives in ``repro.serve.ServeEngine``: prompts prefill
in ONE jitted program each (a scan of the decode step — not the old
O(prompt_len) Python dispatch loop) and every generated token, including
the first, is sampled at ``--temperature`` inside the jitted step.

For continuous batching, open-loop traffic, and live federation-checkpoint
hot-swaps, use ``python -m repro.serve``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.serve.engine import ServeConfig, ServeEngine, batch_generate


def main() -> None:
    decoder_only = [a for a in ARCHITECTURES
                    if not get_smoke_config(a).is_encoder_decoder]
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=decoder_only, default="smollm-360m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    engine = ServeEngine(ServeConfig(
        arch=args.arch,
        slots=args.batch,
        max_len=args.prompt_len + args.gen,
        temperature=args.temperature,
    ))
    cfg = engine.model_cfg
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    ), np.int32)

    t0 = time.time()
    gen = batch_generate(engine, prompts, args.gen)
    dt = time.time() - t0
    print(f"arch={args.arch} generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, "
          f"{engine.decode_dispatches + engine.admit_dispatches} dispatches)")
    print("sample tokens:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
