"""Re-run the roofline analysis over cached HLO artifacts (no recompiles).

Usage: PYTHONPATH=src python -m repro.launch.reanalyze [--dir DIR]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard as zstd

from repro.launch.roofline import HLOAnalyzer, roofline_terms


def reanalyze_file(json_path: str) -> dict | None:
    hlo_path = json_path.replace(".json", ".hlo.zst")
    if not os.path.exists(hlo_path):
        return None
    with open(json_path) as f:
        rec = json.load(f)
    with open(hlo_path, "rb") as f:
        text = zstd.ZstdDecompressor().decompress(f.read()).decode()
    an = HLOAnalyzer(text)
    tot = an.totals()
    n = rec["n_chips"]
    param_bytes = an.entry_param_bytes()
    rec.update(
        corrected_flops=tot.flops * n,
        collective_bytes=tot.coll_bytes * n,
        collective_by_kind={k: v * n for k, v in tot.coll_by_kind.items()},
        toplevel_result_bytes=tot.result_bytes * n,
        dot_bytes=tot.dot_bytes * n,
        dus_bytes=tot.dus_bytes * n,
        entry_param_bytes=param_bytes,
        hbm_traffic_model_bytes=(
            tot.dot_bytes + tot.dus_bytes + tot.coll_bytes + param_bytes
        ) * n,
    )
    rec["roofline"] = roofline_terms(
        flops=rec["corrected_flops"],
        hbm_bytes=rec["hbm_traffic_model_bytes"],
        coll_bytes=rec["collective_bytes"],
        n_chips=n,
    )
    mf = rec.get("model_flops")
    rec["useful_flops_ratio"] = (
        mf / rec["corrected_flops"] if mf and rec["corrected_flops"] else None
    )
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..",
        "benchmarks", "artifacts", "dryrun"))
    args = p.parse_args()
    n = 0
    for jp in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = reanalyze_file(jp)
        if rec:
            n += 1
            r = rec["roofline"]
            print(f"{rec['arch']:20s} {rec['shape']:12s} {rec['mesh']:9s} "
                  f"cmp={r['compute_s']:.2e} mem={r['memory_s']:.2e} "
                  f"col={r['collective_s']:.2e} -> {r['bottleneck']}")
    print(f"reanalyzed {n} artifacts")


if __name__ == "__main__":
    main()
