"""Mixture-of-Experts with expert-parallel-friendly capacity dispatch.

Dispatch is sort-based (argsort by expert id + position-in-expert buckets)
rather than GShard one-hot einsums: the dense [tokens, experts, capacity]
dispatch tensor is impossible at DeepSeek scale (65k tokens x 256 experts),
while the sorted scatter is O(tokens·k).  Tokens are processed in ``groups``
aligned with the data-parallel shards, so the sort never crosses a shard and
the only cross-shard traffic is the expert all-to-all the partitioner inserts
when contracting the grouped buffer against ``experts``-sharded weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init, pname, shard


def moe_init(key, cfg, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {
        pname("w_router", "embed", "experts"): dense_init(ks[0], d, (d, e), jnp.float32),
        pname("w_gate", "experts", "embed", "expert_mlp"): dense_init(ks[1], d, (e, d, f), dtype),
        pname("w_up", "experts", "embed", "expert_mlp"): dense_init(ks[2], d, (e, d, f), dtype),
        pname("w_down", "experts", "expert_mlp", "embed"): dense_init(ks[3], f, (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.expert_d_ff * cfg.n_shared_experts
        ksh = jax.random.split(ks[4], 3)
        p[pname("w_shared_gate", "embed", "mlp")] = dense_init(ksh[0], d, (d, fs), dtype)
        p[pname("w_shared_up", "embed", "mlp")] = dense_init(ksh[1], d, (d, fs), dtype)
        p[pname("w_shared_down", "mlp", "embed")] = dense_init(ksh[2], fs, (fs, d), dtype)
    return p


def _dispatch_group(x, top_ids, top_probs, n_experts: int, capacity: int):
    """Sort-based dispatch for one token group.

    x: [T, D]; top_ids/top_probs: [T, K].  Returns (buffer [E, C, D],
    gather metadata) for combine.
    """
    t, k = top_ids.shape
    flat_ids = top_ids.reshape(-1)                          # [T*K]
    order = jnp.argsort(flat_ids)                           # stable
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=n_experts)
    starts = jnp.cumsum(counts) - counts                    # exclusive cumsum
    pos = jnp.arange(t * k) - starts[sorted_ids]            # slot within expert
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, capacity - 1)
    tok = order // k                                        # source token
    buf = jnp.zeros((n_experts, capacity, x.shape[-1]), x.dtype)
    buf = buf.at[sorted_ids, pos_c].add(
        x[tok] * keep[:, None].astype(x.dtype)
    )
    meta = (sorted_ids, pos_c, tok, keep, order)
    return buf, meta


def _combine_group(h, meta, top_probs, t: int, k: int):
    """Gather expert outputs back per token, weight by router probs."""
    sorted_ids, pos_c, tok, keep, order = meta
    out_sorted = h[sorted_ids, pos_c] * keep[:, None].astype(h.dtype)  # [T*K, D]
    probs_sorted = top_probs.reshape(-1)[order]
    weighted = out_sorted * probs_sorted[:, None].astype(h.dtype)
    out = jnp.zeros((t, h.shape[-1]), h.dtype)
    return out.at[tok].add(weighted)


def moe_apply(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y [B,S,D], aux_loss scalar).

    Router in fp32; load-balance auxiliary loss (Switch-style) returned for
    the training objective.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    groups = max(1, getattr(cfg, "moe_groups", 1))
    t_all = b * s
    assert t_all % groups == 0, "tokens must divide moe_groups"
    tg = t_all // groups
    capacity = max(1, int(cfg.capacity_factor * tg * k / e))

    xf = x.reshape(t_all, d)
    logits = (xf.astype(jnp.float32) @ params[pname("w_router", "embed", "experts")])
    probs = jax.nn.softmax(logits, axis=-1)                 # [T, E]
    top_probs, top_ids = jax.lax.top_k(probs, k)            # [T, K]
    top_probs = top_probs / jnp.sum(top_probs, -1, keepdims=True)

    # Switch-transformer load-balance aux loss.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, e, dtype=jnp.float32), axis=1), axis=0
    ) / k
    aux = e * jnp.sum(me * ce)

    xg = xf.reshape(groups, tg, d)
    idsg = top_ids.reshape(groups, tg, k)
    probsg = top_probs.reshape(groups, tg, k)

    def group_fn(xg_i, ids_i, probs_i):
        buf, meta = _dispatch_group(xg_i, ids_i, probs_i, e, capacity)
        return buf, meta

    bufs, metas = jax.vmap(group_fn)(xg, idsg, probsg)      # [G, E, C, D]
    bufs = shard(bufs, "batch", "experts", None, None)
    act = act_fn(cfg.moe_act if hasattr(cfg, "moe_act") else "silu")
    gate = jnp.einsum("gecd,edf->gecf", bufs, params[pname("w_gate", "experts", "embed", "expert_mlp")])
    up = jnp.einsum("gecd,edf->gecf", bufs, params[pname("w_up", "experts", "embed", "expert_mlp")])
    h = act(gate) * up
    h = shard(h, "batch", "experts", None, None)
    yexp = jnp.einsum("gecf,efd->gecd", h, params[pname("w_down", "experts", "expert_mlp", "embed")])
    yexp = shard(yexp, "batch", "experts", None, None)

    def comb_fn(h_i, meta_i, probs_i):
        return _combine_group(h_i, meta_i, probs_i, tg, k)

    y = jax.vmap(comb_fn)(yexp, metas, probsg).reshape(b, s, d)

    if cfg.n_shared_experts:
        gate_s = jax.nn.silu(x @ params[pname("w_shared_gate", "embed", "mlp")])
        up_s = x @ params[pname("w_shared_up", "embed", "mlp")]
        y = y + (gate_s * up_s) @ params[pname("w_shared_down", "mlp", "embed")]
    return y.astype(x.dtype), aux
