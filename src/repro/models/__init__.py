"""Substrate model zoo: unified transformer stack + paper task models."""
