"""The paper's case-study models: MLP, logistic regression, SVC, mini-DenseNet.

These are the architectures DeCaPH's experiments actually train (GEMINI MLP
436-300-100-50-10-1, pancreas MLP 15558-1000-100-4, DenseNet121 on X-rays).
They are expressed as ``repro.core.federation.Model`` triples and also expose
a **ghost-clipping** fast path (dense stacks -> per-example norms without
per-example grads; `repro.kernels.ghost_norm` covers the sequence case).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.arms.base import Model


def linear_model(d: int) -> Model:
    """Flat-pytree logistic regression — small enough for smoke runs, real
    enough to learn.  The canonical tiny model for the CLI
    (``repro.run``), ``benchmarks/sim_report.py`` and the scenario sweeps;
    keep the numerically-stable softplus form in this one place.
    """

    def init_fn(key):
        return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss(params, ex):
        logit = ex["x"] @ params["w"] + params["b"]
        y = ex["y"]
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def predict(params, x):
        return jax.nn.sigmoid(x @ params["w"] + params["b"])

    return Model(init_fn, loss, predict)


def pooled_accuracy(model: Model, params, silos) -> float:
    """Binary accuracy of ``params`` over every silo's examples pooled."""
    x = np.concatenate([p.x for p in silos])
    y = np.concatenate([p.y for p in silos])
    pred = np.asarray(model.predict_fn(params, jnp.asarray(x))) > 0.5
    return float((pred == y).mean())


def _dense_init(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (d_in, d_out), jnp.float32) * math.sqrt(2.0 / d_in)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def mlp_init(key, sizes: Sequence[int]):
    ks = jax.random.split(key, len(sizes) - 1)
    return {f"l{i}": _dense_init(ks[i], sizes[i], sizes[i + 1])
            for i in range(len(sizes) - 1)}


def mlp_forward(params, x, n_layers: int):
    h = x
    for i in range(n_layers):
        h = h @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def _bce_with_logits(logit, y):
    return jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))


def make_mlp_classifier(sizes: Sequence[int], task: str = "binary") -> Model:
    """task: binary (GEMINI, 1 output) | multiclass (pancreas, C outputs)."""
    n_layers = len(sizes) - 1

    def init_fn(key):
        return mlp_init(key, sizes)

    def loss_fn(params, ex):
        logit = mlp_forward(params, ex["x"], n_layers)
        if task == "binary":
            return jnp.mean(_bce_with_logits(logit[..., 0], ex["y"]))
        logp = jax.nn.log_softmax(logit, axis=-1)
        onehot = jax.nn.one_hot(ex["y"].astype(jnp.int32), sizes[-1])
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    def predict_fn(params, x):
        logit = mlp_forward(params, x, n_layers)
        if task == "binary":
            return jax.nn.sigmoid(logit[..., 0])
        return jax.nn.softmax(logit, axis=-1)

    return Model(init_fn, loss_fn, predict_fn)


def make_logistic(d_in: int) -> Model:
    return make_mlp_classifier([d_in, 1], task="binary")


def make_svc(d_in: int, n_classes: int) -> Model:
    """One-layer SVC via multi-margin loss (paper: MLP + MultiMarginLoss)."""

    def init_fn(key):
        return mlp_init(key, [d_in, n_classes])

    def loss_fn(params, ex):
        scores = mlp_forward(params, ex["x"], 1)
        y = ex["y"].astype(jnp.int32)
        gold = jnp.take_along_axis(scores, y[..., None], axis=-1)[..., 0]
        margins = jnp.maximum(0.0, 1.0 + scores - gold[..., None])
        # subtract the gold term (margin vs itself is exactly 1.0)
        return jnp.mean(jnp.sum(margins, axis=-1) - 1.0)

    def predict_fn(params, x):
        return mlp_forward(params, x, 1)

    return Model(init_fn, loss_fn, predict_fn)


# ---------------------------------------------------------------------------
# Mini-DenseNet (chest-radiology stand-in for DenseNet121; BN-free as the
# paper requires for DP-SGD — norm layers are replaced by fixed scaling).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseNetConfig:
    growth: int = 12
    blocks: tuple[int, ...] = (2, 2, 2)
    init_channels: int = 16
    n_outputs: int = 4          # Atelectasis, Effusion, Cardiomegaly, NoFinding
    image_size: int = 32


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * math.sqrt(2.0 / fan_in)


def densenet_init(key, cfg: DenseNetConfig):
    params = {}
    k = jax.random.fold_in(key, 0)
    params["stem"] = _conv_init(k, 3, 3, 1, cfg.init_channels)
    ch = cfg.init_channels
    idx = 1
    for bi, n in enumerate(cfg.blocks):
        for li in range(n):
            params[f"b{bi}_l{li}"] = _conv_init(
                jax.random.fold_in(key, idx), 3, 3, ch, cfg.growth
            )
            ch += cfg.growth
            idx += 1
        if bi < len(cfg.blocks) - 1:  # transition 1x1 conv, halve channels
            params[f"t{bi}"] = _conv_init(
                jax.random.fold_in(key, idx), 1, 1, ch, ch // 2
            )
            ch = ch // 2
            idx += 1
    params["head"] = _dense_init(jax.random.fold_in(key, idx), ch, cfg.n_outputs)
    return params


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def densenet_forward(params, x, cfg: DenseNetConfig):
    """x: [B, H, W, 1] -> logits [B, n_outputs]."""
    h = jax.nn.relu(_conv(x, params["stem"]))
    for bi, n in enumerate(cfg.blocks):
        for li in range(n):
            new = jax.nn.relu(_conv(h, params[f"b{bi}_l{li}"]))
            h = jnp.concatenate([h, new], axis=-1)
        if bi < len(cfg.blocks) - 1:
            h = _conv(h, params[f"t{bi}"])
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            ) / 4.0
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return h @ params["head"]["w"] + params["head"]["b"]


def make_densenet(cfg: DenseNetConfig = DenseNetConfig()) -> Model:
    def init_fn(key):
        return densenet_init(key, cfg)

    def loss_fn(params, ex):
        logits = densenet_forward(params, ex["x"][None] if ex["x"].ndim == 3 else ex["x"], cfg)
        y = ex["y"][None] if ex["y"].ndim == 1 else ex["y"]
        return jnp.mean(_bce_with_logits(logits, y))

    def predict_fn(params, x):
        return jax.nn.sigmoid(densenet_forward(params, x, cfg))

    return Model(init_fn, loss_fn, predict_fn)


# ---------------------------------------------------------------------------
# Ghost-clipped DP-SGD for MLP stacks (exact, no per-example grads).
# ---------------------------------------------------------------------------

def ghost_clipped_grad_sum_mlp(params, batch, sizes, task, clip_norm):
    """Exact sum of per-example-clipped grads via ghost norms.

    Two cheap passes: (1) forward capturing activations + manual backward for
    per-layer cotangents -> per-example norm^2 = sum_l |a_l|^2|g_l|^2 + |g_l|^2
    (weights + biases); (2) the clipped-weighted gradient is  a_l^T diag(c) g_l
    — one matmul per layer.  Matches vmap(grad)+clip to float tolerance
    (tests/test_ghost.py).
    """
    n_layers = len(sizes) - 1
    x, y = batch["x"], batch["y"]

    # pass 1: forward with caches
    acts = [x]
    pre = []
    h = x
    for i in range(n_layers):
        z = h @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
        pre.append(z)
        h = jax.nn.relu(z) if i < n_layers - 1 else z
        acts.append(h)

    logits = acts[-1]
    # d loss_i / d logits  (per-example mean-free: loss_i is one example's loss)
    if task == "binary":
        g = (jax.nn.sigmoid(logits[..., 0]) - y)[..., None]
    else:
        onehot = jax.nn.one_hot(y.astype(jnp.int32), sizes[-1])
        g = jax.nn.softmax(logits, axis=-1) - onehot

    # manual backward collecting per-layer cotangents
    cots = [None] * n_layers
    cots[n_layers - 1] = g
    for i in range(n_layers - 2, -1, -1):
        g = (g @ params[f"l{i+1}"]["w"].T) * (pre[i] > 0)
        cots[i] = g

    norm_sq = jnp.zeros(x.shape[0], jnp.float32)
    for i in range(n_layers):
        a, g = acts[i], cots[i]
        norm_sq += jnp.sum(a**2, -1) * jnp.sum(g**2, -1)  # weight (ghost)
        norm_sq += jnp.sum(g**2, -1)                       # bias

    norms = jnp.sqrt(jnp.maximum(norm_sq, 1e-24))
    c = jnp.minimum(1.0, clip_norm / norms)               # [B]

    grads = {}
    for i in range(n_layers):
        a, g = acts[i], cots[i]
        gw = jnp.einsum("bi,b,bo->io", a, c, g)
        gb = jnp.einsum("b,bo->o", c, g)
        grads[f"l{i}"] = {"w": gw, "b": gb}
    return grads, norms
