"""Primitive layers, parameter conventions, and sharding annotations.

Parameter convention
--------------------
Every parameter lives in a plain dict pytree whose **key encodes its logical
axes**: ``"wq|embed,qheads"`` names a weight whose dims are (embed, qheads).
Sharding specs are derived purely from these names (``logical_axes``), so the
spec tree can never diverge from the param tree — stacked-layer leading dims
(from ``vmap``'d inits) are detected by rank and mapped to the ``layers`` axis.

Logical axis vocabulary: embed, mlp, qheads, kv_heads, vocab, experts,
expert_mlp, dc (MLA latent), rope, state, conv, inner, heads_inner, null.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Param naming / logical axes
# ---------------------------------------------------------------------------

def pname(name: str, *axes: str) -> str:
    """Encode logical axes into a parameter key."""
    return f"{name}|{','.join(axes)}"


def logical_axes(key: str, ndim: int) -> tuple[str, ...]:
    """Decode logical axes from a param key; prepend 'layers' for stacked."""
    if "|" not in key:
        axes: tuple[str, ...] = ()
    else:
        axes = tuple(a for a in key.split("|")[1].split(",") if a)
    if len(axes) < ndim:  # vmap-stacked (scan over layers / pattern repeats)
        axes = ("layers",) * (ndim - len(axes)) + axes
    return axes


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, dtype, stddev: float):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, d_in: int, shape, dtype):
    return trunc_normal(key, shape, dtype, 1.0 / math.sqrt(d_in))


# ---------------------------------------------------------------------------
# Sharding context — annotations become no-ops without an active mesh.
# ---------------------------------------------------------------------------

# Per-thread: the serve trainer thread and the decode loop both build
# models concurrently, and one thread's mesh rules must not leak into
# (or get clobbered by) the other's unwind.
_SHARDING = threading.local()


class activation_sharding:
    """Context manager installing logical->mesh rules for activation hints."""

    def __init__(self, rules: dict | None):
        self.rules = rules

    def __enter__(self):
        self._prev = getattr(_SHARDING, "rules", None)
        _SHARDING.rules = self.rules
        return self

    def __exit__(self, *exc):
        _SHARDING.rules = self._prev
        return False


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate activation ``x`` with logical axes (identity without rules).

    Mesh axes may appear in at most one position: earlier logical axes win
    (e.g. attn_batch over ("data","model") suppresses heads -> "model"),
    and dims not divisible by their mesh extent fall back to replication.
    """
    rules = getattr(_SHARDING, "rules", None)
    if rules is None:
        return x
    import numpy as _np
    from jax.sharding import PartitionSpec as P  # local import: cheap

    mesh = rules["__mesh__"]
    used: set = set()
    entries = []
    for dim, a in zip(x.shape, axes):
        mesh_ax = rules.get(a) if a else None
        flat = tuple(mesh_ax) if isinstance(mesh_ax, tuple) else (mesh_ax,)
        size = int(_np.prod([mesh.shape[m] for m in flat if m])) if mesh_ax else 1
        if mesh_ax is None or any(m in used for m in flat) or dim % size != 0:
            entries.append(None)
        else:
            entries.append(mesh_ax)
            used.update(flat)
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {pname("scale", "embed"): jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = params[pname("scale", "embed")].astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def layernorm_nonparam(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> dict:
    return {
        pname("scale", "embed"): jnp.ones((d,), dtype),
        pname("bias", "embed"): jnp.zeros((d,), dtype),
    }


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    y = layernorm_nonparam(x, eps).astype(jnp.float32)
    y = y * params[pname("scale", "embed")].astype(jnp.float32)
    y = y + params[pname("bias", "embed")].astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(kind: str, d: int, dtype):
    """(init_params, apply) pair for the configured norm."""
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype), rmsnorm
    if kind == "ln_nonparam":
        return {}, lambda p, x: layernorm_nonparam(x)
    if kind == "layernorm":
        return layernorm_init(d, dtype), layernorm
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# Activations / gated FFN variants
# ---------------------------------------------------------------------------

def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # Nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def ffn_init(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    """kind: swiglu | geglu | relu2 | gelu (non-gated kinds: up+down only)."""
    ks = jax.random.split(key, 3)
    p = {}
    gated = kind in ("swiglu", "geglu")
    if gated:
        p[pname("w_gate", "embed", "mlp")] = dense_init(ks[0], d_model, (d_model, d_ff), dtype)
    p[pname("w_up", "embed", "mlp")] = dense_init(ks[1], d_model, (d_model, d_ff), dtype)
    p[pname("w_down", "mlp", "embed")] = dense_init(ks[2], d_ff, (d_ff, d_model), dtype)
    return p


def ffn_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    up = x @ params[pname("w_up", "embed", "mlp")]
    if kind == "swiglu":
        h = jax.nn.silu(x @ params[pname("w_gate", "embed", "mlp")]) * up
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params[pname("w_gate", "embed", "mlp")]) * up
    elif kind in ("relu2", "gelu"):
        h = act_fn(kind)(up)
    else:
        raise ValueError(f"unknown ffn kind {kind!r}")
    h = shard(h, "batch", None, "mlp")
    return h @ params[pname("w_down", "mlp", "embed")]


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, D/2]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # [..., S, 1, D/2]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions_3d: jax.Array, theta: float, sections: tuple[int, int, int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: frequency bands split across (t, h, w).

    x: [B, S, H, D]; positions_3d: [B, S, 3] (temporal, height, width ids).
    ``sections`` gives the number of *frequency pairs* per component,
    summing to D/2.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # [D/2]
    # Select which positional component drives each frequency band.
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )  # [D/2] in {0,1,2}
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),
        jnp.broadcast_to(sec_ids[None, None, :], positions_3d.shape[:2] + (d // 2,)).astype(jnp.int32),
        axis=-1,
    )  # [B, S, D/2]
    ang = pos * inv  # [B, S, D/2]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
