"""Unified transformer stack: every assigned architecture is an instance.

Functional API (all pure, jit/pjit-friendly):

  init(cfg, key)                          -> params
  forward(cfg, params, batch)             -> (logits, aux_loss)
  loss_fn(cfg, params, batch)             -> scalar (batched mean CE)
  per_example_loss_fn(cfg, params, ex)    -> scalar (one example, for DP)
  init_cache(cfg, batch, max_len)         -> cache pytree
  cache_spec(cfg, batch, max_len)         -> ShapeDtypeStruct pytree (dry-run)
  decode_step(cfg, params, cache, tokens, index) -> (logits, cache)
  decode_step_positions(cfg, params, cache, tokens, positions)
                                          -> (logits, cache)  [per-slot index]
  prefill(cfg, params, cache, tokens)     -> (last_logits, cache)  [one program]

Layer stacking uses ``lax.scan`` over vmap-stacked per-pattern parameter
pytrees (one group per (repeat, pattern) entry in cfg.stack) — compile time
and HLO size stay bounded at 96 layers, and the roofline analyzer multiplies
one-layer costs by trip counts (launch/roofline.py).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    ffn_apply,
    ffn_init,
    make_norm,
    pname,
    rmsnorm,
    shard,
    sinusoidal_positions,
    trunc_normal,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _norm_init(cfg):
    from repro.models.layers import layernorm_init

    if cfg.norm == "rmsnorm":
        return {pname("scale", "embed"): jnp.ones((cfg.d_model,), cfg.pdtype)}
    if cfg.norm == "layernorm":
        return layernorm_init(cfg.d_model, cfg.pdtype)
    return {}  # ln_nonparam


def _apply_norm(cfg, p, x):
    from repro.models.layers import layernorm, layernorm_nonparam

    if cfg.norm == "rmsnorm":
        return rmsnorm(p, x)
    if cfg.norm == "layernorm":
        return layernorm(p, x)
    return layernorm_nonparam(x)


def _mixer_init(key, spec, cfg):
    if spec.mixer == "attn":
        return attn.gqa_init(key, cfg, cfg.pdtype)
    if spec.mixer == "mla":
        return attn.mla_init(key, cfg, cfg.pdtype)
    if spec.mixer == "mamba":
        return ssm_lib.mamba_init(key, cfg, cfg.pdtype)
    if spec.mixer == "rwkv6":
        return ssm_lib.rwkv6_init(key, cfg, cfg.pdtype)
    raise ValueError(f"unknown mixer {spec.mixer!r}")


def _layer_init(key, spec, cfg) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "norm1": _norm_init(cfg),
        "mixer": _mixer_init(ks[0], spec, cfg),
        "norm2": _norm_init(cfg),
    }
    if spec.ffn == "moe":
        p["ffn"] = moe_lib.moe_init(ks[1], cfg, cfg.pdtype)
    else:
        p["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind, cfg.pdtype)
    if spec.cross_attn:
        p["cross"] = attn.cross_init(ks[2], cfg, cfg.pdtype)
        p["norm_cross"] = _norm_init(cfg)
    return p


def _group_init(key, repeat: int, pattern, cfg) -> dict:
    """Stacked params: leaves get a leading (repeat,) 'layers' dim."""
    def one(k):
        ks = jax.random.split(k, len(pattern))
        return {f"e{j}": _layer_init(ks[j], spec, cfg)
                for j, spec in enumerate(pattern)}

    if repeat == 1:
        p = one(key)
        return jax.tree_util.tree_map(lambda x: x[None], p)
    return jax.vmap(one)(jax.random.split(key, repeat))


def init(cfg, key) -> PyTree:
    cfg.validate()
    ks = jax.random.split(key, 8 + len(cfg.stack))
    params: dict = {
        pname("embed", "vocab", "embed"): trunc_normal(
            ks[0], (cfg.vocab_size, cfg.d_model), cfg.pdtype, 0.02
        ),
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params[pname("head", "embed", "vocab")] = trunc_normal(
            ks[1], (cfg.d_model, cfg.vocab_size), cfg.pdtype, 0.02
        )
    for gi, (repeat, pattern) in enumerate(cfg.stack):
        params[f"group{gi}"] = _group_init(ks[2 + gi], repeat, pattern, cfg)
    if cfg.mtp_depth:
        from repro.models.layers import dense_init

        spec = cfg.stack[-1][1][0]  # MTP block mirrors the main stack family
        params["mtp"] = {
            "proj": {pname("w", "embed", "embed"): dense_init(
                jax.random.fold_in(ks[1], 7), 2 * cfg.d_model,
                (2 * cfg.d_model, cfg.d_model), cfg.pdtype)},
            "norm_h": _norm_init(cfg),
            "norm_e": _norm_init(cfg),
            "block": jax.tree_util.tree_map(
                lambda x: x[None],
                _layer_init(jax.random.fold_in(ks[1], 8), spec, cfg),
            ),
        }
    if cfg.is_encoder_decoder:
        from repro.configs.base import LayerSpec

        enc_pattern = (LayerSpec("attn", "dense"),)
        params["encoder"] = _group_init(
            ks[-1], cfg.encoder_layers, enc_pattern, cfg
        )
        params["enc_final_norm"] = _norm_init(cfg)
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _layer_apply(cfg, spec, p, x, positions, mrope_positions, enc_out,
                 window) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    h = _apply_norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        h = attn.gqa_apply(p["mixer"], h, positions, cfg, window=window,
                           causal=True, mrope_positions=mrope_positions)
    elif spec.mixer == "mla":
        h = attn.mla_apply(p["mixer"], h, positions, cfg, window=window)
    elif spec.mixer == "mamba":
        h = ssm_lib.mamba_apply(p["mixer"], h, cfg)
    elif spec.mixer == "rwkv6":
        h = ssm_lib.rwkv6_apply(p["mixer"], h, cfg)
    x = x + h
    if spec.cross_attn and enc_out is not None:
        h = _apply_norm(cfg, p["norm_cross"], x)
        h = attn.cross_apply(p["cross"], h, enc_out, cfg)
        x = x + h
    h = _apply_norm(cfg, p["norm2"], x)
    if spec.ffn == "moe":
        h, aux = moe_lib.moe_apply(p["ffn"], h, cfg)
    else:
        h = ffn_apply(p["ffn"], h, cfg.ffn_kind)
    x = x + h
    x = shard(x, "batch", "seq", None)
    return x, aux


def _run_group(cfg, pattern, stacked, x, positions, mrope_positions, enc_out,
               window) -> tuple[jax.Array, jax.Array]:
    def body(carry, layer_p):
        x, aux = carry

        def inner(x, aux):
            for j, spec in enumerate(pattern):
                x, a = _layer_apply(cfg, spec, layer_p[f"e{j}"], x, positions,
                                    mrope_positions, enc_out, window)
                aux = aux + a
            return x, aux

        if cfg.remat:
            x, aux = jax.checkpoint(inner)(x, aux)
        else:
            x, aux = inner(x, aux)
        return (x, aux), None

    repeat = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    if cfg.scan_layers and repeat > 1:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    else:
        aux = jnp.zeros((), jnp.float32)
        for r in range(repeat):
            layer_p = jax.tree_util.tree_map(lambda t: t[r], stacked)
            (x, aux), _ = body((x, aux), layer_p)
    return x, aux


def _encode(cfg, params, frames) -> jax.Array:
    """Whisper encoder over (stub) conv-frontend frame embeddings."""
    t = frames.shape[1]
    x = frames.astype(cfg.cdtype) + sinusoidal_positions(t, cfg.d_model).astype(cfg.cdtype)
    from repro.configs.base import LayerSpec

    pattern = (LayerSpec("attn", "dense"),)
    positions = jnp.broadcast_to(jnp.arange(t)[None], frames.shape[:2])

    def body(carry, layer_p):
        x, _ = carry
        h = _apply_norm(cfg, layer_p["e0"]["norm1"], x)
        h = attn.gqa_apply(layer_p["e0"]["mixer"], h, positions, cfg,
                           causal=False)
        x = x + h
        h = _apply_norm(cfg, layer_p["e0"]["norm2"], x)
        x = x + ffn_apply(layer_p["e0"]["ffn"], h, cfg.ffn_kind)
        return (x, jnp.zeros((), jnp.float32)), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["encoder"])
    return _apply_norm(cfg, params["enc_final_norm"], x)


def _embed_inputs(cfg, params, batch) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Token (+ modality-stub) embedding. Returns (x, positions, mrope_pos)."""
    emb = params[pname("embed", "vocab", "embed")]
    tokens = batch["tokens"]
    x = emb[tokens].astype(cfg.cdtype)
    if cfg.arch_type == "vlm" and "vision_embeds" in batch:
        # Vision tower stub: precomputed patch embeddings prefix the text.
        ve = batch["vision_embeds"].astype(cfg.cdtype)
        x = jnp.concatenate([ve, x], axis=1)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    mrope_positions = batch.get("mrope_positions")
    if cfg.rope_type == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[..., None], (b, s, 3))
    return x, positions, mrope_positions


def forward(cfg, params, batch) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits [B,S,V], aux_loss)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
    x, positions, mrope_positions = _embed_inputs(cfg, params, batch)
    x = shard(x, "batch", "seq", None)
    window = cfg.sliding_window
    aux_total = jnp.zeros((), jnp.float32)
    for gi, (repeat, pattern) in enumerate(cfg.stack):
        x, aux = _run_group(cfg, pattern, params[f"group{gi}"], x, positions,
                            mrope_positions, enc_out, window)
        aux_total = aux_total + aux
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params[pname("embed", "vocab", "embed")].T.astype(cfg.cdtype)
    else:
        logits = x @ params[pname("head", "embed", "vocab")].astype(cfg.cdtype)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux_total


def _ce(logits, labels) -> jax.Array:
    """Token-mean cross entropy; labels < 0 are masked."""
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels_c[..., None], axis=-1
    )[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg, params, batch) -> jax.Array:
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.arch_type == "vlm" and "vision_embeds" in batch:
        # loss only over the text segment (labels align to text tokens)
        logits = logits[:, -labels.shape[1]:]
    loss = _ce(logits, labels) + cfg.router_aux_coef * aux
    if cfg.mtp_depth:
        loss = loss + cfg.mtp_loss_weight * _mtp_loss(cfg, params, batch)
    return loss


def _mtp_loss(cfg, params, batch) -> jax.Array:
    """DeepSeek-V3 multi-token prediction: an extra block predicts t+2 from
    [h_t ; emb(tok_{t+1})] with shared embeddings/head (depth-1 MTP)."""
    # re-run the backbone for hidden states (cheap relative to the stack at
    # smoke scale; production would thread hidden out of forward())
    hidden = _backbone_hidden(cfg, params, batch)
    emb = params[pname("embed", "vocab", "embed")]
    tokens = batch["tokens"]
    b, s = tokens.shape
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    e_next = emb[nxt].astype(cfg.cdtype)
    mtp = params["mtp"]
    h = jnp.concatenate(
        [_apply_norm(cfg, mtp["norm_h"], hidden),
         _apply_norm(cfg, mtp["norm_e"], e_next)], axis=-1
    ) @ mtp["proj"][pname("w", "embed", "embed")]
    spec = cfg.stack[-1][1][0]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    block_p = jax.tree_util.tree_map(lambda t: t[0], mtp["block"])
    h, _ = _layer_apply(cfg, spec, block_p, h, positions, None, None,
                        cfg.sliding_window)
    h = _apply_norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits2 = h @ emb.T.astype(cfg.cdtype)
    else:
        logits2 = h @ params[pname("head", "embed", "vocab")].astype(cfg.cdtype)
    labels = batch["labels"]
    # position t predicts labels_{t+1} (i.e. token t+2); mask the tail
    labels2 = jnp.concatenate(
        [labels[:, 1:], jnp.full_like(labels[:, -1:], -1)], axis=1
    )
    return _ce(logits2, labels2)


def _backbone_hidden(cfg, params, batch) -> jax.Array:
    """Hidden states before the LM head (used by the MTP module)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
    x, positions, mrope_positions = _embed_inputs(cfg, params, batch)
    for gi, (repeat, pattern) in enumerate(cfg.stack):
        x, _ = _run_group(cfg, pattern, params[f"group{gi}"], x, positions,
                          mrope_positions, enc_out, cfg.sliding_window)
    return _apply_norm(cfg, params["final_norm"], x)


def per_example_loss_fn(cfg, params, example) -> jax.Array:
    """One-example loss for per-example (DP) gradients."""
    batch = jax.tree_util.tree_map(lambda a: a[None], example)
    return loss_fn(cfg, params, batch)


# ---------------------------------------------------------------------------
# KV caches & decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg, spec, batch: int, max_len: int) -> dict:
    c: dict = {}
    if spec.mixer == "attn":
        c["attn"] = attn.gqa_init_cache(cfg, batch, max_len, cfg.cdtype)
    elif spec.mixer == "mla":
        c["attn"] = attn.mla_init_cache(cfg, batch, max_len, cfg.cdtype)
    elif spec.mixer == "mamba":
        c["ssm"] = ssm_lib.mamba_init_cache(cfg, batch, cfg.cdtype)
    elif spec.mixer == "rwkv6":
        c["ssm"] = ssm_lib.rwkv6_init_cache(cfg, batch, cfg.cdtype)
    if spec.cross_attn:
        c["cross"] = {
            "k": jnp.zeros((batch, cfg.n_audio_ctx, cfg.n_kv_heads, cfg.head_dim), cfg.cdtype),
            "v": jnp.zeros((batch, cfg.n_audio_ctx, cfg.n_kv_heads, cfg.head_dim), cfg.cdtype),
        }
    return c


def init_cache(cfg, batch: int, max_len: int) -> PyTree:
    cache = {}
    for gi, (repeat, pattern) in enumerate(cfg.stack):
        def one():
            return {f"e{j}": _layer_cache(cfg, spec, batch, max_len)
                    for j, spec in enumerate(pattern)}

        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (repeat,) + x.shape), one()
        )
        cache[f"group{gi}"] = stacked
    return cache


def cache_spec(cfg, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_window(cfg) -> int | None:
    return cfg.sliding_window


def _layer_decode(cfg, spec, p, x, cache, index, window):
    h = _apply_norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        h, new_attn = attn.gqa_decode(p["mixer"], h, cache["attn"], index, cfg,
                                      window=window)
        cache = dict(cache, attn=new_attn)
    elif spec.mixer == "mla":
        h, new_attn = attn.mla_decode(p["mixer"], h, cache["attn"], index, cfg,
                                      window=window)
        cache = dict(cache, attn=new_attn)
    elif spec.mixer == "mamba":
        h, new_ssm = ssm_lib.mamba_decode(p["mixer"], h, cache["ssm"], cfg)
        cache = dict(cache, ssm=new_ssm)
    elif spec.mixer == "rwkv6":
        h, new_ssm = ssm_lib.rwkv6_decode(p["mixer"], h, cache["ssm"], cfg)
        cache = dict(cache, ssm=new_ssm)
    x = x + h
    if spec.cross_attn:
        h = _apply_norm(cfg, p["norm_cross"], x)
        h = attn.cross_decode(p["cross"], h, cache["cross"], cfg)
        x = x + h
    h = _apply_norm(cfg, p["norm2"], x)
    if spec.ffn == "moe":
        h, _ = moe_lib.moe_apply(p["ffn"], h, cfg.replace(moe_groups=1))
    else:
        h = ffn_apply(p["ffn"], h, cfg.ffn_kind)
    return x + h, cache


def decode_step(cfg, params, cache, tokens, index) -> tuple[jax.Array, PyTree]:
    """One-token decode. tokens: [B,1] int32; index: scalar int32 position."""
    emb = params[pname("embed", "vocab", "embed")]
    x = emb[tokens].astype(cfg.cdtype)
    window = cfg.sliding_window
    new_cache = {}
    for gi, (repeat, pattern) in enumerate(cfg.stack):
        stacked_p = params[f"group{gi}"]
        stacked_c = cache[f"group{gi}"]

        def body(x, pc):
            layer_p, layer_c = pc
            out_c = {}
            for j, spec in enumerate(pattern):
                x, c = _layer_decode(cfg, spec, layer_p[f"e{j}"], x,
                                     layer_c[f"e{j}"], index, window)
                out_c[f"e{j}"] = c
            return x, out_c

        if cfg.scan_layers and repeat > 1:
            x, out_stacked = jax.lax.scan(body, x, (stacked_p, stacked_c))
        else:
            outs = []
            for r in range(repeat):
                lp = jax.tree_util.tree_map(lambda t: t[r], stacked_p)
                lc = jax.tree_util.tree_map(lambda t: t[r], stacked_c)
                x, oc = body(x, (lp, lc))
                outs.append(oc)
            out_stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs
            )
        new_cache[f"group{gi}"] = out_stacked
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params[pname("embed", "vocab", "embed")].T.astype(cfg.cdtype)
    else:
        logits = x @ params[pname("head", "embed", "vocab")].astype(cfg.cdtype)
    return logits, new_cache


def decode_step_positions(cfg, params, cache, tokens, positions
                          ) -> tuple[jax.Array, PyTree]:
    """Per-slot decode: each batch row advances at its OWN sequence position.

    ``tokens``: [B,1] int32; ``positions``: [B] int32 — the write index for
    each row.  This is the continuous-batching requirement (DESIGN.md §9):
    serving slots are admitted and evicted independently, so the batch is
    never position-aligned.  Implemented as a vmap of ``decode_step`` over
    the batch axis — every cache leaf carries batch at axis 1 (after the
    stacked-layer axis), params are broadcast — so the per-row
    ``dynamic_update_slice`` becomes a batched scatter at per-row indices
    and the causal mask is evaluated against each row's own position.
    """

    def one(row_cache, tok, idx):
        c = jax.tree_util.tree_map(lambda x: x[:, None], row_cache)
        logits, c = decode_step(cfg, params, c, tok[None], idx)
        return logits[0], jax.tree_util.tree_map(lambda x: x[:, 0], c)

    return jax.vmap(one, in_axes=(1, 0, 0), out_axes=(0, 1))(
        cache, tokens, positions
    )


def prefill(cfg, params, cache, tokens) -> tuple[jax.Array, PyTree]:
    """Prefill a whole prompt in ONE program: scan ``decode_step`` over the
    prompt positions.  ``tokens``: [B,S] int32 (S static).  Returns the
    last position's logits ([B,1,V] — what the first generated token is
    sampled from) and the cache filled through position S-1.

    A scan of the decode step (rather than a masked ``forward``) is exact
    for every mixer family — SSM recurrences advance token by token, so
    right-padding a prompt would corrupt their state; callers keep S exact
    and bucket prompt lengths to bound retracing.
    """
    b, s = tokens.shape

    def body(carry, xs):
        c, _ = carry
        tok, idx = xs
        logits, c = decode_step(cfg, params, c, tok, idx)
        return (c, logits), None

    init_logits = jnp.zeros((b, 1, cfg.vocab_size), cfg.cdtype)
    (cache, logits), _ = jax.lax.scan(
        body, (cache, init_logits),
        (tokens.T[:, :, None], jnp.arange(s, dtype=jnp.int32)),
    )
    return logits, cache
