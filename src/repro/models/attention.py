"""Attention variants: GQA (full / causal / sliding-window), DeepSeek MLA.

All functions are pure; KV caches are explicit pytrees so ``serve_step`` can
take them as sharded inputs (``long_500k`` shards the cache *sequence* over
the ``data`` axis — the partitioner then lowers the softmax reductions into
the log-sum-exp merge collectives described in DESIGN.md §6).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import (
    apply_mrope,
    apply_rope,
    dense_init,
    pname,
    rmsnorm,
    shard,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        pname("wq", "embed", "qheads"): dense_init(ks[0], d, (d, h * hd), dtype),
        pname("wk", "embed", "kv_heads"): dense_init(ks[1], d, (d, kv * hd), dtype),
        pname("wv", "embed", "kv_heads"): dense_init(ks[2], d, (d, kv * hd), dtype),
        pname("wo", "qheads", "embed"): dense_init(ks[3], h * hd, (h * hd, d), dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _sdpa(q, k, v, mask, *, use_flash: bool = False, causal: bool = False,
          window: int | None = None):
    """q: [B,S,H,D]; k,v: [B,L,KV,D]; mask: [B,1,S,L] additive or None."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, d)
    scores = jnp.einsum("bskgd,blkd->bkgsl", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if mask is not None:
        scores = scores + mask[:, :, None]  # mask: [B, KV->1, S, L]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsl,blkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def _sdpa_blocked(q, k, v, *, causal: bool = True, window: int | None = None,
                  block_k: int = 512):
    """FlashAttention's algorithm in plain XLA: scan over KV blocks with an
    online softmax, ``jax.checkpoint``'d so the backward recomputes block
    probs instead of saving the full [.., S, L] score tensor.  On TPU the
    Pallas kernel in ``repro.kernels.flash_attention`` takes this role; this
    path gives the dry-run (and any non-TPU run) the same HBM behaviour.
    """
    b, s, h, d = q.shape
    l, kvh = k.shape[1], k.shape[2]
    l_orig = l
    group = h // kvh
    block_k = min(block_k, l)
    if l % block_k:
        pad = block_k - l % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = k.shape[1]
    n_blocks = l // block_k
    qg = (q.reshape(b, s, kvh, group, d).astype(jnp.float32)
          / math.sqrt(d))
    kb = k.reshape(b, n_blocks, block_k, kvh, d).swapaxes(0, 1)
    vb = v.reshape(b, n_blocks, block_k, kvh, d).swapaxes(0, 1)
    q_pos = jnp.arange(s)

    def body(carry, xs):
        m_run, l_run, acc = carry
        k_blk, v_blk, idx = xs
        scores = jnp.einsum("bskgd,blkd->bkgsl", qg,
                            k_blk.astype(jnp.float32))
        k_pos = idx * block_k + jnp.arange(block_k)
        ok = jnp.broadcast_to((k_pos < l_orig)[None, :], (s, block_k))
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(ok[None, None, None], scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_run, m_cur)
        p = jnp.exp(scores - m_new)
        p = jnp.where(ok[None, None, None], p, 0.0)
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bkgsl,blkd->bkgsd", p,
                                       v_blk.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, kvh, group, s, 1), NEG_INF, jnp.float32),
        jnp.zeros((b, kvh, group, s, 1), jnp.float32),
        jnp.zeros((b, kvh, group, s, d), jnp.float32),
    )
    (m_run, l_run, acc), _ = jax.lax.scan(
        jax.checkpoint(body), init, (kb, vb, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l_run, 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d).astype(q.dtype)


def _causal_mask(s: int, l: int, offset: int = 0, window: int | None = None):
    """Additive [1,1,S,L] mask; query i attends keys j <= i+offset, within window."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(l)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, NEG_INF)[None, None]


def gqa_apply(params: dict, x: jax.Array, positions: jax.Array, cfg,
              *, window: int | None = None, causal: bool = True,
              mrope_positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence (training / prefill) GQA."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(x @ params[pname("wq", "embed", "qheads")], h, hd)
    k = _split_heads(x @ params[pname("wk", "embed", "kv_heads")], kv, hd)
    v = _split_heads(x @ params[pname("wv", "embed", "kv_heads")], kv, hd)
    if cfg.rope_type == "mrope" and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_type != "none":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "attn_batch", None, "heads", None)
    k = shard(k, "attn_batch", None, None, None)
    v = shard(v, "attn_batch", None, None, None)
    if getattr(cfg, "use_flash", False):
        if jax.default_backend() == "tpu" and causal:
            from repro.kernels.flash_attention import ops as flash_ops

            out = flash_ops.flash_attention(q, k, v, causal=causal,
                                            window=window)
        else:
            out = _sdpa_blocked(q, k, v, causal=causal, window=window)
    else:
        mask = _causal_mask(s, s, 0, window) if causal else None
        out = _sdpa(q, k, v, mask, causal=causal, window=window)
    return out.reshape(b, s, h * hd) @ params[pname("wo", "qheads", "embed")]


def gqa_init_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def gqa_decode(params: dict, x: jax.Array, cache: dict, index: jax.Array, cfg,
               *, window: int | None = None) -> tuple[jax.Array, dict]:
    """One-token decode. x: [B,1,D]; cache k/v: [B,L,KV,hd]; index: scalar."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    l = cache["k"].shape[1]
    q = _split_heads(x @ params[pname("wq", "embed", "qheads")], h, hd)
    k_new = _split_heads(x @ params[pname("wk", "embed", "kv_heads")], kv, hd)
    v_new = _split_heads(x @ params[pname("wv", "embed", "kv_heads")], kv, hd)
    if cfg.rope_type != "none":
        pos = jnp.full((b, 1), index, jnp.int32)
        if cfg.rope_type == "mrope":
            pos3 = jnp.broadcast_to(pos[..., None], (b, 1, 3))
            q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k_new = apply_mrope(k_new, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, pos, cfg.rope_theta)
            k_new = apply_rope(k_new, pos, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, index, 0, 0))
    kj = jnp.arange(l)
    ok = kj <= index
    if window is not None:
        ok &= kj > index - window
    mask = jnp.where(ok, 0.0, NEG_INF)[None, None, None, :]  # [1,1,1,L]
    if getattr(cfg, "use_decode_kernel", False):
        from repro.kernels.decode_attention import ops as dec_ops

        out = dec_ops.decode_attention(q, k, v, index, window=window)
    else:
        out = _sdpa(q, k, v, mask)
    y = out.reshape(b, 1, h * hd) @ params[pname("wo", "qheads", "embed")]
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2/V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, dc = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        pname("w_dq", "embed", "dc"): dense_init(ks[0], d, (d, qr), dtype),
        pname("q_norm_scale", "dc"): jnp.ones((qr,), dtype),
        pname("w_uq", "dc", "qheads"): dense_init(ks[1], qr, (qr, h * (dn + dr)), dtype),
        pname("w_dkv", "embed", "dc"): dense_init(ks[2], d, (d, dc), dtype),
        pname("kv_norm_scale", "dc"): jnp.ones((dc,), dtype),
        pname("w_uk", "dc", "qheads"): dense_init(ks[3], dc, (dc, h * dn), dtype),
        pname("w_uv", "dc", "qheads"): dense_init(ks[4], dc, (dc, h * dv), dtype),
        pname("w_kr", "embed", "rope"): dense_init(ks[5], d, (d, dr), dtype),
        pname("wo", "qheads", "embed"): dense_init(ks[6], h * dv, (h * dv, d), dtype),
    }


def _mla_q(params, x, positions, cfg):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = x @ params[pname("w_dq", "embed", "dc")]
    ql = rmsnorm({pname("scale", "embed"): params[pname("q_norm_scale", "dc")]}, ql)
    q = (ql @ params[pname("w_uq", "dc", "qheads")]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(params, x, positions, cfg):
    c = x @ params[pname("w_dkv", "embed", "dc")]
    c = rmsnorm({pname("scale", "embed"): params[pname("kv_norm_scale", "dc")]}, c)
    kr = x @ params[pname("w_kr", "embed", "rope")]  # [B,S,dr] shared across heads
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, kr


def mla_apply(params: dict, x: jax.Array, positions: jax.Array, cfg,
              *, window: int | None = None) -> jax.Array:
    """Full-sequence MLA (training/prefill): materialises per-head K/V."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    c, kr = _mla_latents(params, x, positions, cfg)
    k_nope = (c @ params[pname("w_uk", "dc", "qheads")]).reshape(b, s, h, dn)
    v = (c @ params[pname("w_uv", "dc", "qheads")]).reshape(b, s, h, dv)
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (
        jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
    ) * scale
    scores = scores + _causal_mask(s, s, 0, window)[:, 0]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(b, s, h * dv) @ params[pname("wo", "qheads", "embed")]


def mla_init_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(params: dict, x: jax.Array, cache: dict, index: jax.Array, cfg,
               *, window: int | None = None) -> tuple[jax.Array, dict]:
    """Absorbed one-token MLA decode: attends over the compressed latents —
    per-token cache is kv_lora_rank + qk_rope_dim (576 for V3), the paper's
    (DeepSeek's) sub-quadratic-memory long-context story."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    pos = jnp.full((b, 1), index, jnp.int32)
    q_nope, q_rope = _mla_q(params, x, pos, cfg)        # [B,1,H,dn/dr]
    c_new, kr_new = _mla_latents(params, x, pos, cfg)   # [B,1,dc], [B,1,dr]
    c = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype), (0, index, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_new.astype(cache["kr"].dtype), (0, index, 0))
    w_uk = params[pname("w_uk", "dc", "qheads")].reshape(dc, h, dn)
    w_uv = params[pname("w_uv", "dc", "qheads")].reshape(dc, h, dv)
    q_abs = jnp.einsum("bshn,dhn->bshd", q_nope, w_uk)  # [B,1,H,dc]
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (
        jnp.einsum("bshd,bld->bhsl", q_abs.astype(jnp.float32), c.astype(jnp.float32))
        + jnp.einsum("bshr,blr->bhsl", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
    ) * scale
    l = c.shape[1]
    kj = jnp.arange(l)
    ok = kj <= index
    if window is not None:
        ok &= kj > index - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhsl,bld->bshd", probs, c.astype(jnp.float32))  # [B,1,H,dc]
    out = jnp.einsum("bshd,dhv->bshv", ctx.astype(x.dtype), w_uv)
    y = out.reshape(b, 1, h * dv) @ params[pname("wo", "qheads", "embed")]
    return y, {"c": c, "kr": kr}


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_init(key, cfg, dtype) -> dict:
    return gqa_init(key, cfg, dtype)


def cross_apply(params: dict, x: jax.Array, enc: jax.Array, cfg) -> jax.Array:
    """x: [B,S,D] decoder states; enc: [B,T,D] encoder output (no masking)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(x @ params[pname("wq", "embed", "qheads")], h, hd)
    k = _split_heads(enc @ params[pname("wk", "embed", "kv_heads")], kv, hd)
    v = _split_heads(enc @ params[pname("wv", "embed", "kv_heads")], kv, hd)
    out = _sdpa(q, k, v, None)
    return out.reshape(b, s, h * hd) @ params[pname("wo", "qheads", "embed")]


def cross_kv_cache(params: dict, enc: jax.Array, cfg) -> dict:
    """Precompute encoder K/V once for decode."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": _split_heads(enc @ params[pname("wk", "embed", "kv_heads")], kv, hd),
        "v": _split_heads(enc @ params[pname("wv", "embed", "kv_heads")], kv, hd),
    }


def cross_decode(params: dict, x: jax.Array, ckv: dict, cfg) -> jax.Array:
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    q = _split_heads(x @ params[pname("wq", "embed", "qheads")], h, hd)
    out = _sdpa(q, ckv["k"], ckv["v"], None)
    return out.reshape(b, 1, h * hd) @ params[pname("wo", "qheads", "embed")]
