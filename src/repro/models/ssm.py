"""State-space mixers: Mamba-1 selective scan and RWKV6 ("Finch").

Both use ``jax.lax.associative_scan`` along the sequence for training /
prefill (log-depth on TPU; the recurrences are linear with diagonal
transition so the combine is elementwise) and O(1)-state single-step
recurrences for decode — these are the architectures that make ``long_500k``
native (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, pname, shard


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM)
# ---------------------------------------------------------------------------

def mamba_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds, dconv, dt_rank = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.mamba_dt_rank
    ks = jax.random.split(key, 7)
    return {
        pname("w_in", "embed", "inner"): dense_init(ks[0], d, (d, 2 * di), dtype),
        pname("conv_w", "conv", "inner"): dense_init(ks[1], dconv, (dconv, di), dtype),
        pname("conv_b", "inner"): jnp.zeros((di,), dtype),
        pname("w_bcdt", "inner", "state"): dense_init(ks[2], di, (di, 2 * ds + dt_rank), dtype),
        pname("w_dt", "dc", "inner"): dense_init(ks[3], dt_rank, (dt_rank, di), dtype),
        pname("dt_bias", "inner"): jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                             * (math.log(0.1) - math.log(0.001)) + math.log(0.001)),
                     1e-4, None))).astype(dtype),
        pname("a_log", "inner", "state"): jnp.log(
            jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        ).astype(jnp.float32),
        pname("d_skip", "inner"): jnp.ones((di,), jnp.float32),
        pname("w_out", "inner", "embed"): dense_init(ks[5], di, (di, d), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along S. x: [B,S,DI]; w: [K,DI]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _diag_combine(x, y):
    """Associative combine for h_t = a_t * h_{t-1} + b_t (diagonal A)."""
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def _ssm_scan(u, dt, a, b, c, chunk: int = 256):
    """Chunked selective scan.  u,dt: [B,S,DI]; a: [DI,DS]; b,c: [B,S,DS].

    h_t = exp(dt_t * A) h_{t-1} + (dt_t * B_t) u_t ;  y_t = C_t . h_t
    Diagonal transition => associative scan with elementwise combine.  The
    sequence is processed in chunks (lax.scan carries the boundary state) so
    the materialised [B, L, DI, DS] working set is bounded by the chunk size
    instead of the full sequence — the Mamba-2/SSD-style TPU formulation.
    """
    s = u.shape[1]
    chunk = min(chunk, s)
    if s % chunk != 0:  # pad to a multiple (padded steps have dt=0 => identity)
        pad = chunk - s % chunk
        u, dt = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (u, dt))
        b, c = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (b, c))
    n_chunks = u.shape[1] // chunk

    def rechunk(t):
        return t.reshape(t.shape[0], n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    uc, dtc, bc, cc = map(rechunk, (u, dt, b, c))  # [N, B, L, ...]

    def step(h0, args):
        u_i, dt_i, b_i, c_i = args
        da = jnp.exp(dt_i[..., None] * a)                       # [B,L,DI,DS]
        dbu = (dt_i * u_i)[..., None] * b_i[:, :, None, :]      # [B,L,DI,DS]
        a_cum, h_rel = jax.lax.associative_scan(_diag_combine, (da, dbu), axis=1)
        h = a_cum * h0[:, None] + h_rel                          # [B,L,DI,DS]
        y = jnp.einsum("bldn,bln->bld", h, c_i)
        return h[:, -1], y

    _, ys = jax.lax.scan(step, jnp.zeros((u.shape[0], a.shape[0], a.shape[1]),
                                         u.dtype), (uc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(u.shape[0], -1, a.shape[0])
    return y[:, :s]


def mamba_apply(params: dict, x: jax.Array, cfg) -> jax.Array:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds, dt_rank = cfg.mamba_d_state, cfg.mamba_dt_rank
    xz = x @ params[pname("w_in", "embed", "inner")]
    xi, z = xz[..., :di], xz[..., di:]
    xi = shard(xi, "batch", None, "mlp")
    xi = _causal_conv(xi, params[pname("conv_w", "conv", "inner")],
                      params[pname("conv_b", "inner")])
    xi = jax.nn.silu(xi)
    bcdt = xi @ params[pname("w_bcdt", "inner", "state")]
    b, c = bcdt[..., :ds], bcdt[..., ds : 2 * ds]
    dt = jax.nn.softplus(
        bcdt[..., 2 * ds :] @ params[pname("w_dt", "dc", "inner")]
        + params[pname("dt_bias", "inner")]
    )
    a = -jnp.exp(params[pname("a_log", "inner", "state")])
    y = _ssm_scan(xi.astype(jnp.float32), dt.astype(jnp.float32), a,
                  b.astype(jnp.float32), c.astype(jnp.float32))
    y = y + xi.astype(jnp.float32) * params[pname("d_skip", "inner")]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ params[pname("w_out", "inner", "embed")]


def mamba_init_cache(cfg, batch: int, dtype) -> dict:
    di = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
    }


def mamba_decode(params: dict, x: jax.Array, cache: dict, cfg
                 ) -> tuple[jax.Array, dict]:
    """One-step recurrence. x: [B,1,D]."""
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    xz = x @ params[pname("w_in", "embed", "inner")]
    xi, z = xz[..., :di], xz[..., di:]
    conv_w = params[pname("conv_w", "conv", "inner")]
    hist = jnp.concatenate([cache["conv"], xi], axis=1)     # [B,K,DI]
    conv_out = jnp.einsum("bkd,kd->bd", hist, conv_w)[:, None] + params[pname("conv_b", "inner")]
    xi_c = jax.nn.silu(conv_out)
    bcdt = xi_c @ params[pname("w_bcdt", "inner", "state")]
    bssm, cssm = bcdt[..., :ds], bcdt[..., ds : 2 * ds]
    dt = jax.nn.softplus(
        bcdt[..., 2 * ds :] @ params[pname("w_dt", "dc", "inner")]
        + params[pname("dt_bias", "inner")]
    )
    a = -jnp.exp(params[pname("a_log", "inner", "state")])
    da = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * a)          # [B,DI,DS]
    dbu = (dt * xi_c)[:, 0, :, None].astype(jnp.float32) * bssm[:, 0, None, :].astype(jnp.float32)
    h = da * cache["ssm"] + dbu                              # [B,DI,DS]
    y = jnp.einsum("bdn,bn->bd", h, cssm[:, 0].astype(jnp.float32))[:, None]
    y = y + xi_c.astype(jnp.float32) * params[pname("d_skip", "inner")]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params[pname("w_out", "inner", "embed")]
    return out, {"conv": hist[:, 1:], "ssm": h}


# ---------------------------------------------------------------------------
# RWKV6 (Finch): data-dependent decay linear attention
# ---------------------------------------------------------------------------

def rwkv6_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    lora = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 9)
    return {
        pname("w_r", "embed", "qheads"): dense_init(ks[0], d, (d, d), dtype),
        pname("w_k", "embed", "kv_heads"): dense_init(ks[1], d, (d, d), dtype),
        pname("w_v", "embed", "kv_heads"): dense_init(ks[2], d, (d, d), dtype),
        pname("w_g", "embed", "mlp"): dense_init(ks[3], d, (d, d), dtype),
        pname("w_o", "qheads", "embed"): dense_init(ks[4], d, (d, d), dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x W_a) W_b))
        pname("decay_w0", "embed"): jnp.full((d,), -6.0, jnp.float32)
        + jax.random.uniform(ks[5], (d,), jnp.float32),
        pname("decay_wa", "embed", "dc"): dense_init(ks[6], d, (d, lora), dtype),
        pname("decay_wb", "dc", "embed"): dense_init(ks[7], lora, (lora, d), dtype),
        pname("bonus_u", "qheads"): jnp.zeros((nh, hs), jnp.float32),
        pname("token_mix", "embed"): 0.5 * jnp.ones((5, d), jnp.float32),
    }


def _rwkv_wkv_scan_quadratic(r, k, v, w, u, chunk: int = 32):
    """GLA-style chunked linear attention (the §Perf-optimized RWKV6 path).

    Within a chunk the recurrence is evaluated with two [L, L] matmuls using
    decay-factorised queries/keys (r~ = r * exp(cum_excl), k~ = k *
    exp(-cum)); full [L, NH, HS, HS] states are materialised ONLY at chunk
    boundaries — a ~L-fold cut of the dominant HBM term in the train_4k
    roofline (EXPERIMENTS.md §Perf).  Numerically safe while per-chunk decay
    products stay in fp32 range (RWKV decays ~1; chunk=32 by default).
    """
    s = r.shape[1]
    chunk = min(chunk, s)
    if s % chunk != 0:
        pad = chunk - s % chunk
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n_chunks = r.shape[1] // chunk
    b_dim, _, nh, hs = r.shape

    def rechunk(t):
        return t.reshape(b_dim, n_chunks, chunk, nh, hs).swapaxes(0, 1)

    rc, kc, vc, wc = map(rechunk, (r, k, v, w))  # [N,B,L,NH,HS]
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # strict

    def step(s0, args):
        r_i, k_i, v_i, w_i = args                       # [B,L,NH,HS]
        lw = jnp.log(jnp.maximum(w_i, 1e-30))
        ca = jnp.cumsum(lw, axis=1)                      # inclusive
        cae = ca - lw                                    # exclusive
        r_dec = r_i * jnp.exp(cae)                       # r~
        k_dec = k_i * jnp.exp(-ca)                       # k~
        # inter-chunk: r~_t . S0
        y_inter = jnp.einsum("blnk,bnkv->blnv", r_dec, s0)
        # intra-chunk: strictly-causal decayed scores
        scores = jnp.einsum("blnk,bmnk->bnlm", r_dec, k_dec) * mask[None, None]
        y_intra = jnp.einsum("bnlm,bmnv->blnv", scores, v_i)
        # bonus (current token): y += (r . (u * k)) v
        bonus_coef = jnp.sum(r_i * u[None, None] * k_i, axis=-1)  # [B,L,NH]
        y_bonus = bonus_coef[..., None] * v_i
        y = y_inter + y_intra + y_bonus
        # boundary state update
        k_tail = k_i * jnp.exp(ca[:, -1:, :, :] - ca)    # k * prod_{>tau} w
        s1 = jnp.exp(ca[:, -1])[..., None] * s0 + jnp.einsum(
            "blnk,blnv->bnkv", k_tail, v_i
        )
        return s1, y

    s_final, ys = jax.lax.scan(
        step, jnp.zeros((b_dim, nh, hs, hs), r.dtype), (rc, kc, vc, wc)
    )
    y = ys.swapaxes(0, 1).reshape(b_dim, -1, nh, hs)
    return y[:, :s], s_final


def _rwkv_wkv_scan(r, k, v, w, u, chunk: int = 32):
    """r,k,v: [B,S,NH,HS]; w (decay in (0,1)): [B,S,NH,HS]; u: [NH,HS].

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    Chunked associative scan (boundary state carried by lax.scan) so the
    [B, L, NH, HS, HS] outer-product working set is bounded by the chunk —
    and the exclusive-prefix state is recovered by an in-chunk shift rather
    than dividing by (possibly tiny) decays: numerically safe on TPU bf16.
    """
    s = r.shape[1]
    chunk = min(chunk, s)
    if s % chunk != 0:
        pad = chunk - s % chunk
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n_chunks = r.shape[1] // chunk
    b_dim, _, nh, hs = r.shape

    def rechunk(t):
        return t.reshape(b_dim, n_chunks, chunk, nh, hs).swapaxes(0, 1)

    rc, kc, vc, wc = map(rechunk, (r, k, v, w))  # [N,B,L,NH,HS]

    def step(s0, args):
        r_i, k_i, v_i, w_i = args
        kv = jnp.einsum("blnk,blnv->blnkv", k_i, v_i)        # [B,L,NH,HS,HS]
        a = jnp.broadcast_to(w_i[..., None], kv.shape)
        a_cum, s_rel = jax.lax.associative_scan(_diag_combine, (a, kv), axis=1)
        s_all = a_cum * s0[:, None] + s_rel                   # S_t within chunk
        # Exclusive prefix: S_{t-1}; first position sees the carried state.
        s_prev = jnp.concatenate([s0[:, None], s_all[:, :-1]], axis=1)
        y = jnp.einsum(
            "blnk,blnkv->blnv", r_i, s_prev + u[None, None, :, :, None] * kv
        )
        return s_all[:, -1], y

    s_final, ys = jax.lax.scan(
        step, jnp.zeros((b_dim, nh, hs, hs), r.dtype), (rc, kc, vc, wc)
    )
    y = ys.swapaxes(0, 1).reshape(b_dim, -1, nh, hs)
    return y[:, :s], s_final


def _rwkv_proj(params, x, x_prev, cfg):
    """Token-shift mixed projections. x: [B,S,D]; x_prev: [B,S,D] (shifted)."""
    mix = params[pname("token_mix", "embed")].astype(x.dtype)
    xs = [x * mix[i] + x_prev * (1.0 - mix[i]) for i in range(5)]
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    r = (xs[0] @ params[pname("w_r", "embed", "qheads")]).reshape(*x.shape[:-1], nh, hs)
    k = (xs[1] @ params[pname("w_k", "embed", "kv_heads")]).reshape(*x.shape[:-1], nh, hs)
    v = (xs[2] @ params[pname("w_v", "embed", "kv_heads")]).reshape(*x.shape[:-1], nh, hs)
    g = jax.nn.silu(xs[3] @ params[pname("w_g", "embed", "mlp")])
    dec = params[pname("decay_w0", "embed")] + jnp.tanh(
        xs[4] @ params[pname("decay_wa", "embed", "dc")]
    ) @ params[pname("decay_wb", "dc", "embed")]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(*x.shape[:-1], nh, hs)
    return r, k, v, g, w


def rwkv6_apply(params: dict, x: jax.Array, cfg) -> jax.Array:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv_proj(params, x, x_prev, cfg)
    u = params[pname("bonus_u", "qheads")]
    scan_fn = (_rwkv_wkv_scan_quadratic
               if getattr(cfg, "rwkv_chunk_impl", "states") == "quadratic"
               else _rwkv_wkv_scan)
    y, _ = scan_fn(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w, u, chunk=getattr(cfg, "rwkv_chunk", 32),
    )
    y = y.reshape(*x.shape[:-1], d).astype(x.dtype) * g.astype(x.dtype)
    return (y @ params[pname("w_o", "qheads", "embed")]).astype(x.dtype)


def rwkv6_init_cache(cfg, batch: int, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    return {
        "x_prev": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, nh, hs, hs), jnp.float32),
    }


def rwkv6_decode(params: dict, x: jax.Array, cache: dict, cfg
                 ) -> tuple[jax.Array, dict]:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    r, k, v, g, w = _rwkv_proj(params, x, cache["x_prev"], cfg)
    u = params[pname("bonus_u", "qheads")]
    r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    kv = jnp.einsum("bnk,bnv->bnkv", k1, v1)
    y = jnp.einsum("bnk,bnkv->bnv", r1, cache["wkv"] + u[None, :, :, None] * kv)
    s_new = w1[..., None] * cache["wkv"] + kv
    y = y.reshape(x.shape[0], 1, d).astype(x.dtype) * g.astype(x.dtype)
    out = (y @ params[pname("w_o", "qheads", "embed")]).astype(x.dtype)
    return out, {"x_prev": x, "wkv": s_new.astype(cache["wkv"].dtype)}
