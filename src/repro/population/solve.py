"""The solve phase: topologically execute the traced compute graph.

Where the trace phase decided *when* everything happens, the solver decides
nothing — it walks the trace's rounds in topological order (the graph's
aggregate chain) and executes each round's thousands of per-client train
leaves as ONE fused cohort dispatch (``RoundArm.fused_round``, DESIGN.md
§7), so H=1000 costs one program launch per round instead of 1000.

Randomness contract (DESIGN.md §10): the solver owns one host
``np.random.Generator`` seeded from the config, consumed strictly in
(executed round, ascending participant index) order.  Rounds the trace
voided *before* compute (below quorum, dead hub) consume nothing; with
``q=1`` and an ideal trace the stream is consumed exactly as the idealized
backend would, which is what makes ``population`` bit-identical to
``ideal`` there (pinned by ``tests/test_population.py``).

Delivery is replayed from the trace: when every sampled upload arrived the
round stays entirely on device (``need_payloads=False`` + the in-jit
reduced sum); when the trace dropped uploads mid-round the solver pulls
per-participant payloads, sums the delivered subset, and — for arms whose
noise rides distributed shares (``distributed_noise``) — adds the
conservative Gaussian top-up that restores the full-cohort noise
calibration (the same ``core.dp.tree_topup_noise`` the sim backend applies
after SecAgg recovery).

``SolveReport`` separates the two clocks: simulated seconds come from the
trace, host wall seconds from executing the solve.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping, Sequence

import numpy as np

import repro.obs as obs
from repro.arms.base import (
    AggregationServices,
    Contribution,
    RoundArm,
    tree_bytes,
    tree_sum,
)
from repro.arms.results import RoundLog
from repro.population.trace import Trace

PyTree = Any


class _PopulationServices(AggregationServices):
    """Aggregate-level services: plain sums + optional noise top-up."""

    def __init__(self, fused_reduced: PyTree | None,
                 cover: frozenset[int],
                 topup: PyTree | None = None) -> None:
        self.fused_reduced = fused_reduced
        self._cover = cover
        self._topup = topup

    def sum_sizes(self, sizes: Sequence[int]) -> int:
        return int(sum(sizes))

    def sum_payloads(self, payloads: Mapping[int, PyTree]) -> PyTree:
        if self.fused_reduced is not None and set(payloads) == self._cover:
            return self.fused_reduced
        total = tree_sum([payloads[i] for i in sorted(payloads)])
        if self._topup is not None:
            total = tree_sum([total, self._topup])
        return total


@dataclasses.dataclass
class SolveReport:
    """What the solve phase did, with simulated vs host time separated."""

    simulated_seconds: float      # the trace's clock (systems story)
    wall_seconds: float           # host time spent executing the solve
    rounds_planned: int
    rounds_completed: int
    lost_rounds: int              # trace-lost + solve-lost (empty draws)
    bytes_on_wire: float
    dropout_events: int
    recoveries: int
    noise_topups: int
    graph_nodes: int
    graph_hash: str
    empirical_q: float
    mean_cohort: float
    evals: list[tuple[int, float]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SolveResult:
    """Training outputs + the report (the backend splices these into a
    ``RunReport``)."""

    params: PyTree
    logs: list[RoundLog]
    epsilon: float
    report: SolveReport


def solve(
    trace: Trace,
    arm: RoundArm,
    *,
    on_round: Callable[[int, PyTree], None] | None = None,
) -> SolveResult:
    """Execute the traced rounds against ``arm``'s fused round-step."""
    import jax  # deferred: the trace phase never pays this import

    cfg = arm.cfg
    # repro: allow[nondeterminism] host wall metric, reported beside (never inside) content-addressed records
    t0 = time.time()
    params = arm.init_params()
    from repro.core import dp as dp_lib

    rng = np.random.default_rng(cfg.seed)
    topup_base = jax.random.key(cfg.seed * 31 + dp_lib.TOPUP_SALT)
    model_bytes = tree_bytes(params, cfg.bytes_per_param)
    logs: list[RoundLog] = []
    completed = 0
    solve_lost = 0
    noise_topups = 0
    evals: list[tuple[int, float]] = []
    eval_rounds = {n.round for n in trace.graph.nodes if n.kind == "eval"}

    for plan in trace.rounds:
      # trace-lost rounds exit the span in microseconds; executed rounds
      # time the fused dispatch + aggregate for the phase breakdown
      with obs.span("round", cat="population", arm=arm.name, t=plan.t,
                    lost=plan.lost):
        if plan.lost:
            continue  # voided pre-compute: no rng consumed (see module doc)
        t = plan.t
        # the arm may veto participants beyond availability (e.g. a local
        # privacy budget exhausted mid-run) — the trace cannot know that
        active = [i for i in plan.cohort if arm.participates(i, t)]
        if not active:
            if arm.empty_break:
                break
            solve_lost += 1
            continue
        delivered_set = set(plan.delivered)
        delivered = [i for i in active if i in delivered_set]
        missing = len(active) - len(delivered)
        if not delivered:
            solve_lost += 1
            continue

        with obs.span("fused_round", cat="train", t=t, cohort=len(active)):
            if missing == 0:
                # whole cohort delivered: payloads stay on device, the
                # in-jit reduced sum serves the aggregation
                fr = arm.fused_round(params, active, t, rng, len(active),
                                     need_payloads=False, need_reduced=True)
            else:
                fr = arm.fused_round(params, active, t, rng, len(active),
                                     need_payloads=True, need_reduced=False)
        if fr is None:
            raise RuntimeError(
                f"arm {arm.name!r} has no fused round-step; the population "
                "backend is fused-only (validation should have caught this)"
            )
        contribs, reduced = fr

        topup = None
        if missing and getattr(arm, "distributed_noise", False):
            # each of the n_shares participants added N(0, (Cσ)²/n) — with
            # ``missing`` shares lost the sum is under-noised; restore the
            # full calibration conservatively (core.dp.tree_topup_noise)
            with obs.span("noise_topup", cat="dp", t=t, missing=missing):
                topup = dp_lib.tree_topup_noise(
                    params, jax.random.fold_in(topup_base, t),
                    clip_norm=cfg.dp.clip_norm,
                    noise_multiplier=cfg.dp.noise_multiplier,
                    missing=missing, n_shares=len(active),
                )
            obs.counter("noise_topups", 1)
            noise_topups += 1

        services = _PopulationServices(
            fused_reduced=reduced, cover=frozenset(delivered), topup=topup,
        )
        with obs.span("aggregate", cat="train", t=t,
                      delivered=len(delivered)):
            outcome = arm.aggregate(
                params, {i: contribs[i] for i in delivered}, services
            )
        if not outcome.stepped:
            solve_lost += 1  # e.g. empty Poisson draw across the cohort
            if arm.void_logs:
                logs.append(RoundLog(t, plan.dst, float("nan"),
                                     arm.epsilon(), 0))
            continue
        params = outcome.params
        arm.account()
        completed += 1
        obs.counter("rounds_completed", 1)
        obs.ledger_round(arm, round=t, backend="population",
                         cohort=active, delivered=delivered,
                         bytes_up=model_bytes, topup=topup is not None)
        logs.append(RoundLog(t, plan.dst, outcome.loss, arm.epsilon(),
                             outcome.aggregate_batch))
        if t in eval_rounds:
            evals.append((t, _eval_loss(arm, params, plan.dst)))
        if on_round is not None:
            on_round(t, params)
        if arm.should_stop():
            break

    report = SolveReport(
        simulated_seconds=trace.wall_clock,
        wall_seconds=time.time() - t0,  # repro: allow[nondeterminism] host wall metric, reported beside (never inside) content-addressed records
        rounds_planned=len(trace.rounds),
        rounds_completed=completed,
        lost_rounds=trace.lost_rounds + solve_lost,
        bytes_on_wire=trace.bytes_on_wire,
        dropout_events=trace.dropout_events,
        recoveries=trace.recoveries,
        noise_topups=noise_topups,
        graph_nodes=len(trace.graph),
        graph_hash=trace.graph.graph_hash(),
        empirical_q=trace.empirical_q,
        mean_cohort=trace.mean_cohort,
        evals=evals,
    )
    return SolveResult(params=params, logs=logs, epsilon=arm.epsilon(),
                       report=report)


def _eval_loss(arm: RoundArm, params: PyTree, dst: int,
               probe: int = 64) -> float:
    """Eval-node execution: mean loss over the facilitator's probe batch."""
    import jax
    import jax.numpy as jnp

    part = arm.participants[dst % len(arm.participants)]
    n = min(probe, len(part))
    if n == 0:
        return float("nan")
    losses = jax.vmap(
        lambda x, y: arm.model.loss_fn(params, {"x": x, "y": y})
    )(jnp.asarray(part.x[:n]), jnp.asarray(part.y[:n]))
    return float(jnp.mean(losses))
