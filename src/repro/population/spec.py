"""``PopulationSpec`` — generate 1000-hospital federations from distributions.

Cross-silo scenarios pin every hospital's trace by hand; at H=1000 nobody
writes 1000 dicts.  A ``PopulationSpec`` describes the *population* —
per-hospital throughput and availability distributions, a sparse topology
family, link churn — and deterministically materialises the same
JSON-serialisable node/topology traces the rest of the repo already
consumes (``sim.nodes_from_trace`` / ``sim.Topology.from_trace``).  The
same seed always yields byte-identical traces, which is what makes the
trace phase's determinism contract (DESIGN.md §10) hold end to end.

Stdlib + the stdlib ``random`` module only: building a population must not
pay the JAX import, and ``ScenarioSpec.population`` validation imports this
module at spec-construction time.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Mapping

TOPOLOGIES = ("k_regular", "small_world", "star", "ring", "full")

# Fixed sub-stream tags so node sampling, availability sampling and churn
# sampling each consume an independent deterministic stream — adding one
# never perturbs the others.
_TAG_NODES = 101
_TAG_AVAIL = 211
_TAG_CHURN = 307


@dataclasses.dataclass
class PopulationSpec:
    """Distributional description of one hospital population."""

    hospitals: int = 1000
    seed: int = 0
    # -- topology ------------------------------------------------------------
    topology: str = "k_regular"     # k_regular | small_world | star | ring | full
    degree: int = 8                 # k_regular / small_world neighbour count
    rewire_p: float = 0.1           # small_world rewiring probability
    bandwidth: float = 12.5e6       # bytes/s per link
    latency: float = 0.02           # seconds per link
    # -- per-hospital compute (lognormal throughput spread) ------------------
    throughput_median: float = 400.0   # examples/s at the distribution median
    throughput_sigma: float = 0.5      # lognormal sigma (log-space); 0 = uniform
    overhead: float = 0.02             # fixed seconds per round
    # -- availability: a flaky fraction with exponential on/off windows ------
    flaky_fraction: float = 0.05
    mean_uptime: float = 120.0         # seconds online between outages
    mean_downtime: float = 15.0        # seconds per outage
    horizon: float = 3600.0            # availability/churn sampled over [0, horizon)
    # -- link churn ----------------------------------------------------------
    churn_rate: float = 0.0            # expected link outages per sim-second
    churn_downtime: float = 5.0        # seconds a churned link stays down

    def __post_init__(self) -> None:
        self.validate()

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        if self.hospitals < 2:
            raise ValueError("population needs at least 2 hospitals")
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"topology {self.topology!r} not in {TOPOLOGIES}"
            )
        if self.topology in ("k_regular", "small_world"):
            if not 2 <= self.degree < self.hospitals:
                raise ValueError(
                    f"degree must satisfy 2 <= k < H "
                    f"(got k={self.degree}, H={self.hospitals})"
                )
        if not 0.0 <= self.rewire_p <= 1.0:
            raise ValueError("rewire_p must be in [0, 1]")
        if not 0.0 <= self.flaky_fraction <= 1.0:
            raise ValueError("flaky_fraction must be in [0, 1]")
        for field in ("bandwidth", "latency", "throughput_median",
                      "throughput_sigma", "overhead", "mean_uptime",
                      "mean_downtime", "horizon", "churn_rate",
                      "churn_downtime"):
            if getattr(self, field) < 0:
                raise ValueError(f"{field} must be >= 0")
        if self.bandwidth == 0 or self.throughput_median == 0:
            raise ValueError("bandwidth and throughput_median must be > 0")

    # -- (de)serialisation ----------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PopulationSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown PopulationSpec fields: {sorted(unknown)}"
            )
        return cls(**dict(d))

    def replace(self, **changes: Any) -> "PopulationSpec":
        return dataclasses.replace(self, **changes)

    # -- trace materialisation ------------------------------------------------

    def build_nodes(self) -> list[dict]:
        """Per-hospital trace dicts (``sim.nodes_from_trace`` input).

        Throughputs are lognormal around ``throughput_median``; the first
        ``round(flaky_fraction * H)`` hospitals (by a seeded shuffle, so the
        flaky set is not index-correlated with the throughput draw) carry
        exponential on/off availability windows over ``[0, horizon)``.
        """
        h = self.hospitals
        rng = random.Random(f"{self.seed}:{_TAG_NODES}")
        traces: list[dict] = []
        for _ in range(h):
            if self.throughput_sigma > 0:
                thr = self.throughput_median * math.exp(
                    self.throughput_sigma * rng.gauss(0.0, 1.0)
                )
            else:
                thr = self.throughput_median
            traces.append({"throughput": round(thr, 6),
                           "overhead": self.overhead})
        n_flaky = int(round(self.flaky_fraction * h))
        if n_flaky and self.horizon > 0:
            avail = random.Random(f"{self.seed}:{_TAG_AVAIL}")
            flaky = avail.sample(range(h), n_flaky)
            for i in sorted(flaky):
                windows = []
                t = avail.expovariate(1.0 / max(self.mean_uptime, 1e-9))
                while t < self.horizon:
                    down = avail.expovariate(
                        1.0 / max(self.mean_downtime, 1e-9)
                    )
                    windows.append([round(t, 6), round(t + down, 6)])
                    t += down + avail.expovariate(
                        1.0 / max(self.mean_uptime, 1e-9)
                    )
                if windows:
                    traces[i]["dropouts"] = windows
        return traces

    def build_topology(self) -> dict:
        """``sim.Topology.from_trace`` dict (sparse family + churn schedule).

        Churn is a Poisson process over the whole edge set: each event picks
        one edge uniformly, downs it, and restores it ``churn_downtime``
        later — consumable by the existing ``LinkSchedule`` machinery.
        """
        trace: dict[str, Any] = {
            "n": self.hospitals,
            "kind": self.topology,
            "default": {"bandwidth": self.bandwidth,
                        "latency": self.latency},
        }
        if self.topology in ("k_regular", "small_world"):
            trace["k"] = self.degree
        if self.topology == "small_world":
            trace["p"] = self.rewire_p
            trace["seed"] = self.seed
        if self.churn_rate > 0 and self.horizon > 0:
            churn = random.Random(f"{self.seed}:{_TAG_CHURN}")
            edges = self._edge_list()
            schedule = []
            t = churn.expovariate(self.churn_rate)
            while t < self.horizon:
                i, j = edges[churn.randrange(len(edges))]
                schedule.append({"t": round(t, 6), "link": f"{i}-{j}",
                                 "down": True})
                schedule.append({"t": round(t + self.churn_downtime, 6),
                                 "link": f"{i}-{j}",
                                 "bandwidth": self.bandwidth,
                                 "latency": self.latency})
                t += churn.expovariate(self.churn_rate)
            if schedule:
                trace["schedule"] = sorted(schedule, key=lambda e: e["t"])
        return trace

    def _edge_list(self) -> list[tuple[int, int]]:
        """Undirected edge list of the base (pre-churn) topology."""
        # deferred: sim.topology is stdlib-only too, but avoid a module-level
        # cycle (topology never imports population)
        from repro.sim.topology import Topology

        topo = Topology.from_trace(self.build_topology_static())
        return sorted(
            {(min(i, j), max(i, j)) for (i, j) in topo._links}
        )

    def build_topology_static(self) -> dict:
        """The topology dict without the churn schedule."""
        trace = dataclasses.replace(self, churn_rate=0.0).build_topology()
        trace.pop("schedule", None)
        return trace
